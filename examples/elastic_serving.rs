//! Elastic serving demo: drive the serving engine through a load ramp and
//! watch the capacity controller trade compute for throughput.
//!
//!     cargo run --release --example elastic_serving -- \
//!         [--requests 96] [--config lm_tiny] [--workers 1]
//!
//! Three phases of offered load (light / burst / drain); the report shows
//! per-tier request counts, latency percentiles and the mean capacity
//! actually served — the paper's "variable inference time compute" as an
//! operable system.  The engine is the multi-worker `Executor`-trait
//! pipeline: each worker thread builds its own `XlaExecutor` (PJRT
//! handles are not `Send`) from the factory passed to `run`.

use std::time::{Duration, Instant};

use anyhow::Result;

use elastiformer::cli::Args;
use elastiformer::coordinator::serving::{
    ElasticServer, Request, ServeConfig, XlaExecutor,
};
use elastiformer::data::{mathgen, Tokenizer};
use elastiformer::experiments::common::{artifacts_dir, Ctx};
use elastiformer::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let config = args.str_or("config", "lm_tiny");
    let n_requests = args.usize_or("requests", 96)?;
    let workers = args.usize_or("workers", 1)?;
    let seed = args.u64_or("seed", 42)?;

    let ctx = Ctx::load(config, seed)?;
    let teacher = ctx.teacher(200)?;
    let router = ctx.router_init("router_init_r0", seed as i32)?;
    let t = ctx.rt.manifest.seq_len();

    println!("spinning up {workers} worker(s) — each compiles 4 serve \
              tiers on its own thread...");
    let cfg = ServeConfig::standard().with_workers(workers);
    let factory = XlaExecutor::factory(artifacts_dir(), config.to_string(),
                                       teacher, router, cfg.tiers.clone());
    let server = ElasticServer::new(cfg);

    // the load ramp starts only once every worker is warm — otherwise
    // the light phase would be swallowed by PJRT compile time
    let report = server.run_with_producer(factory, move |tx| {
        let tok = Tokenizer::new();
        let mut rng = Rng::new(seed ^ 0xE5);
        let phase_len = n_requests / 3;
        for id in 0..n_requests as u64 {
            let phase = (id as usize) / phase_len.max(1);
            // light -> burst -> drain
            let gap = match phase {
                0 => Duration::from_millis(40),
                1 => Duration::from_millis(1),
                _ => Duration::from_millis(25),
            };
            let p = mathgen::gen_problem(&mut rng);
            if tx
                .send(Request {
                    id,
                    tokens: tok.encode_padded(&p.full_text(), t),
                    submitted: Instant::now(),
                })
                .is_err()
            {
                return;
            }
            std::thread::sleep(gap);
        }
    }, n_requests)?;

    println!("\n== serving report ==");
    println!("requests : {}", report.completions.len());
    println!("workers  : {} (completions {:?})", report.workers,
             report.worker_counts());
    println!("wall     : {:.2}s  ({:.1} req/s)", report.wall_secs,
             report.throughput_rps());
    println!("latency  : p50 {:.1} ms   p99 {:.1} ms",
             report.latency_p(0.5), report.latency_p(0.99));
    println!("capacity : mean {:.2} (1.0 = teacher-exact)",
             report.mean_capacity());
    println!("tiers    :");
    for (tier, count) in &report.tier_counts {
        let bar = "#".repeat(count * 40 / report.completions.len().max(1));
        println!("  {tier:>4.2} | {count:>4} {bar}");
    }
    // burst phase should have shed capacity on at least some requests
    let shed = report
        .completions
        .iter()
        .filter(|c| c.tier < 1.0)
        .count();
    println!("\n{} of {} requests served below full capacity \
              (controller engaged under burst load)",
             shed, report.completions.len());
    Ok(())
}
