//! Elastic serving demo: drive the serving engine through a load ramp and
//! watch the capacity controller trade compute for throughput.
//!
//!     cargo run --release --example elastic_serving -- \
//!         [--requests 96] [--config lm_tiny]
//!
//! Three phases of offered load (light / burst / drain); the report shows
//! per-tier request counts, latency percentiles and the mean capacity
//! actually served — the paper's "variable inference time compute" as an
//! operable system.

use std::time::{Duration, Instant};

use anyhow::Result;

use elastiformer::cli::Args;
use elastiformer::coordinator::serving::{
    ElasticServer, Request, ServeConfig,
};
use elastiformer::data::{mathgen, Tokenizer};
use elastiformer::experiments::common::Ctx;
use elastiformer::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let config = args.str_or("config", "lm_tiny");
    let n_requests = args.usize_or("requests", 96)?;
    let seed = args.u64_or("seed", 42)?;

    let ctx = Ctx::load(config, seed)?;
    let teacher = ctx.teacher(200)?;
    let router = ctx.router_init("router_init_r0", seed as i32)?;
    let t = ctx.rt.manifest.seq_len();

    println!("warming up serve tiers (compiling 4 executables)...");
    let mut server = ElasticServer::new(&ctx.rt, &teacher, &router,
                                        ServeConfig::standard())?;

    let (tx, rx) = std::sync::mpsc::channel();
    let producer = std::thread::spawn(move || {
        let tok = Tokenizer::new();
        let mut rng = Rng::new(seed ^ 0xE5);
        let phase_len = n_requests / 3;
        for id in 0..n_requests as u64 {
            let phase = (id as usize) / phase_len.max(1);
            // light -> burst -> drain
            let gap = match phase {
                0 => Duration::from_millis(40),
                1 => Duration::from_millis(1),
                _ => Duration::from_millis(25),
            };
            let p = mathgen::gen_problem(&mut rng);
            if tx
                .send(Request {
                    id,
                    tokens: tok.encode_padded(&p.full_text(), t),
                    submitted: Instant::now(),
                })
                .is_err()
            {
                return;
            }
            std::thread::sleep(gap);
        }
    });

    let report = server.run(rx, n_requests)?;
    producer.join().ok();

    println!("\n== serving report ==");
    println!("requests : {}", report.completions.len());
    println!("wall     : {:.2}s  ({:.1} req/s)", report.wall_secs,
             report.throughput_rps());
    println!("latency  : p50 {:.1} ms   p99 {:.1} ms",
             report.latency_p(0.5), report.latency_p(0.99));
    println!("capacity : mean {:.2} (1.0 = teacher-exact)",
             report.mean_capacity());
    println!("tiers    :");
    for (tier, count) in &report.tier_counts {
        let bar = "#".repeat(count * 40 / report.completions.len().max(1));
        println!("  {tier:>4.2} | {count:>4} {bar}");
    }
    // burst phase should have shed capacity on at least some requests
    let shed = report
        .completions
        .iter()
        .filter(|c| c.tier < 1.0)
        .count();
    println!("\n{} of {} requests served below full capacity \
              (controller engaged under burst load)",
             shed, report.completions.len());
    Ok(())
}
