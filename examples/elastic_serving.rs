//! Elastic serving demo on the handle-based client API: start an
//! engine, drive it through a load ramp under two SLO classes, and
//! print *per-request* results — the tier each request was served at,
//! its queue/exec latency split, and its admission/shed verdicts —
//! delivered through each request's own `Response` future.
//!
//!     cargo run --release --example elastic_serving -- \
//!         [--backend sim|xla] [--requests 96] [--workers 2] [--seed S] \
//!         [--config lm_tiny]
//!
//! The default `sim` backend is hermetic (no artifacts, no XLA
//! runtime): the deterministic `SimExecutor` sleeps through a seeded
//! per-tier latency model.  `--backend xla` serves the real AOT
//! `serve_cap*` artifacts instead (needs `make artifacts` and a `pjrt`
//! build); each worker thread builds its own `XlaExecutor` because
//! PJRT handles are not `Send`.
//!
//! Three phases of offered load (light / burst / drain).  Interactive
//! requests carry a deadline and a quality floor; bulk requests are
//! best-effort.  Under the burst the controller sheds bulk capacity
//! while the floor pins interactive quality — and interactive requests
//! that can no longer meet their deadline are shed outright, which
//! shows up per-request below and in the report's class sections.

use std::time::Duration;

use anyhow::{bail, Result};

use elastiformer::cli::Args;
use elastiformer::coordinator::serving::{
    sim, ElasticEngine, EngineHandle, Request, ServeConfig, ServeError,
    SimSpec, SloClass,
};
use elastiformer::rng::Rng;

use elastiformer::data::{mathgen, Tokenizer};

#[cfg(feature = "pjrt")]
use elastiformer::coordinator::serving::XlaExecutor;
#[cfg(feature = "pjrt")]
use elastiformer::experiments::common::{artifacts_dir, Ctx};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let backend = args.str_or("backend", "sim");
    let n_requests = args.usize_or("requests", 96)?;
    let workers = args.usize_or("workers", 2)?;
    let seed = args.u64_or("seed", 42)?;

    let (engine, seq_len) = match backend {
        "sim" => start_sim(workers, seed)?,
        "xla" => start_xla(&args, workers, seed)?,
        other => bail!("--backend must be sim or xla, got {other:?}"),
    };
    drive(engine, seq_len, n_requests, seed)
}

/// Hermetic engine over the deterministic simulator: costs tuned so the
/// burst phase genuinely outruns the fleet and the controller engages.
fn start_sim(workers: usize, seed: u64) -> Result<(EngineHandle, usize)> {
    let spec = SimSpec {
        batch: 4,
        base_ms: 1.5,
        ms_per_capacity: 1.5,
        jitter_ms: 0.2,
        seed,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(workers)
        .with_queue_bound(64)
        .with_depth_per_tier(2.0)
        .with_max_batch_wait(Duration::from_millis(2));
    println!("starting {workers} sim worker(s)...");
    let seq_len = spec.seq_len;
    let engine = ElasticEngine::start(
        cfg.clone(), sim::factory(spec, cfg.capacities()))?;
    Ok((engine, seq_len))
}

/// Real-artifact engine: each worker compiles all four `serve_cap*`
/// tiers on its own thread before `start` returns.
#[cfg(feature = "pjrt")]
fn start_xla(args: &Args, workers: usize, seed: u64)
             -> Result<(EngineHandle, usize)> {
    let config = args.str_or("config", "lm_tiny");
    let ctx = Ctx::load(config, seed)?;
    let teacher = ctx.teacher(200)?;
    let router = ctx.router_init("router_init_r0", seed as i32)?;
    let seq_len = ctx.rt.manifest.seq_len();
    println!("spinning up {workers} worker(s) — each compiles 4 serve \
              tiers on its own thread...");
    let cfg = ServeConfig::standard().with_workers(workers);
    let factory = XlaExecutor::factory(artifacts_dir(), config.to_string(),
                                       teacher, router, cfg.tiers.clone());
    let engine = ElasticEngine::start(cfg, factory)?;
    Ok((engine, seq_len))
}

#[cfg(not(feature = "pjrt"))]
fn start_xla(_args: &Args, _workers: usize, _seed: u64)
             -> Result<(EngineHandle, usize)> {
    bail!("--backend xla needs a build with the `pjrt` feature \
           (default builds enable it)")
}

fn drive(engine: EngineHandle, seq_len: usize, n_requests: usize,
         seed: u64) -> Result<()> {
    let interactive = SloClass::named("interactive")
        .with_deadline(Duration::from_millis(25))
        .with_floor_tier(0.5);
    let bulk = SloClass::named("bulk");

    // submit the three-phase ramp; every submit hands back a Response
    let tok = Tokenizer::new();
    let mut rng = Rng::new(seed ^ 0xE5);
    let phase_len = (n_requests / 3).max(1);
    let mut responses = Vec::with_capacity(n_requests);
    for id in 0..n_requests as u64 {
        let phase = (id as usize) / phase_len;
        // light -> burst -> drain
        let gap = match phase {
            0 => Duration::from_millis(10),
            1 => Duration::ZERO,
            _ => Duration::from_millis(8),
        };
        let slo = if id % 3 == 0 {
            interactive.clone()
        } else {
            bulk.clone()
        };
        let p = mathgen::gen_problem(&mut rng);
        let req =
            Request::new(id, tok.encode_padded(&p.full_text(), seq_len))
                .with_slo(slo);
        responses.push(engine.submit(req));
        if !gap.is_zero() {
            std::thread::sleep(gap);
        }
    }

    // per-request results, straight from each Response future
    println!("\n== per-request results (first 16) ==");
    println!("{:>4}  {:<12} {:>5}  {:>9}  {:>9}  outcome",
             "id", "class", "tier", "queue ms", "total ms");
    let mut served = 0usize;
    let mut shed = 0usize;
    let mut failed = 0usize;
    for (i, r) in responses.into_iter().enumerate() {
        let id = r.id();
        match r.wait() {
            Ok(reply) => {
                let c = &reply.completion;
                if i < 16 {
                    println!("{id:>4}  {:<12} {:>5.2}  {:>9.2}  {:>9.2}  \
                              ok ({} logits)",
                             c.class, c.tier, c.queue_ms, c.total_ms,
                             reply.logits.len());
                }
                served += 1;
            }
            Err(ServeError::DeadlineExceeded) => {
                if i < 16 {
                    println!("{id:>4}  {:<12} {:>5}  {:>9}  {:>9}  \
                              shed: deadline expired",
                             "interactive", "-", "-", "-");
                }
                shed += 1;
            }
            Err(e) => {
                if i < 16 {
                    println!("{id:>4}  -            -      -          -  \
                              error: {e}");
                }
                failed += 1;
            }
        }
    }
    println!("  ... {served} served, {shed} shed on deadline, \
              {failed} errored (of {n_requests})");

    let report = engine.shutdown()?;
    println!("\n== serving report ==");
    println!("requests : {}", report.completions.len());
    println!("workers  : {} (completions {:?})", report.workers,
             report.worker_counts());
    println!("wall     : {:.2}s  ({:.1} req/s)", report.wall_secs,
             report.throughput_rps());
    println!("latency  : p50 {:.1} ms   p99 {:.1} ms",
             report.latency_p(0.5), report.latency_p(0.99));
    println!("capacity : mean {:.2} (1.0 = teacher-exact)",
             report.mean_capacity());
    println!("tiers    :");
    for (tier, count) in &report.tier_counts {
        let bar = "#".repeat(count * 40 / report.completions.len().max(1));
        println!("  {tier:>4.2} | {count:>4} {bar}");
    }
    println!("classes  :");
    for s in report.class_sections() {
        println!("  {:<12} served {:>4}  shed {:>3}  p50 {:>7.2} ms  \
                  p99 {:>7.2} ms  mean cap {:.2}",
                 s.class, s.served, s.shed, s.p50_ms, s.p99_ms,
                 s.mean_capacity);
    }
    // burst phase should have shed capacity on at least some requests
    let low = report
        .completions
        .iter()
        .filter(|c| c.tier < 1.0)
        .count();
    println!("\n{} of {} requests served below full capacity \
              (controller engaged under burst load)",
             low, report.completions.len());
    Ok(())
}
