//! Elasti-ViT demo: distill a token router on the ViT autoencoder and
//! visualize which patches it keeps (the Fig. 8-style heatmap), plus the
//! decoder-cosine quality metric at the chosen capacity.
//!
//!     cargo run --release --example vit_routing -- [--capacity 0.5]

use anyhow::Result;

use elastiformer::analysis::similarity::ascii_heatmap;
use elastiformer::cli::Args;
use elastiformer::coordinator::trainer::Caps;
use elastiformer::data::imagen;
use elastiformer::experiments::common::Ctx;
use elastiformer::experiments::fig7;
use elastiformer::runtime::client::Arg;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let capacity = args.f64_or("capacity", 0.5)? as f32;
    let seed = args.u64_or("seed", 42)?;

    let ctx = Ctx::load("vit_tiny", seed)?;
    let teacher = ctx.teacher(250)?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let caps = Caps([1.0, capacity, 1.0, 1.0]);

    println!("distilling Elasti-ViT token router at capacity {capacity}...");
    let eval = fig7::eval_image_batches(&ctx, 2, 0x717)?;
    let (cos, router) = fig7::distill_and_eval_vit(
        &ctx, &teacher, 50, caps, &layer_en, None, &eval, seed)?;
    println!("decoder-output cosine vs teacher: {cos:.4} \
              (paper threshold: 0.95)");

    // per-class patch selection heatmaps on one image per class
    let size = ctx.rt.manifest.cfg_usize("img_size")?;
    let b = ctx.rt.manifest.batch();
    let n_tok = ctx.rt.manifest.cfg_usize("n_tokens")?;
    let side = (n_tok as f64).sqrt() as usize;
    for class in [0usize, 2, 4] {
        let imgs: Vec<f32> = imagen::dataset(b, size, Some(class), 0x71A)
            .into_iter()
            .flat_map(|(im, _)| im)
            .collect();
        let out = ctx.rt.exec("elastic_forward", &[
            Arg::F32(&teacher),
            Arg::F32(&router),
            Arg::F32(&imgs),
            Arg::F32(&caps.0),
            Arg::F32(&layer_en),
            Arg::ScalarF32(0.0),
        ])?;
        let m_mlp = out.f32(5)?; // [B, L, N]
        let first_layer0 = &m_mlp[..n_tok];
        println!("\npatches kept for a {:?} image (layer 0, '#'=kept):",
                 imagen::CLASS_NAMES[class]);
        print!("{}", ascii_heatmap(first_layer0, side)?);
    }
    Ok(())
}
