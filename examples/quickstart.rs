//! Quickstart: load the AOT artifacts, make a pretrained-ish teacher, turn
//! it elastic, and compare loss/compute across capacities.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Runs in ~2 minutes on CPU.  Uses the `lm_tiny` config; every step here
//! is the public-API path a downstream user would take (Runtime -> Trainer
//! -> distill -> elastic forward).

use anyhow::Result;

use elastiformer::analysis::flops::{self, Capacity};
use elastiformer::coordinator::trainer::{Caps, Trainer};
use elastiformer::data::{mathgen, textgen, Batcher, TextDataset};
use elastiformer::experiments::common::Ctx;

fn main() -> Result<()> {
    // 1. load the artifact set (HLO text + manifest) onto the PJRT client
    let ctx = Ctx::load("lm_tiny", 42)?;
    println!("loaded config {} ({} teacher params)",
             ctx.rt.manifest.name(), ctx.rt.manifest.teacher_params.total());

    // 2. teacher: quick pretrain on the synthetic corpus (cached on disk)
    let teacher = ctx.teacher(200)?;

    // 3. attach ElastiFormer routers and self-distill at 75% token /
    //    50% expert capacity (the paper's Eq. 1 objective)
    let caps = Caps([0.75, 0.75, 1.0, 0.5]);
    let layer_en = vec![1.0f32; ctx.rt.manifest.n_layers()];
    let router = ctx.router_init("router_init_r1", 1)?;
    let ds = TextDataset::from_texts(
        &textgen::dataset(400, 7), ctx.rt.manifest.seq_len());
    let mut batcher = Batcher::new(ds.len(), ctx.rt.manifest.batch(), 7);
    let mut trainer = Trainer::new(&ctx.rt);
    println!("distilling routers (60 steps)...");
    let (router, hist) = trainer.distill_lm(
        "distill_step_r1", &teacher, &teacher, router, 60, 1e-3, caps,
        &layer_en, 1.0, || batcher.next_tokens(&ds))?;
    println!("  distill loss {:.4} -> {:.4}",
             hist.first().unwrap().distill, hist.last().unwrap().distill);

    // 4. evaluate the elastic model vs the teacher across capacities
    let eval_texts: Vec<String> = mathgen::dataset(100, 0xE0)
        .iter()
        .map(|p| p.full_text())
        .collect();
    let eval = ctx.lm_eval_batches(&eval_texts, 3, 9);
    let teacher_loss = ctx.lm_teacher_loss(&teacher, &eval)?;
    println!("\n{:<28} {:>10} {:>12}", "setting", "lm loss", "macs vs T");
    println!("{:<28} {:>10.4} {:>11.0}%", "teacher (dense)", teacher_loss,
             100.0);
    let dims = ctx.rt.manifest.dims()?;
    for c in [1.0f32, 0.75, 0.5] {
        let cc = Caps([c, c, 1.0, c.max(0.5)]);
        let loss = ctx.lm_elastic_loss("elastic_forward_r1", &teacher,
                                       &router, &eval, cc, &layer_en, 0.0)?;
        let macs = flops::elastic_macs(&dims, &Capacity {
            mha_tokens: c as f64,
            mlp_tokens: c as f64,
            heads: 1.0,
            experts: c.max(0.5) as f64,
            layers: 1.0,
        }) as f64 / flops::teacher_macs(&dims) as f64;
        println!("{:<28} {:>10.4} {:>11.0}%",
                 format!("elastic @ capacity {c}"), loss, 100.0 * macs);
    }
    println!("\nDone. `./target/release/elastiformer exp all` regenerates \
              every paper figure/table (DESIGN.md §4).");
    Ok(())
}
