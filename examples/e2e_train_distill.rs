//! End-to-end driver (the DESIGN.md/EXPERIMENTS.md §E2E run): pretrain a
//! transformer teacher for a few hundred steps on the synthetic corpus,
//! log the loss curve, then run the full ElastiFormer post-training
//! pipeline (router distillation at several capacities + LoRA), evaluate
//! elastic-vs-teacher quality and compute, and write everything to
//! `results/e2e/`.
//!
//!     cargo run --release --example e2e_train_distill -- \
//!         [--config lm_base] [--pretrain-steps 300] [--distill-steps 120]
//!
//! Default config is `lm_base` (~6.5M params).  `lm_large` (~29M) is
//! available after `python -m compile.aot --config lm_large`; the sandbox
//! default keeps the recorded run under ~20 minutes of CPU time.

use anyhow::Result;

use elastiformer::analysis::flops::{self, Capacity};
use elastiformer::bench::{fmt_f, Table};
use elastiformer::checkpoint::Checkpoint;
use elastiformer::cli::Args;
use elastiformer::coordinator::trainer::{BatchArg, Caps, Trainer};
use elastiformer::data::{mathgen, textgen, Batcher, TextDataset};
use elastiformer::experiments::common::{self, Ctx};
use elastiformer::metrics::{ema, write_file};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let config = args.str_or("config", "lm_base").to_string();
    let pretrain_steps = args.usize_or("pretrain-steps", 300)?;
    let distill_steps = args.usize_or("distill-steps", 120)?;
    let seed = args.u64_or("seed", 42)?;

    let ctx = Ctx::load(&config, seed)?;
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let out_dir = common::results_dir().join("e2e");

    // ---- phase 1: pretrain the teacher, logging the loss curve ---------
    // --reuse-teacher reloads results/e2e/teacher.bin (e.g. to iterate on
    // phase 2 after an interrupted run).
    let reuse = args.has("reuse-teacher");
    let mut pretrain_secs = 0.0;
    let mut final_loss = f64::NAN;
    let expect_n = ctx.rt.manifest.teacher_params.total();
    let cached = if reuse {
        Checkpoint::load(out_dir.join("teacher.bin"))
            .ok()
            .filter(|c| c.expect(&config, "teacher", expect_n).is_ok())
    } else {
        None
    };
    let teacher = if let Some(ck) = cached {
        println!("== phase 1: reusing cached teacher ({} params, step {}) ==",
                 ck.params.len(), ck.step);
        ck.params
    } else {
        println!("== phase 1: pretraining {config} for {pretrain_steps} \
                  steps (batch {b} x seq {t}) ==");
        let mut trainer = Trainer::with_logger(
            &ctx.rt, out_dir.join("pretrain_log.jsonl").to_str().unwrap())?;
        let init = trainer.init_params("init", seed as i32)?;
        let n_params = init.len();
        let ds = TextDataset::from_texts(
            &textgen::dataset(3000, seed ^ 0xE2E), t);
        let mut batcher = Batcher::new(ds.len(), b, seed ^ 11);
        let start = std::time::Instant::now();
        let (teacher, losses) = trainer.pretrain(
            "pretrain_step", init, pretrain_steps, 3e-3,
            || vec![BatchArg::Tokens(batcher.next_tokens(&ds))])?;
        pretrain_secs = start.elapsed().as_secs_f64();
        let smooth = ema(
            &losses.iter().map(|&x| x as f64).collect::<Vec<_>>(), 0.1);
        final_loss = *smooth.last().unwrap();
        println!("  {} params, {:.1}s ({:.0} tok/s)", n_params,
                 pretrain_secs,
                 (pretrain_steps * b * t) as f64 / pretrain_secs);
        let mut curve = String::from("step,loss,loss_ema\n");
        for (i, (&l, s)) in losses.iter().zip(&smooth).enumerate() {
            curve.push_str(&format!("{i},{l:.5},{s:.5}\n"));
        }
        write_file(out_dir.join("pretrain_curve.csv"), &curve)?;
        println!("  loss: {:.3} -> {:.3} (curve in \
                  results/e2e/pretrain_curve.csv)",
                 losses[0], final_loss);
        Checkpoint::new(&config, "teacher", pretrain_steps as u64,
                        teacher.clone())
            .save(out_dir.join("teacher.bin"))?;
        teacher
    };
    let smooth_last = final_loss;

    // ---- phase 2: ElastiFormer self-distillation across capacities -----
    println!("== phase 2: ElastiFormer distillation ({distill_steps} steps \
              per capacity) ==");
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let eval_texts: Vec<String> = mathgen::dataset(200, 0xE2EE)
        .iter()
        .map(|p| p.full_text())
        .collect();
    let eval = ctx.lm_eval_batches(&eval_texts, 4, 13);
    let teacher_loss = ctx.lm_teacher_loss(&teacher, &eval)?;
    let dims = ctx.rt.manifest.dims()?;
    let teacher_macs = flops::teacher_macs(&dims);

    let mut table = Table::new(&[
        "capacity", "elastic_lm_loss", "teacher_lm_loss", "macs_ratio",
        "distill_final",
    ]);
    for cap in [0.9f32, 0.75, 0.5] {
        let caps = Caps([cap, cap, 1.0, 0.5f32.max(cap)]);
        let router = ctx.router_init("router_init_r1", seed as i32)?;
        let train_ds = TextDataset::from_texts(
            &common::gsm_train_texts(800, seed ^ cap.to_bits() as u64), t);
        let mut tb = Batcher::new(train_ds.len(), b, seed ^ 12);
        let mut trainer = Trainer::with_logger(
            &ctx.rt,
            out_dir.join(format!("distill_cap{cap}.jsonl")).to_str().unwrap())?;
        let (router, hist) = trainer.distill_lm(
            "distill_step_r1", &teacher, &teacher, router, distill_steps,
            1e-3, caps, &layer_en, 1.0, || tb.next_tokens(&train_ds))?;
        let loss = ctx.lm_elastic_loss("elastic_forward_r1", &teacher,
                                       &router, &eval, caps, &layer_en, 0.0)?;
        let macs = flops::elastic_macs(&dims, &Capacity {
            mha_tokens: cap as f64,
            mlp_tokens: cap as f64,
            heads: 1.0,
            experts: (0.5f32.max(cap)) as f64,
            layers: 1.0,
        });
        println!("  capacity {cap}: elastic loss {loss:.4} vs teacher \
                  {teacher_loss:.4}, compute {:.0}%",
                 100.0 * macs as f64 / teacher_macs as f64);
        table.row(vec![
            fmt_f(cap as f64, 2),
            fmt_f(loss, 4),
            fmt_f(teacher_loss, 4),
            fmt_f(macs as f64 / teacher_macs as f64, 4),
            fmt_f(hist.last().unwrap().distill as f64, 4),
        ]);
        Checkpoint::new(&config, &format!("router_r1_cap{cap}"),
                        distill_steps as u64, router)
            .save(out_dir.join(format!("router_cap{cap}.bin")))?;
    }
    write_file(out_dir.join("e2e_summary.md"),
               &format!("# e2e run ({config})\n\npretrain: {pretrain_steps} \
                         steps, final loss {:.4}, {:.1}s\n\n{}",
                        smooth_last, pretrain_secs,
                        table.to_markdown()))?;
    table.print();
    println!("\nAll layers composed: Pallas kernels (L1) -> JAX model (L2, \
              AOT) -> Rust coordinator (L3).  Artifacts in results/e2e/.");
    Ok(())
}
