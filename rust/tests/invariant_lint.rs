//! Drives the `invariant-lint` scanner as a library over the fixture
//! files in `tests/lint_fixtures/` (one per rule), asserting exact
//! rule IDs and line numbers — plus the whole-tree cleanliness check
//! that CI gates on, so `cargo test` and the CI job cannot drift.

use std::path::Path;

use elastiformer::lint::{
    scan_source, scan_tree, RULE_GUARD_ACROSS_EXECUTE, RULE_ORDERING,
    RULE_RAW_MUTEX, RULE_STALE_ALLOW, RULE_TERMINAL_OUTSIDE_CHANNEL,
    RULE_TRACE_CONFINED,
};

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

fn rules_and_lines(rel_path: &str, source: &str)
                   -> Vec<(usize, &'static str)> {
    scan_source(rel_path, source)
        .findings
        .iter()
        .map(|f| (f.line, f.rule))
        .collect()
}

#[test]
fn raw_mutex_fixture_flags_every_raw_lock_line() {
    let got = rules_and_lines(
        "coordinator/serving/fixture_raw_mutex.rs",
        &fixture("fixture_raw_mutex.rs"));
    assert_eq!(got, vec![(4, RULE_RAW_MUTEX), (7, RULE_RAW_MUTEX)]);
}

#[test]
fn ordering_fixture_fails_without_an_allowlist_row() {
    let got = rules_and_lines(
        "coordinator/serving/fixture_ordering.rs",
        &fixture("fixture_ordering.rs"));
    assert_eq!(got, vec![(7, RULE_ORDERING)],
               "a file with no ORDERING_ALLOWLIST row must fail");
}

#[test]
fn ordering_allowlist_rows_are_enforced_per_file() {
    let src = fixture("fixture_ordering.rs");
    // queue.rs's row allows SeqCst: the same source passes there
    assert_eq!(rules_and_lines("coordinator/serving/queue.rs", &src),
               vec![]);
    // worker.rs's row is Relaxed-only: SeqCst creep is flagged
    assert_eq!(rules_and_lines("coordinator/serving/worker.rs", &src),
               vec![(7, RULE_ORDERING)]);
}

#[test]
fn guard_across_execute_fixture_flags_only_the_live_guard() {
    let got = rules_and_lines(
        "coordinator/serving/fixture_guard_across_execute.rs",
        &fixture("fixture_guard_across_execute.rs"));
    assert_eq!(got, vec![(7, RULE_GUARD_ACROSS_EXECUTE)],
               "drop()-released and scope-released guards are clean");
}

#[test]
fn terminal_fixture_flags_construction_outside_the_channel_module() {
    let src = fixture("fixture_terminal.rs");
    let got = rules_and_lines(
        "coordinator/serving/stream/fixture_terminal.rs", &src);
    assert_eq!(got, vec![(6, RULE_TERMINAL_OUTSIDE_CHANNEL),
                         (10, RULE_TERMINAL_OUTSIDE_CHANNEL)]);
    // the channel module itself is the one legitimate home
    assert_eq!(rules_and_lines("coordinator/serving/stream/mod.rs", &src),
               vec![]);
}

#[test]
fn trace_fixture_flags_construction_outside_the_recorder_module() {
    let src = fixture("fixture_trace_confined.rs");
    let got = rules_and_lines(
        "coordinator/serving/fixture_trace_confined.rs", &src);
    assert_eq!(got, vec![(6, RULE_TRACE_CONFINED),
                         (10, RULE_TRACE_CONFINED)]);
    // the recorder module itself is the one legitimate home
    assert_eq!(rules_and_lines("coordinator/serving/trace.rs", &src),
               vec![]);
}

#[test]
fn stale_allow_fixture_reports_dead_and_unknown_escapes() {
    let report = scan_source(
        "coordinator/serving/fixture_stale_allow.rs",
        &fixture("fixture_stale_allow.rs"));
    let got: Vec<(usize, &str)> = report.findings.iter()
        .map(|f| (f.line, f.rule)).collect();
    // the live escape on line 5 suppresses its raw-mutex finding; the
    // stale escape (line 7) and the unknown-rule escape (line 12) are
    // findings themselves
    assert_eq!(got, vec![(7, RULE_STALE_ALLOW),
                         (12, RULE_STALE_ALLOW)]);
    // every escape — live or not — is inventoried for --list-allows
    let allow_lines: Vec<usize> =
        report.allows.iter().map(|a| a.line).collect();
    assert_eq!(allow_lines, vec![5, 7, 12]);
    assert!(report.allows.iter().all(|a| !a.reason.is_empty()),
            "escape reasons survive parsing");
}

#[test]
fn out_of_scope_paths_are_never_linted() {
    let src = fixture("fixture_raw_mutex.rs");
    assert!(scan_source("runtime/client.rs", &src).findings.is_empty());
    assert!(scan_source("coordinator/training.rs", &src)
                .findings
                .is_empty());
    assert!(scan_source("coordinator/serving/README.md", &src)
                .findings
                .is_empty(),
            "non-.rs files are out of scope even under serving/");
}

/// The gate itself, mirrored into the test suite: the shipped serving
/// tree must be lint-clean with zero allow escapes.  If this fails,
/// so does the CI `invariant-lint` job — fix the code or write an
/// explicit `lint: allow` with a reason.
#[test]
fn shipped_serving_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let (findings, allows) =
        scan_tree(&root).expect("scanning rust/src must succeed");
    assert!(findings.is_empty(),
            "invariant-lint findings in the shipped tree:\n{}",
            findings.iter().map(|f| f.to_string())
                .collect::<Vec<_>>().join("\n"));
    assert!(allows.is_empty(),
            "the shipped tree carries no allow escapes today; if you \
             added one on purpose, update this assertion and say why");
}
