//! Property-based tests over coordinator invariants (in-repo harness,
//! see src/proptest.rs).  No artifacts required — these cover the pure
//! substrates: batcher, capacity controller, tokenizer, JSON codec,
//! checkpoint format, top-k/ranking math mirrors, schedules.

use elastiformer::checkpoint::Checkpoint;
use elastiformer::coordinator::schedule::LrSchedule;
use elastiformer::coordinator::serving::CapacityController;
use elastiformer::data::loader::Batcher;
use elastiformer::data::{capgen, imagen, Tokenizer};
use elastiformer::json::{self, Value};
use elastiformer::metrics::bootstrap_ci;
use elastiformer::proptest::check;
use elastiformer::rng::Rng;

#[test]
fn prop_batcher_full_batches_and_epoch_coverage() {
    check("batcher_coverage", 50, |rng| {
        let n = 1 + rng.below(40);
        let b = 1 + rng.below(12);
        let mut batcher = Batcher::new(n, b, rng.next_u64());
        let mut seen = vec![0usize; n];
        let epochs = 3;
        let steps = (n * epochs).div_ceil(b);
        for _ in 0..steps {
            let idx = batcher.next_indices();
            if idx.len() != b {
                return Err(format!("batch size {} != {b}", idx.len()));
            }
            for i in idx {
                if i >= n {
                    return Err(format!("index {i} out of range {n}"));
                }
                seen[i] += 1;
            }
        }
        // coverage: every row appears at least once over >= 3 epochs
        if seen.iter().any(|&c| c == 0) {
            return Err("some row never sampled across epochs".into());
        }
        // balance: counts differ by at most the wrap-around slack
        let (mn, mx) = (seen.iter().min().unwrap(), seen.iter().max().unwrap());
        if mx - mn > epochs + 1 {
            return Err(format!("unbalanced sampling: min {mn}, max {mx}"));
        }
        Ok(())
    });
}

#[test]
fn prop_controller_never_exceeds_bounds_and_monotone() {
    check("controller_bounds", 60, |rng| {
        let k = 2 + rng.below(3);
        let tiers: Vec<f32> = (0..k).map(|i| 1.0 - i as f32 * 0.25).collect();
        let mut c = CapacityController::new(tiers.clone(), 1.0 + rng.f64() * 8.0);
        let lo = *tiers.last().unwrap();
        for _ in 0..50 {
            let t = c.choose(rng.below(64));
            if !(lo..=1.0).contains(&t) {
                return Err(format!("tier {t} out of [{lo}, 1.0]"));
            }
        }
        // pure mapping is monotone non-increasing in depth
        let mut prev = f32::INFINITY;
        for d in 0..100 {
            let t = c.tier_for_depth(d as f64 * 0.5);
            if t > prev + 1e-9 {
                return Err(format!("not monotone at depth {d}"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn prop_tokenizer_roundtrip_and_padding() {
    check("tokenizer_roundtrip", 80, |rng| {
        let tok = Tokenizer::new();
        let len = 4 + rng.below(60);
        let n_chars = rng.below(100);
        let s: String = (0..n_chars)
            .map(|_| (rng.range(32, 126) as u8) as char)
            .collect();
        if tok.decode(&tok.encode(&s)) != s {
            return Err(format!("roundtrip failed for {s:?}"));
        }
        let padded = tok.encode_padded(&s, len);
        if padded.len() != len {
            return Err(format!("padded len {} != {len}", padded.len()));
        }
        if padded[0] != elastiformer::data::tokenizer::BOS {
            return Err("missing BOS".into());
        }
        if !padded.contains(&elastiformer::data::tokenizer::EOS) {
            return Err("missing EOS".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.range(-1_000_000, 1_000_000) as f64) / 64.0),
            3 => {
                let n = rng.below(12);
                Value::Str(
                    (0..n).map(|_| (rng.range(32, 126) as u8) as char).collect())
            }
            4 => Value::Arr(
                (0..rng.below(5)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect()),
        }
    }
    check("json_roundtrip", 80, |rng| {
        let v = random_value(rng, 0);
        let s = json::to_string(&v);
        let v2 = json::parse(&s).map_err(|e| format!("parse failed: {e}"))?;
        if v != v2 {
            return Err(format!("roundtrip mismatch: {s}"));
        }
        let sp = json::to_string_pretty(&v);
        let v3 = json::parse(&sp).map_err(|e| format!("pretty parse: {e}"))?;
        if v != v3 {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip_random() {
    check("checkpoint_roundtrip", 30, |rng| {
        let n = rng.below(5000);
        let params: Vec<f32> = (0..n).map(|_| rng.gaussian_f32(1.0)).collect();
        let ck = Checkpoint::new("cfg", "kind", rng.next_u64(), params);
        let path = std::env::temp_dir()
            .join(format!("efck_prop_{}.bin", rng.next_u64()));
        ck.save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        if back != ck {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lr_schedule_bounded_and_finite() {
    check("lr_schedule", 60, |rng| {
        let total = 1 + rng.below(2000);
        let base = 10f64.powf(-(1.0 + rng.f64() * 4.0));
        let s = LrSchedule::cosine(base, total);
        for step in 0..total + 10 {
            let lr = s.at(step);
            if !lr.is_finite() || lr <= 0.0 || lr > base * 1.0001 {
                return Err(format!("lr {lr} out of (0, {base}] at {step}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bootstrap_ci_orders_and_brackets() {
    check("bootstrap_ci", 40, |rng| {
        let n = 2 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian() * 3.0).collect();
        let (mean, lo, hi) = bootstrap_ci(&xs, 100, 0.95, rng.next_u64());
        if !(lo <= hi) {
            return Err(format!("lo {lo} > hi {hi}"));
        }
        if mean < lo - 3.0 || mean > hi + 3.0 {
            return Err(format!("mean {mean} far outside [{lo}, {hi}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_caption_scores_in_range_and_truth_scores_perfectly() {
    check("caption_scores", 60, |rng| {
        let class = rng.below(imagen::NUM_CLASSES);
        let (_, scene) = imagen::gen_image(rng, class, 8);
        let cap = capgen::caption(&scene, rng);
        let sc = capgen::score_caption(&cap, &scene);
        if sc.recall != 1.0 || sc.hallucination != 0.0 {
            return Err(format!("truth caption scored {sc:?}: {cap}"));
        }
        // arbitrary text stays in range
        let junk: String = (0..rng.below(40))
            .map(|_| (rng.range(97, 122) as u8) as char)
            .collect();
        let sj = capgen::score_caption(&junk, &scene);
        if !(0.0..=1.0).contains(&sj.recall)
            || !(0.0..=1.0).contains(&sj.hallucination) {
            return Err(format!("junk caption out of range {sj:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_imagen_images_bounded_and_class_deterministic() {
    check("imagen_bounds", 40, |rng| {
        let class = rng.below(imagen::NUM_CLASSES);
        let size = 8 + rng.below(3) * 8;
        let (img, scene) = imagen::gen_image(rng, class, size);
        if img.len() != size * size * 3 {
            return Err("bad size".into());
        }
        if img.iter().any(|&p| !(0.0..=1.0).contains(&p)) {
            return Err("pixel out of [0,1]".into());
        }
        let again = imagen::render(&scene, size);
        if again != img {
            return Err("render not pure".into());
        }
        Ok(())
    });
}
