//! Property-based tests over coordinator invariants (in-repo harness,
//! see src/proptest.rs).  No artifacts required — these cover the pure
//! substrates: batcher, capacity controller, tokenizer, JSON codec,
//! checkpoint format, top-k/ranking math mirrors, schedules.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use elastiformer::checkpoint::Checkpoint;
use elastiformer::coordinator::schedule::LrSchedule;
use elastiformer::coordinator::serving::{
    floor_rung, form_batch, sim, AdmissionQueue, CapacityController,
    ElasticEngine, ExecOutput, Executor, FaultPlan, FaultPolicy, Request,
    Response, ServeConfig, ServeError, ServeReport, SimSpec, SloClass,
    Stamped, StreamEvent, StreamRequest, TraceCounts,
};

mod common;
use common::counting_factory;
use elastiformer::data::loader::Batcher;
use elastiformer::data::{capgen, imagen, Tokenizer};
use elastiformer::json::{self, Value};
use elastiformer::metrics::bootstrap_ci;
use elastiformer::proptest::check;
use elastiformer::rng::Rng;

#[test]
fn prop_batcher_full_batches_and_epoch_coverage() {
    check("batcher_coverage", 50, |rng| {
        let n = 1 + rng.below(40);
        let b = 1 + rng.below(12);
        let mut batcher = Batcher::new(n, b, rng.next_u64());
        let mut seen = vec![0usize; n];
        let epochs = 3;
        let steps = (n * epochs).div_ceil(b);
        for _ in 0..steps {
            let idx = batcher.next_indices();
            if idx.len() != b {
                return Err(format!("batch size {} != {b}", idx.len()));
            }
            for i in idx {
                if i >= n {
                    return Err(format!("index {i} out of range {n}"));
                }
                seen[i] += 1;
            }
        }
        // coverage: every row appears at least once over >= 3 epochs
        if seen.iter().any(|&c| c == 0) {
            return Err("some row never sampled across epochs".into());
        }
        // balance: counts differ by at most the wrap-around slack
        let (mn, mx) = (seen.iter().min().unwrap(), seen.iter().max().unwrap());
        if mx - mn > epochs + 1 {
            return Err(format!("unbalanced sampling: min {mn}, max {mx}"));
        }
        Ok(())
    });
}

#[test]
fn prop_controller_never_exceeds_bounds_and_monotone() {
    check("controller_bounds", 60, |rng| {
        let k = 2 + rng.below(3);
        let tiers: Vec<f32> = (0..k).map(|i| 1.0 - i as f32 * 0.25).collect();
        let mut c = CapacityController::new(tiers.clone(), 1.0 + rng.f64() * 8.0);
        let lo = *tiers.last().unwrap();
        for _ in 0..50 {
            let t = c.choose(rng.below(64));
            if !(lo..=1.0).contains(&t) {
                return Err(format!("tier {t} out of [{lo}, 1.0]"));
            }
        }
        // pure mapping is monotone non-increasing in depth
        let mut prev = f32::INFINITY;
        for d in 0..100 {
            let t = c.tier_for_depth(d as f64 * 0.5);
            if t > prev + 1e-9 {
                return Err(format!("not monotone at depth {d}"));
            }
            prev = t;
        }
        Ok(())
    });
}

fn sim_request(id: u64, tokens: Vec<i32>) -> Request {
    Request::new(id, tokens)
}

#[test]
fn prop_admission_queue_fifo_no_drop_no_dup() {
    // arbitrary single-consumer push/pop interleavings: every pushed
    // request comes back exactly once, in admission order
    check("queue_fifo_no_drop", 40, |rng| {
        let n = 1 + rng.below(60);
        let q = AdmissionQueue::new(n); // never block the test thread
        let mut next_id = 0u64;
        let mut popped: Vec<u64> = Vec::new();
        while (next_id as usize) < n || !q.is_empty() {
            let can_push = (next_id as usize) < n;
            let can_pop = !q.is_empty();
            if can_push && (!can_pop || rng.chance(0.6)) {
                q.push(sim_request(next_id, vec![0; 4]))
                    .map_err(|_| "push rejected on open queue".to_string())?;
                next_id += 1;
            } else {
                let max = 1 + rng.below(8);
                let got = q.pop_batch(max, Duration::ZERO);
                if got.is_empty() {
                    return Err("empty pop on nonempty open queue".into());
                }
                if got.len() > max {
                    return Err(format!("pop of {} > max {max}", got.len()));
                }
                popped.extend(got.iter().map(|r| r.id));
            }
        }
        q.close();
        if !q.pop_batch(8, Duration::ZERO).is_empty() {
            return Err("drained queue still yielded requests".into());
        }
        if popped != (0..n as u64).collect::<Vec<_>>() {
            return Err(format!(
                "dropped/duplicated/reordered: {} of {n} popped",
                popped.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_form_batch_exact_padding_and_order() {
    check("form_batch_padding", 60, |rng| {
        let batch = 1 + rng.below(8);
        let seq_len = rng.below(33);
        let k = 1 + rng.below(batch);
        let reqs: Vec<Request> = (0..k)
            .map(|i| {
                let len = rng.below(seq_len * 2 + 1);
                let tokens =
                    (0..len).map(|_| rng.range(0, 96) as i32).collect();
                sim_request(i as u64, tokens)
            })
            .collect();
        let rows: Vec<Vec<i32>> =
            reqs.iter().map(|r| r.tokens.clone()).collect();
        let b = form_batch(reqs, batch, seq_len);
        if b.tokens.len() != batch * seq_len {
            return Err(format!("{} tokens != {batch} * {seq_len}",
                               b.tokens.len()));
        }
        if b.requests.len() != k || b.padded_rows != batch - k {
            return Err("requests dropped or duplicated".into());
        }
        for (i, row) in rows.iter().enumerate() {
            if b.requests[i].id != i as u64 {
                return Err(format!("row {i} out of order"));
            }
            let m = row.len().min(seq_len);
            if b.tokens[i * seq_len..i * seq_len + m] != row[..m] {
                return Err(format!("row {i} content mangled"));
            }
            if b.tokens[i * seq_len + m..(i + 1) * seq_len]
                .iter()
                .any(|&t| t != 0)
            {
                return Err(format!("row {i} pad not zero"));
            }
        }
        for p in k..batch {
            if b.tokens[p * seq_len..(p + 1) * seq_len]
                != b.tokens[(k - 1) * seq_len..k * seq_len]
            {
                return Err(format!("pad row {p} != last real row"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_serving_pipeline_exactly_once_across_shards() {
    // full engine over instant sim executors: arbitrary (n, workers,
    // shards, batch, bound) topologies — 1-shard shared mode, the
    // default one-shard-per-worker mode, and shard counts that force
    // heavy stealing — never drop, duplicate, or starve a request:
    // every submitted Response resolves Ok within a bounded time, and
    // the report's completion set is exactly the submitted id set.
    // (The old per-worker FIFO assertion is gone by design: stealing
    // interleaves shards, so a worker's completion order is no longer
    // globally monotone.  Order within one shard is still FIFO —
    // covered by the queue-level properties.)
    check("serving_exactly_once", 25, |rng| {
        let n = 1 + rng.below(80);
        let workers = 1 + rng.below(3);
        let shards = rng.below(workers + 2); // 0 = auto (one per worker)
        let batch = 1 + rng.below(6);
        let spec = SimSpec { batch, seq_len: 8, ..SimSpec::instant() };
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_shards(shards)
            .with_queue_bound(1 + rng.below(64))
            .with_max_batch_wait(Duration::ZERO);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(cfg, sim::factory(spec, caps))
            .map_err(|e| format!("start failed: {e:#}"))?;
        let responses: Vec<Response> = (0..n as u64)
            .map(|id| engine.submit(sim_request(id, vec![0; 8])))
            .collect();
        for r in responses {
            match r.wait_timeout(Duration::from_secs(30)) {
                Some(Ok(_)) => {}
                Some(Err(e)) => {
                    return Err(format!("response errored: {e}"));
                }
                None => return Err("response never resolved".into()),
            }
        }
        let report = engine
            .shutdown()
            .map_err(|e| format!("engine failed: {e:#}"))?;
        let mut ids: Vec<u64> =
            report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        if ids != (0..n as u64).collect::<Vec<_>>() {
            return Err(format!("exactly-once violated: {} of {n}",
                               ids.len()));
        }
        if report.completions.iter().any(|c| c.worker >= workers) {
            return Err("completion from a nonexistent worker".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_queue_exactly_once_across_steals() {
    // raw queue level: concurrent producers and stealing consumers on
    // arbitrary (bound, shards, producers, consumers) topologies lose
    // and duplicate nothing, and the aggregate depth gauge returns to
    // exactly zero once everything is drained
    check("sharded_queue_steals", 10, |rng| {
        let shards = 1 + rng.below(4);
        let bound = 1 + rng.below(32);
        let n_producers = 1 + rng.below(3);
        let per_producer = (20 + rng.below(80)) as u64;
        let n_consumers = 1 + rng.below(4);
        let q = Arc::new(AdmissionQueue::sharded(bound, shards));
        let mut producers = Vec::new();
        for p in 0..n_producers as u64 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for w in 0..n_consumers {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                loop {
                    let got =
                        q.pop_batch_as(w, 5, Duration::from_micros(200));
                    if got.is_empty() {
                        return ids;
                    }
                    ids.extend(got);
                }
            }));
        }
        for p in producers {
            p.join().map_err(|_| "producer panicked".to_string())?;
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(
                c.join().map_err(|_| "consumer panicked".to_string())?);
        }
        if q.len() != 0 {
            return Err(format!("depth gauge stuck at {}", q.len()));
        }
        all.sort_unstable();
        let want: Vec<u64> =
            (0..n_producers as u64 * per_producer).collect();
        if all != want {
            return Err(format!("{} of {} popped exactly once",
                               all.len(), want.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_per_class_controllers_isolate_exec_estimates() {
    // tentpole invariant: a slow worker class under load never changes
    // a fast class's tier choice.  Across random topologies (worker
    // counts, batch sizes, shard counts), a fast (instant) and a slow
    // (base-latency >= 2x the deadline) sim class share one queue; both
    // are warmed at tier 1.0 with best-effort traffic, then deadline'd
    // requests are submitted one at a time.  The fast class's own
    // estimate (~0ms) always fits the slack, so every fast-served
    // deadline'd completion must stay at tier 1.0 — under the old
    // single shared controller, the slow class's observations inflated
    // the shared estimate and demoted fast-served batches too.  The
    // slow class's estimate must stay its own: demotion there is
    // *required*, and the learned estimates must diverge.
    check("per_class_controller_isolation", 5, |rng| {
        let fast_workers = 1 + rng.below(2);
        let slow_workers = 1 + rng.below(2);
        let batch = 1 + rng.below(3);
        let slow_ms = 80.0 + rng.f64() * 60.0; // 80..140ms per batch
        // the budget sits far above an instant batch and far below a
        // slow one, so neither verdict hinges on scheduler luck
        let deadline = Duration::from_millis(40);
        let cfg0 = ServeConfig::sim();
        let caps = cfg0.capacities();
        let fast_spec = SimSpec { batch, seq_len: 8, ..SimSpec::instant() };
        let slow_spec = SimSpec {
            batch,
            seq_len: 8,
            base_ms: slow_ms,
            ms_per_capacity: 0.0,
            jitter_ms: 0.0,
            ..SimSpec::standard()
        };
        let fast_count = Arc::new(AtomicUsize::new(0));
        let slow_count = Arc::new(AtomicUsize::new(0));
        let cfg = cfg0
            .with_queue_bound(64)
            .with_queue_shards(rng.below(4)) // incl. shared + steal-heavy
            .with_depth_per_tier(1e9) // backlog never demotes
            .with_max_batch_wait(Duration::ZERO)
            .with_worker_class(
                "fast", fast_workers,
                counting_factory(fast_spec, caps.clone(),
                                 fast_count.clone()))
            .with_worker_class(
                "slow", slow_workers,
                counting_factory(slow_spec, caps, slow_count.clone()));
        let engine = ElasticEngine::start_fleet(cfg)
            .map_err(|e| format!("start_fleet failed: {e:#}"))?;
        let mut id = 0u64;
        // warm both latency models at tier 1.0 until the counters
        // prove both classes executed
        let mut rounds = 0usize;
        while fast_count.load(Ordering::SeqCst) == 0
            || slow_count.load(Ordering::SeqCst) == 0
        {
            rounds += 1;
            if rounds > 200 {
                return Err("a class never executed a warmup batch".into());
            }
            let warm: Vec<Response> = (0..8)
                .map(|_| {
                    let r = engine.submit(sim_request(id, vec![0; 8]));
                    id += 1;
                    r
                })
                .collect();
            for r in warm {
                r.wait().map_err(|e| format!("warmup failed: {e}"))?;
            }
        }
        // deadline'd phase, one at a time (slack at pop ~= the budget);
        // run until the slow class has provably served one
        let slo = SloClass::named("dl").with_deadline(deadline);
        let slow_before = slow_count.load(Ordering::SeqCst);
        let mut submitted = 0usize;
        while submitted < 6
            || slow_count.load(Ordering::SeqCst) == slow_before
        {
            submitted += 1;
            if submitted > 300 {
                return Err(
                    "slow class never served a deadline'd request".into());
            }
            let r = engine.submit(
                sim_request(id, vec![0; 8]).with_slo(slo.clone()));
            id += 1;
            match r.wait() {
                Ok(_) => {}
                // a scheduler stall past the whole budget sheds the
                // request — rare, legitimate, and accounted below
                Err(ServeError::DeadlineExceeded) => {}
                Err(e) => {
                    return Err(format!("deadline'd serve failed: {e}"));
                }
            }
        }
        let report = engine
            .shutdown()
            .map_err(|e| format!("engine failed: {e:#}"))?;
        if report.completions.len() + report.sheds.len() != id as usize {
            return Err(format!("{} served + {} shed != {id} submitted",
                               report.completions.len(),
                               report.sheds.len()));
        }
        // the isolation claim, per completion
        for c in report.completions.iter().filter(|c| c.class == "dl") {
            if c.worker_class == "fast" && c.tier != 1.0 {
                return Err(format!(
                    "slow-class load changed a fast-served tier: {c:?}"));
            }
            if c.worker_class == "slow" && c.tier >= 1.0 {
                return Err(format!(
                    "slow-served deadline'd batch not demoted: {c:?}"));
            }
        }
        // and the learned estimates stay per-class
        let sections = report.worker_class_sections();
        let top_est = |name: &str| {
            sections
                .iter()
                .find(|s| s.class == name)
                .and_then(|s| {
                    s.exec_estimates_ms
                        .iter()
                        .find(|(t, _)| (*t - 1.0).abs() < 1e-6)
                        .and_then(|(_, e)| *e)
                })
        };
        let fast_est =
            top_est("fast").ok_or("fast class has no 1.0 estimate")?;
        let slow_est =
            top_est("slow").ok_or("slow class has no 1.0 estimate")?;
        if slow_est < slow_ms * 0.75 {
            return Err(format!(
                "slow estimate {slow_est} ms forgot its {slow_ms} ms \
                 latency model"));
        }
        if fast_est >= slow_est {
            return Err(format!(
                "estimates did not diverge: fast {fast_est} >= \
                 slow {slow_est}"));
        }
        Ok(())
    });
}

/// Executor that panics after a globally shared number of batches —
/// the hostile backend for the exactly-once resolution property.
struct PanicAfter {
    executed: Arc<AtomicUsize>,
    panic_after: usize,
    batch: usize,
}

impl Executor for PanicAfter {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        8
    }
    fn execute(&mut self, tier: f32, _tokens: &[i32])
               -> anyhow::Result<ExecOutput> {
        let k = self.executed.fetch_add(1, Ordering::SeqCst);
        if k >= self.panic_after {
            panic!("injected executor panic at batch {k}");
        }
        Ok(ExecOutput { logits: vec![tier; self.batch] })
    }
}

#[test]
fn prop_every_submit_resolves_exactly_once_across_panics_and_shutdown() {
    // the handle-API backbone: no submitted request's Response is ever
    // lost or left hanging, no matter how the fleet dies.  Executors
    // panic after a random number of batches (possibly zero: the whole
    // fleet dies instantly; possibly huge: nothing panics at all), the
    // engine is shut down with requests possibly still queued, and yet
    // every Response must resolve exactly once — Ok for the served
    // prefix, an error verdict for the rest.  "Exactly once" is
    // structural (wait consumes the Response and the engine holds a
    // unique drop-guarded responder), so the observable property is:
    // every wait returns, within a bounded time, and the served ones
    // match the engine's own report.
    check("submit_resolves_exactly_once", 12, |rng| {
        let n = 1 + rng.below(60);
        let workers = 1 + rng.below(3);
        let batch = 1 + rng.below(4);
        let panic_after = rng.below(12); // 0 => immediate fleet death
        let executed = Arc::new(AtomicUsize::new(0));
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_shards(rng.below(workers + 2)) // incl. steal-heavy
            .with_queue_bound(1 + rng.below(32))
            .with_max_batch_wait(Duration::ZERO);
        let factory_counter = executed.clone();
        let engine = ElasticEngine::start(cfg, move |_| {
            Ok(Box::new(PanicAfter {
                executed: factory_counter.clone(),
                panic_after,
                batch,
            }) as Box<dyn Executor>)
        })
        .map_err(|e| format!("start failed: {e:#}"))?;
        // blocking submits cannot hang: a dying fleet closes the queue,
        // which resolves the pending push immediately
        let responses: Vec<Response> = (0..n as u64)
            .map(|id| engine.submit(sim_request(id, vec![0; 8])))
            .collect();
        // shutdown may surface the injected panics as Err — that's the
        // correct report; the property under test is response delivery
        let shutdown_result = engine.shutdown();
        let mut served = 0usize;
        let mut errored = 0usize;
        for r in responses {
            match r.wait_timeout(Duration::from_secs(30)) {
                Some(Ok(_)) => served += 1,
                Some(Err(_)) => errored += 1,
                None => {
                    return Err("a response never resolved".into());
                }
            }
        }
        if served + errored != n {
            return Err(format!("{served} + {errored} != {n}"));
        }
        match shutdown_result {
            Ok(report) => {
                if report.completions.len() != served {
                    return Err(format!(
                        "report says {} served, callers saw {served}",
                        report.completions.len()));
                }
            }
            Err(_) => {
                // fleet died: at least one request must have errored,
                // unless every request was already served before the
                // panic landed (possible when n is small)
                if errored == 0 && served != n {
                    return Err("fleet died, nothing errored, yet not \
                                everything was served"
                        .into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_stream_terminates_in_exactly_one_done_or_shed() {
    // streaming backbone: every submit_stream observes Token* then
    // exactly one terminal (Done | Shed) then end-of-stream — across
    // panicking executors (possibly before the first batch), mid-decode
    // shutdown, expired deadlines, mixed one-shot traffic, and random
    // (workers, shards, batch, bound) topologies.  Token steps are
    // strictly ordered from 0, and on a clean shutdown the report's
    // session logs reconcile exactly with what the clients observed.
    check("stream_exactly_once", 10, |rng| {
        let sessions = 1 + rng.below(8);
        let max_steps = 1 + rng.below(5);
        let workers = 1 + rng.below(3);
        let batch = 1 + rng.below(4);
        let panic_after = rng.below(16); // 0 => instant fleet death
        let executed = Arc::new(AtomicUsize::new(0));
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_shards(rng.below(workers + 2))
            .with_queue_bound(1 + rng.below(32))
            .with_max_batch_wait(Duration::ZERO);
        let factory_counter = executed.clone();
        let engine = ElasticEngine::start(cfg, move |_| {
            Ok(Box::new(PanicAfter {
                executed: factory_counter.clone(),
                panic_after,
                batch,
            }) as Box<dyn Executor>)
        })
        .map_err(|e| format!("start failed: {e:#}"))?;
        let mut streams = Vec::new();
        let mut oneshots = Vec::new();
        for id in 0..sessions as u64 {
            let mut req = StreamRequest::new(id, vec![1; 4], max_steps);
            if rng.chance(0.3) {
                // near-instant deadline: exercises the expired-session
                // shed path and the urgent queue machinery for decode
                req = req.with_slo(SloClass::named("dl").with_deadline(
                    Duration::from_micros(rng.below(500) as u64)));
            }
            streams.push(engine.submit_stream(req));
            if rng.chance(0.5) {
                // one-shot traffic interleaves with decode steps
                oneshots.push(
                    engine.submit(sim_request(1000 + id, vec![0; 8])));
            }
        }
        // shutdown races live sessions: mid-decode close is the norm
        // here, not the exception
        let shutdown_result = engine.shutdown();
        for r in oneshots {
            if r.wait_timeout(Duration::from_secs(30)).is_none() {
                return Err("a one-shot response never resolved".into());
            }
        }
        let mut done = 0usize;
        let mut shed = 0usize;
        for s in streams {
            let mut next_step = 0usize;
            let mut terminals = 0usize;
            let mut completed = false;
            loop {
                match s.recv_timeout(Duration::from_secs(30)) {
                    Ok(Some(StreamEvent::Token { step, .. })) => {
                        if step != next_step {
                            return Err(format!(
                                "token step {step}, want {next_step}"));
                        }
                        next_step += 1;
                    }
                    Ok(Some(StreamEvent::Done(stats))) => {
                        terminals += 1;
                        completed = true;
                        if stats.steps != max_steps {
                            return Err(format!(
                                "Done with {} of {max_steps} steps",
                                stats.steps));
                        }
                        if stats.steps != next_step {
                            return Err(format!(
                                "Done says {} steps, client saw \
                                 {next_step} tokens", stats.steps));
                        }
                    }
                    Ok(Some(StreamEvent::Shed(_))) => {
                        terminals += 1;
                    }
                    Ok(None) => break,
                    Err(_) => {
                        return Err("a stream never terminated".into());
                    }
                }
            }
            if terminals != 1 {
                return Err(format!(
                    "{terminals} terminal events on one stream"));
            }
            if completed {
                done += 1;
            } else {
                shed += 1;
            }
        }
        if done + shed != sessions {
            return Err(format!("{done} + {shed} != {sessions}"));
        }
        // a surviving fleet's report must reconcile with the clients
        if let Ok(report) = shutdown_result {
            if report.sessions_started != sessions {
                return Err(format!(
                    "report started {} != {sessions} submitted",
                    report.sessions_started));
            }
            if report.stream_done.len() != done
                || report.stream_shed.len() != shed
            {
                return Err(format!(
                    "report {}/{} vs client {done}/{shed} done/shed",
                    report.stream_done.len(), report.stream_shed.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_arena_pages_recycle_exactly_once_under_racing_terminals() {
    // arena backbone: when every terminal path (worker Done, engine
    // shed, shutdown sweep) races to free the same session's page,
    // exactly one recycle call wins per stored session — no leak, no
    // double-free — and the pool invariant (free + live == slots,
    // enforced by debug_asserts inside the arena) survives arbitrary
    // store/lookup traffic interleaved with the recycling.
    check("arena_recycle_exactly_once", 12, |rng| {
        let pages = 1 + rng.below(12);
        let sessions = 1 + rng.below(24);
        let racers = 2 + rng.below(3);
        let arena = Arc::new(
            elastiformer::coordinator::serving::SessionArena::new(pages));
        for s in 0..sessions as u64 {
            arena.store(s, 1, vec![s as i32]);
        }
        let stored = arena.live(); // <= pages; the rest spilled
        let evicted_before = arena.evicted();
        let wins = Arc::new(AtomicUsize::new(0));
        let mut threads = Vec::new();
        for t in 0..racers {
            let arena = arena.clone();
            let wins = wins.clone();
            threads.push(std::thread::spawn(move || {
                for s in 0..sessions as u64 {
                    // interleave cache traffic with the terminal race
                    if t == 0 && s % 3 == 0 {
                        arena.lookup(s, 1);
                    }
                    if arena.recycle(s) {
                        wins.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }));
        }
        for t in threads {
            t.join().map_err(|_| "racer panicked".to_string())?;
        }
        let won = wins.load(Ordering::SeqCst);
        if won != stored {
            return Err(format!(
                "{won} recycles won for {stored} live pages \
                 ({sessions} sessions, {pages} pages)"));
        }
        if arena.recycled() != stored {
            return Err(format!("recycled counter {} != {stored}",
                               arena.recycled()));
        }
        if arena.live() != 0 {
            return Err(format!("{} pages leaked", arena.live()));
        }
        if arena.evicted() != evicted_before {
            return Err("recycling must never count as eviction".into());
        }
        // the freed pool is fully reusable afterwards
        for s in 0..pages as u64 {
            arena.store(1000 + s, 1, vec![0]);
        }
        if arena.live() != pages {
            return Err(format!("pool shrank to {} of {pages}",
                               arena.live()));
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_with_arena_survives_panics_and_shutdown_races() {
    // arena + engine teardown: decode sessions over a panicking fleet
    // with mid-decode shutdown must still deliver exactly one terminal
    // per stream, and the report's cache counters must reconcile
    // (every hit and miss is a decode-step lookup; a hit can only come
    // from a live arena).  Page leaks and double-frees would trip the
    // arena's internal debug_assert invariants inside the workers,
    // surfacing here as worker panics on every debug-build run.
    check("streaming_arena_teardown", 8, |rng| {
        let sessions = 1 + rng.below(6);
        let max_steps = 1 + rng.below(6);
        let workers = 1 + rng.below(3);
        let pages = rng.below(5); // incl. 0 = disabled arena
        let panic_after = 2 + rng.below(16);
        let executed = Arc::new(AtomicUsize::new(0));
        let spec = SimSpec { batch: 2, seq_len: 8, ..SimSpec::instant() };
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_shards(rng.below(workers + 2))
            .with_arena_pages(pages)
            .with_max_batch_wait(Duration::ZERO);
        let caps = cfg.capacities();
        let factory_counter = executed.clone();
        let engine = ElasticEngine::start(cfg, move |w| {
            if panic_after < 6 {
                // hostile fleet: dies mid-decode
                Ok(Box::new(PanicAfter {
                    executed: factory_counter.clone(),
                    panic_after,
                    batch: 2,
                }) as Box<dyn Executor>)
            } else {
                Ok(Box::new(
                    elastiformer::coordinator::serving::SimExecutor::new(
                        spec, &caps, w).record_log(false))
                    as Box<dyn Executor>)
            }
        })
        .map_err(|e| format!("start failed: {e:#}"))?;
        let streams: Vec<_> = (0..sessions as u64)
            .map(|id| {
                engine.submit_stream(
                    StreamRequest::new(id, vec![1; 4], max_steps))
            })
            .collect();
        // mid-decode shutdown is the norm here, not the exception
        let shutdown_result = engine.shutdown();
        for s in streams {
            let mut terminals = 0usize;
            loop {
                match s.recv_timeout(Duration::from_secs(30)) {
                    Ok(Some(StreamEvent::Token { .. })) => {}
                    Ok(Some(_)) => terminals += 1,
                    Ok(None) => break,
                    Err(_) => {
                        return Err("a stream never terminated".into());
                    }
                }
            }
            if terminals != 1 {
                return Err(format!("{terminals} terminals on a stream"));
            }
        }
        if let Ok(report) = shutdown_result {
            if pages == 0 && report.cache_hits != 0 {
                return Err(format!(
                    "disabled arena reported {} hits",
                    report.cache_hits));
            }
            if report.sessions_started != sessions {
                return Err(format!(
                    "report started {} != {sessions}",
                    report.sessions_started));
            }
        }
        Ok(())
    });
}

/// Executor whose top-tier and draft-tier predictions always disagree
/// (token 0 vs token 1) — the adversarial verifier that rejects every
/// speculative proposal — and which can also panic after a globally
/// shared batch budget, like [`PanicAfter`].
struct RejectingPanicExec {
    executed: Arc<AtomicUsize>,
    panic_after: usize,
    batch: usize,
    top: f32,
}

impl Executor for RejectingPanicExec {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        8
    }
    fn execute(&mut self, tier: f32, _tokens: &[i32])
               -> anyhow::Result<ExecOutput> {
        let k = self.executed.fetch_add(1, Ordering::SeqCst);
        if k >= self.panic_after {
            panic!("injected executor panic at batch {k}");
        }
        let row: [f32; 2] = if tier >= self.top - 1e-6 {
            [1.0, 0.0] // verifier: token 0
        } else {
            [0.0, 1.0] // draft tiers: token 1 — always rejected
        };
        let mut logits = Vec::with_capacity(self.batch * 2);
        for _ in 0..self.batch {
            logits.extend_from_slice(&row);
        }
        Ok(ExecOutput { logits })
    }
}

#[test]
fn prop_speculative_sessions_terminate_exactly_once_under_rejection_and_panics() {
    // speculative backbone: with draft/verify cycles in the pipeline,
    // every stream still observes Token* (strictly ordered from 0)
    // then exactly one terminal then end-of-stream — across fleets
    // that panic after a random batch budget (possibly before the
    // first draft), adversarial verifiers that reject every proposal,
    // random spec_k, random arena sizes (incl. disabled), and
    // mid-decode shutdown.  Page recycling is exercised implicitly:
    // the arena's internal pool invariants (free + live == slots)
    // debug_assert inside the workers, so a draft- or verify-path leak
    // or double-free surfaces here as worker panics on every
    // debug-build run.  On a clean shutdown the session logs AND the
    // speculative ledger (drafted == accepted + rejected) reconcile.
    check("spec_exactly_once", 10, |rng| {
        let sessions = 1 + rng.below(6);
        let max_steps = 1 + rng.below(6);
        let workers = 1 + rng.below(3);
        let batch = 2 + rng.below(6);
        let spec_k = 1 + rng.below(4);
        let panic_after = rng.below(20); // 0 => instant fleet death
        let always_reject = rng.chance(0.5);
        let executed = Arc::new(AtomicUsize::new(0));
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_shards(rng.below(workers + 2))
            .with_queue_bound(1 + rng.below(32))
            .with_arena_pages(rng.below(5)) // incl. 0 = disabled
            .with_spec_k(spec_k)
            .with_max_batch_wait(Duration::ZERO);
        let top = cfg.capacities()[0];
        let counter = executed.clone();
        let engine = if always_reject {
            ElasticEngine::start(cfg, move |_| {
                Ok(Box::new(RejectingPanicExec {
                    executed: counter.clone(),
                    panic_after,
                    batch,
                    top,
                }) as Box<dyn Executor>)
            })
        } else {
            // PanicAfter's single-logit rows argmax to token 0 at every
            // tier, so drafts always agree — the full-accept extreme
            ElasticEngine::start(cfg, move |_| {
                Ok(Box::new(PanicAfter {
                    executed: counter.clone(),
                    panic_after,
                    batch,
                }) as Box<dyn Executor>)
            })
        }
        .map_err(|e| format!("start failed: {e:#}"))?;
        let streams: Vec<_> = (0..sessions as u64)
            .map(|id| {
                engine.submit_stream(
                    StreamRequest::new(id, vec![1; 4], max_steps))
            })
            .collect();
        // mid-decode shutdown races live draft/verify cycles
        let shutdown_result = engine.shutdown();
        let mut done = 0usize;
        let mut shed = 0usize;
        for s in streams {
            let mut next_step = 0usize;
            let mut terminals = 0usize;
            let mut completed = false;
            loop {
                match s.recv_timeout(Duration::from_secs(30)) {
                    Ok(Some(StreamEvent::Token { step, .. })) => {
                        if step != next_step {
                            return Err(format!(
                                "token step {step}, want {next_step}"));
                        }
                        next_step += 1;
                    }
                    Ok(Some(StreamEvent::Done(stats))) => {
                        terminals += 1;
                        completed = true;
                        if stats.steps != max_steps
                            || stats.steps != next_step
                        {
                            return Err(format!(
                                "Done says {} steps, budget {max_steps}, \
                                 client saw {next_step}", stats.steps));
                        }
                    }
                    Ok(Some(StreamEvent::Shed(_))) => {
                        terminals += 1;
                    }
                    Ok(None) => break,
                    Err(_) => {
                        return Err("a stream never terminated".into());
                    }
                }
            }
            if terminals != 1 {
                return Err(format!(
                    "{terminals} terminal events on one stream"));
            }
            if completed {
                done += 1;
            } else {
                shed += 1;
            }
        }
        if done + shed != sessions {
            return Err(format!("{done} + {shed} != {sessions}"));
        }
        // a surviving fleet's report must reconcile with the clients
        // AND with itself
        if let Ok(report) = shutdown_result {
            if report.sessions_started != sessions {
                return Err(format!(
                    "report started {} != {sessions} submitted",
                    report.sessions_started));
            }
            if report.stream_done.len() != done
                || report.stream_shed.len() != shed
            {
                return Err(format!(
                    "report {}/{} vs client {done}/{shed} done/shed",
                    report.stream_done.len(), report.stream_shed.len()));
            }
            if report.spec_drafted
                != report.spec_accepted + report.spec_rejected
            {
                return Err(format!(
                    "speculative ledger broken: {} drafted != {} \
                     accepted + {} rejected", report.spec_drafted,
                    report.spec_accepted, report.spec_rejected));
            }
            if always_reject && report.spec_accepted != 0 {
                return Err(format!(
                    "always-rejecting verifier accepted {} drafts",
                    report.spec_accepted));
            }
            for sec in report.spec_sections() {
                if sec.drafted != sec.accepted + sec.rejected {
                    return Err(format!(
                        "class {} section ledger broken", sec.class));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_no_request_lost_under_chaos() {
    // fault-layer backbone: across chaos-injected sim fleets (random
    // transient/fatal/spike plans with tier skew) and hostile
    // PanicAfter fleets with tiny restart budgets, plus mid-run
    // shutdown racing live retry ladders and draft/verify cycles,
    // every submit still resolves exactly once and every stream still
    // terminates in exactly one terminal event.  Injected faults are
    // supervised — shutdown itself must stay Ok — and on the report
    // the speculative ledger reconciles, a clean plan leaves no fault
    // sections, and in the PanicAfter arm (where abnormal exits are
    // exactly countable from the shared batch counter) the respawn
    // counter equals min(abnormal exits, restart budget), with the
    // budget-exhausted breadcrumb recorded whenever exits overran it.
    check("no_request_lost_under_chaos", 10, |rng| {
        let n = 1 + rng.below(40);
        let sessions = rng.below(5);
        let max_steps = 1 + rng.below(4);
        let workers = 1 + rng.below(3);
        let batch = 1 + rng.below(4);
        let hostile = rng.chance(0.5);
        let panic_after = rng.below(24); // 0 => instant fleet death
        let budget = rng.below(4); // incl. 0 = no respawns allowed
        let executed = Arc::new(AtomicUsize::new(0));
        let policy = FaultPolicy::default()
            .with_backoff_ms(0)
            .with_restart_budget(if hostile { budget } else { 32 });
        let fault = FaultPlan {
            fail_p: if rng.chance(0.25) { 0.0 } else { rng.f64() * 0.25 },
            fatal_p: if rng.chance(0.5) { 0.0 } else { rng.f64() * 0.04 },
            spike_p: rng.f64() * 0.2,
            spike_ms: rng.f64() * 2.0,
            tier_bias: rng.f64() * 0.5,
            poison_token: 0,
        };
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_shards(rng.below(workers + 2))
            .with_queue_bound(1 + rng.below(32))
            .with_spec_k(1 + rng.below(3))
            .with_fault_policy(policy)
            .with_max_batch_wait(Duration::ZERO);
        let caps = cfg.capacities();
        let engine = if hostile {
            let counter = executed.clone();
            ElasticEngine::start(cfg, move |_| {
                Ok(Box::new(PanicAfter {
                    executed: counter.clone(),
                    panic_after,
                    batch,
                }) as Box<dyn Executor>)
            })
        } else {
            let spec =
                SimSpec { batch, seq_len: 8, fault, ..SimSpec::instant() };
            ElasticEngine::start(cfg, sim::factory(spec, caps))
        }
        .map_err(|e| format!("start failed: {e:#}"))?;
        let responses: Vec<Response> = (0..n as u64)
            .map(|id| engine.submit(sim_request(id, vec![1; 8])))
            .collect();
        let streams: Vec<_> = (0..sessions as u64)
            .map(|id| {
                engine.submit_stream(
                    StreamRequest::new(1000 + id, vec![1; 4], max_steps))
            })
            .collect();
        // mid-run shutdown: the close races live retries and respawns
        let shutdown_result = engine.shutdown();
        let mut served = 0usize;
        for r in responses {
            match r.wait_timeout(Duration::from_secs(30)) {
                Some(Ok(_)) => served += 1,
                Some(Err(_)) => {} // shed/quarantined/failed: resolved
                None => return Err("a response never resolved".into()),
            }
        }
        for s in streams {
            let mut terminals = 0usize;
            loop {
                match s.recv_timeout(Duration::from_secs(30)) {
                    Ok(Some(StreamEvent::Token { .. })) => {}
                    Ok(Some(_)) => terminals += 1,
                    Ok(None) => break,
                    Err(_) => {
                        return Err("a stream never terminated".into());
                    }
                }
            }
            if terminals != 1 {
                return Err(format!(
                    "{terminals} terminal events on one stream"));
            }
        }
        // injected faults are supervised: never a join-level panic
        let report = shutdown_result
            .map_err(|e| format!("shutdown errored: {e:#}"))?;
        if report.completions.len() != served {
            return Err(format!("report says {} served, callers saw {served}",
                               report.completions.len()));
        }
        if report.spec_drafted
            != report.spec_accepted + report.spec_rejected
        {
            return Err(format!(
                "speculative ledger broken: {} drafted != {} accepted \
                 + {} rejected", report.spec_drafted,
                report.spec_accepted, report.spec_rejected));
        }
        let respawns: usize =
            report.fault_sections().iter().map(|s| s.respawns).sum();
        if hostile {
            // every execute bumped the shared counter before deciding
            // to panic, so calls past the threshold are exactly the
            // abnormal exits — and each one spends one respawn attempt
            let exits = executed
                .load(Ordering::SeqCst)
                .saturating_sub(panic_after);
            if respawns != exits.min(budget) {
                return Err(format!(
                    "{respawns} respawns for {exits} abnormal exits \
                     under budget {budget}"));
            }
            if exits > budget
                && !report
                    .worker_errors
                    .iter()
                    .any(|e| e.contains("restart budget exhausted"))
            {
                return Err(
                    "budget overrun left no breadcrumb in \
                     worker_errors".into());
            }
        } else if fault.fail_p == 0.0 && fault.fatal_p == 0.0 {
            // spikes are latency, not faults: a clean plan must leave
            // the fault ledger empty
            if !report.fault_sections().is_empty() {
                return Err(format!(
                    "clean fault plan produced fault sections: {:?}",
                    report.fault_sections()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tracing_changes_nothing_and_loses_nothing() {
    // flight-recorder backbone: the recorder is observer-only and its
    // ledger is exact.  Clean arm: the same seeded workload runs
    // traced and untraced — the served sets must be identical, so
    // turning tracing on changes nothing the caller can see.  Hostile
    // arm: a PanicAfter fleet is shut down with work still in flight,
    // so the close races live emission sites.  Both arms, any ring
    // capacity (tiny rings overflow on purpose): after drain,
    // dropped + exported == emitted, and when nothing was dropped the
    // exported stream reconciles with the engine's own accounting —
    // one admit per admission, each with a unique nonzero trace id,
    // and exactly one terminal per admit.
    #[allow(clippy::too_many_arguments)]
    fn run(trace_capacity: usize, n: usize, sessions: usize,
           max_steps: usize, workers: usize, batch: usize,
           hostile: bool, panic_after: usize, seed: u64)
           -> Result<(ServeReport,
                      Option<(Vec<Stamped>, TraceCounts)>), String> {
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_spec_k(2)
            .with_trace_capacity(trace_capacity)
            .with_fault_policy(FaultPolicy::default()
                .with_backoff_ms(0)
                .with_restart_budget(4))
            .with_max_batch_wait(Duration::ZERO);
        let caps = cfg.capacities();
        let engine = if hostile {
            let counter = Arc::new(AtomicUsize::new(0));
            ElasticEngine::start(cfg, move |_| {
                Ok(Box::new(PanicAfter {
                    executed: counter.clone(),
                    panic_after,
                    batch,
                }) as Box<dyn Executor>)
            })
        } else {
            let spec =
                SimSpec { batch, seq_len: 8, seed, ..SimSpec::instant() };
            ElasticEngine::start(cfg, sim::factory(spec, caps))
        }
        .map_err(|e| format!("start failed: {e:#}"))?;
        let recorder = engine.trace_recorder();
        if (trace_capacity == 0) != recorder.is_none() {
            return Err("recorder presence does not track the \
                        configured capacity"
                .into());
        }
        let responses: Vec<Response> = (0..n as u64)
            .map(|id| engine.submit(sim_request(id, vec![1; 8])))
            .collect();
        let streams: Vec<_> = (0..sessions as u64)
            .map(|id| {
                engine.submit_stream(
                    StreamRequest::new(1000 + id, vec![1; 4], max_steps))
            })
            .collect();
        // hostile arm: close first, racing retries, respawns and any
        // in-flight emission; clean arm: drain everything first so the
        // served set is the full deterministic set
        let mut engine = Some(engine);
        let early_shutdown =
            if hostile { Some(engine.take().unwrap().shutdown()) }
            else { None };
        for r in responses {
            match r.wait_timeout(Duration::from_secs(30)) {
                Some(_) => {}
                None => return Err("a response never resolved".into()),
            }
        }
        for s in streams {
            let mut terminals = 0usize;
            loop {
                match s.recv_timeout(Duration::from_secs(30)) {
                    Ok(Some(StreamEvent::Token { .. })) => {}
                    Ok(Some(_)) => terminals += 1,
                    Ok(None) => break,
                    Err(_) => {
                        return Err("a stream never terminated".into());
                    }
                }
            }
            if terminals != 1 {
                return Err(format!(
                    "{terminals} terminal events on one stream"));
            }
        }
        let report = match early_shutdown {
            Some(r) => r,
            None => engine.take().unwrap().shutdown(),
        }
        .map_err(|e| format!("shutdown errored: {e:#}"))?;
        // drain only now: workers are joined, the ledger is quiescent
        let drained =
            recorder.map(|rec| (rec.drain(), rec.counts()));
        Ok((report, drained))
    }

    check("tracing_changes_nothing", 10, |rng| {
        let n = 1 + rng.below(32);
        let sessions = rng.below(4);
        let max_steps = 1 + rng.below(4);
        let workers = 1 + rng.below(3);
        let batch = 1 + rng.below(4);
        let hostile = rng.chance(0.3);
        let panic_after = rng.below(16); // 0 => instant fleet death
        // half the time a ring small enough that overflow is certain,
        // half the time one big enough that nothing may drop
        let capacity =
            if rng.chance(0.5) { 1 + rng.below(8) } else { 1 << 12 };
        let seed = rng.next_u64();
        let (traced, drained) = run(capacity, n, sessions, max_steps,
                                    workers, batch, hostile,
                                    panic_after, seed)?;
        let (events, counts) =
            drained.ok_or("traced run lost its recorder")?;
        if counts.dropped + counts.exported != counts.emitted {
            return Err(format!("ledger broken: {counts:?}"));
        }
        if counts.exported != events.len() as u64 {
            return Err(format!("{} exported != {} drained",
                               counts.exported, events.len()));
        }
        if counts.dropped == 0 {
            let admits: Vec<u64> = events
                .iter()
                .filter(|e| e.kind() == "admit")
                .map(|e| e.trace_id)
                .collect();
            if admits.len() != n + sessions {
                return Err(format!("{} admit events for {} admissions",
                                   admits.len(), n + sessions));
            }
            if admits.iter().any(|&id| id == 0) {
                return Err("an admit carried trace id 0".into());
            }
            let mut uniq = admits.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() != admits.len() {
                return Err("duplicate trace ids across admits".into());
            }
            let terminals =
                events.iter().filter(|e| e.kind() == "terminal").count();
            if terminals != n + sessions {
                return Err(format!(
                    "{terminals} terminal events for {} admissions",
                    n + sessions));
            }
        }
        if !hostile {
            let (untraced, none) = run(0, n, sessions, max_steps,
                                       workers, batch, hostile,
                                       panic_after, seed)?;
            if none.is_some() {
                return Err("capacity 0 still built a recorder".into());
            }
            let mut a: Vec<u64> =
                traced.completions.iter().map(|c| c.id).collect();
            let mut b: Vec<u64> =
                untraced.completions.iter().map(|c| c.id).collect();
            a.sort_unstable();
            b.sort_unstable();
            if a != b {
                return Err(format!(
                    "traced run served {} requests, untraced {}",
                    a.len(), b.len()));
            }
            if a != (0..n as u64).collect::<Vec<_>>() {
                return Err("a clean run must serve every submission"
                    .into());
            }
            if traced.stream_done.len() != sessions
                || untraced.stream_done.len() != sessions
            {
                return Err("a clean run must complete every session"
                    .into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_affine_requeue_into_a_closed_queue_fails_fast() {
    // teardown-safety for placement affinity: once the queue is
    // closed, concurrent `requeue_to`/`push_pinned` calls from many
    // threads must all return Err promptly (no deadlock, no hang), the
    // item must come back to the caller, and the depth gauge must
    // account only the items actually deposited before the close.
    check("affine_requeue_closed", 15, |rng| {
        let shards = 1 + rng.below(4);
        let bound = 1 + rng.below(16);
        let q = Arc::new(AdmissionQueue::sharded(bound, shards));
        let pre = rng.below(bound.min(4));
        for i in 0..pre as u64 {
            q.push_pinned(rng.below(shards * 2), i, false)
                .map_err(|_| "pinned push rejected while open")?;
        }
        q.close();
        let mut threads = Vec::new();
        for t in 0..3u64 {
            let q = q.clone();
            let shard = rng.below(shards * 2);
            threads.push(std::thread::spawn(move || {
                for i in 0..16u64 {
                    let item = 1000 + t * 100 + i;
                    match q.requeue_to(shard, item, i % 2 == 0) {
                        Ok(_) => return Err(format!(
                            "closed queue accepted requeue of {item}")),
                        Err(back) => {
                            if back != item {
                                return Err(format!(
                                    "lost item: sent {item}, got {back}"));
                            }
                        }
                    }
                    if q.push_pinned(shard, item, false).is_ok() {
                        return Err(
                            "closed queue accepted a pinned push".into());
                    }
                }
                Ok(())
            }));
        }
        for t in threads {
            t.join().map_err(|_| "requeue thread hung or panicked")??;
        }
        // the pre-close deposits are still drainable, nothing else is
        let drained = q.pop_batch(64, Duration::ZERO).len();
        if drained != pre {
            return Err(format!("drained {drained}, deposited {pre}"));
        }
        if q.len() != 0 {
            return Err(format!("depth gauge stuck at {}", q.len()));
        }
        Ok(())
    });
}

/// Executor that fails any batch whose rows carry different floor-rung
/// markers — the hostile probe for class-aware batch formation.  Each
/// request's token row is its rung index replicated, and padded rows
/// repeat the last real row, so the full tensor is uniform iff the real
/// rows are.  It also re-checks that the tier served honours the
/// batch's floor end to end.
struct FloorMarkerExec {
    batch: usize,
    seq_len: usize,
    caps: Vec<f32>,
}

impl Executor for FloorMarkerExec {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn execute(&mut self, tier: f32, tokens: &[i32])
               -> anyhow::Result<ExecOutput> {
        let marker = tokens[0];
        for row in 0..self.batch {
            let m = tokens[row * self.seq_len];
            anyhow::ensure!(
                m == marker,
                "batch mixes floor rungs: row 0 = {marker}, row {row} = {m}");
        }
        let rung = marker as usize;
        anyhow::ensure!(rung < self.caps.len(), "bad rung marker {marker}");
        anyhow::ensure!(
            tier + 1e-6 >= self.caps[rung],
            "tier {tier} below the batch floor rung {rung} \
             (cap {})", self.caps[rung]);
        Ok(ExecOutput { logits: vec![tier; self.batch] })
    }
}

#[test]
fn prop_class_aware_batches_never_mix_floors() {
    // acceptance invariant for class-aware batch formation: across
    // random request mixes (floors drawn from the ladder plus 0.0
    // best-effort), worker counts, batch sizes and queue bounds, no
    // executed batch ever mixes incompatible floor rungs — checked by
    // an executor that rejects mixed batches outright — and every
    // request is still served (nothing starves in a class ghetto)
    check("class_aware_batching", 15, |rng| {
        let n = 1 + rng.below(60);
        let workers = 1 + rng.below(3);
        let batch = 2 + rng.below(5);
        let seq_len = 4usize;
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_bound(1 + rng.below(48))
            .with_max_batch_wait(Duration::from_micros(200));
        let caps = cfg.capacities(); // [1.0, 0.75, 0.5, 0.25]
        let ladder = caps.clone();
        let engine = ElasticEngine::start(cfg, move |_| {
            Ok(Box::new(FloorMarkerExec {
                batch,
                seq_len,
                caps: ladder.clone(),
            }) as Box<dyn Executor>)
        })
        .map_err(|e| format!("start failed: {e:#}"))?;
        let floors = [0.0f32, 0.25, 0.5, 0.75, 1.0];
        let mut responses = Vec::new();
        for id in 0..n as u64 {
            let floor = floors[rng.below(floors.len())];
            // the marker token is the rung the floor clamps to, so
            // every row of a formed batch exposes its request's class
            let rung = floor_rung(&caps, floor) as i32;
            let slo = SloClass::named(&format!("floor{floor}"))
                .with_floor_tier(floor);
            let req =
                Request::new(id, vec![rung; seq_len]).with_slo(slo);
            responses.push(engine.submit(req));
        }
        for r in responses {
            match r.wait_timeout(Duration::from_secs(30)) {
                Some(Ok(_)) => {}
                Some(Err(e)) => {
                    return Err(format!(
                        "request rejected (mixed batch?): {e}"));
                }
                None => return Err("response never resolved".into()),
            }
        }
        let report = engine
            .shutdown()
            .map_err(|e| format!("engine failed: {e:#}"))?;
        if report.completions.len() != n {
            return Err(format!("{} of {n} served", report.completions.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_controller_configured_tiers_and_ewma_convergence() {
    check("controller_converges", 50, |rng| {
        let k = 1 + rng.below(5);
        let mut tiers: Vec<f32> =
            (0..k).map(|_| (1 + rng.below(100)) as f32 / 100.0).collect();
        let mut c = CapacityController::new(
            tiers.clone(), 0.5 + rng.f64() * 8.0);
        tiers.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // pure map: monotone non-increasing, always a configured tier
        let mut depth = 0.0f64;
        let mut prev = f32::INFINITY;
        for _ in 0..40 {
            depth += rng.f64() * 4.0;
            let t = c.tier_for_depth(depth);
            if !tiers.contains(&t) {
                return Err(format!("tier {t} not configured: {tiers:?}"));
            }
            if t > prev + 1e-9 {
                return Err(format!("tier rose at depth {depth}"));
            }
            prev = t;
        }
        // stateful path also stays within the ladder
        for _ in 0..30 {
            let t = c.choose(rng.below(100));
            if !tiers.contains(&t) {
                return Err(format!("choose gave {t} not in {tiers:?}"));
            }
        }
        // after the queue empties the EWMA decays back to the top tier
        for _ in 0..64 {
            c.choose(0);
        }
        if c.choose(0) != c.top_tier() {
            return Err(format!("no convergence: ewma {}",
                               c.smoothed_depth()));
        }
        Ok(())
    });
}

#[test]
fn prop_tokenizer_roundtrip_and_padding() {
    check("tokenizer_roundtrip", 80, |rng| {
        let tok = Tokenizer::new();
        let len = 4 + rng.below(60);
        let n_chars = rng.below(100);
        let s: String = (0..n_chars)
            .map(|_| (rng.range(32, 126) as u8) as char)
            .collect();
        if tok.decode(&tok.encode(&s)) != s {
            return Err(format!("roundtrip failed for {s:?}"));
        }
        let padded = tok.encode_padded(&s, len);
        if padded.len() != len {
            return Err(format!("padded len {} != {len}", padded.len()));
        }
        if padded[0] != elastiformer::data::tokenizer::BOS {
            return Err("missing BOS".into());
        }
        if !padded.contains(&elastiformer::data::tokenizer::EOS) {
            return Err("missing EOS".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.range(-1_000_000, 1_000_000) as f64) / 64.0),
            3 => {
                let n = rng.below(12);
                Value::Str(
                    (0..n).map(|_| (rng.range(32, 126) as u8) as char).collect())
            }
            4 => Value::Arr(
                (0..rng.below(5)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect()),
        }
    }
    check("json_roundtrip", 80, |rng| {
        let v = random_value(rng, 0);
        let s = json::to_string(&v);
        let v2 = json::parse(&s).map_err(|e| format!("parse failed: {e}"))?;
        if v != v2 {
            return Err(format!("roundtrip mismatch: {s}"));
        }
        let sp = json::to_string_pretty(&v);
        let v3 = json::parse(&sp).map_err(|e| format!("pretty parse: {e}"))?;
        if v != v3 {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_checkpoint_roundtrip_random() {
    check("checkpoint_roundtrip", 30, |rng| {
        let n = rng.below(5000);
        let params: Vec<f32> = (0..n).map(|_| rng.gaussian_f32(1.0)).collect();
        let ck = Checkpoint::new("cfg", "kind", rng.next_u64(), params);
        let path = std::env::temp_dir()
            .join(format!("efck_prop_{}.bin", rng.next_u64()));
        ck.save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        if back != ck {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_lr_schedule_bounded_and_finite() {
    check("lr_schedule", 60, |rng| {
        let total = 1 + rng.below(2000);
        let base = 10f64.powf(-(1.0 + rng.f64() * 4.0));
        let s = LrSchedule::cosine(base, total);
        for step in 0..total + 10 {
            let lr = s.at(step);
            if !lr.is_finite() || lr <= 0.0 || lr > base * 1.0001 {
                return Err(format!("lr {lr} out of (0, {base}] at {step}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bootstrap_ci_orders_and_brackets() {
    check("bootstrap_ci", 40, |rng| {
        let n = 2 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian() * 3.0).collect();
        let (mean, lo, hi) = bootstrap_ci(&xs, 100, 0.95, rng.next_u64());
        if !(lo <= hi) {
            return Err(format!("lo {lo} > hi {hi}"));
        }
        if mean < lo - 3.0 || mean > hi + 3.0 {
            return Err(format!("mean {mean} far outside [{lo}, {hi}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_caption_scores_in_range_and_truth_scores_perfectly() {
    check("caption_scores", 60, |rng| {
        let class = rng.below(imagen::NUM_CLASSES);
        let (_, scene) = imagen::gen_image(rng, class, 8);
        let cap = capgen::caption(&scene, rng);
        let sc = capgen::score_caption(&cap, &scene);
        if sc.recall != 1.0 || sc.hallucination != 0.0 {
            return Err(format!("truth caption scored {sc:?}: {cap}"));
        }
        // arbitrary text stays in range
        let junk: String = (0..rng.below(40))
            .map(|_| (rng.range(97, 122) as u8) as char)
            .collect();
        let sj = capgen::score_caption(&junk, &scene);
        if !(0.0..=1.0).contains(&sj.recall)
            || !(0.0..=1.0).contains(&sj.hallucination) {
            return Err(format!("junk caption out of range {sj:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_imagen_images_bounded_and_class_deterministic() {
    check("imagen_bounds", 40, |rng| {
        let class = rng.below(imagen::NUM_CLASSES);
        let size = 8 + rng.below(3) * 8;
        let (img, scene) = imagen::gen_image(rng, class, size);
        if img.len() != size * size * 3 {
            return Err("bad size".into());
        }
        if img.iter().any(|&p| !(0.0..=1.0).contains(&p)) {
            return Err("pixel out of [0,1]".into());
        }
        let again = imagen::render(&scene, size);
        if again != img {
            return Err("render not pure".into());
        }
        Ok(())
    });
}
