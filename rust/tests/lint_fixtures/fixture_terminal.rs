//! Fixture (not compiled): `StreamEvent::Done`/`Shed` constructed
//! outside the channel module must be flagged by rule
//! `terminal-outside-channel`.

pub fn finish(sender: &StreamSender, stats: StreamStats) {
    sender.terminate(StreamEvent::Done(stats));
}

pub fn kill(sender: &StreamSender, err: ServeError) {
    sender.terminate(StreamEvent::Shed(err));
}
