//! Fixture (not compiled): a lock guard live across an executor call
//! must be flagged by rule `guard-across-execute`; dropping the guard
//! first is clean.

pub fn held_across(exec: &mut dyn Executor, log: &RankedMutex<Vec<u32>>) {
    let mut held = log.lock();
    let out = exec.execute(1.0, &[0; 4]);
    held.push(out.unwrap().logits.len() as u32);
}

pub fn dropped_first(exec: &mut dyn Executor, log: &RankedMutex<Vec<u32>>) {
    let held = log.lock();
    drop(held);
    let _ = exec.execute(1.0, &[0; 4]);
}

pub fn scoped_out(exec: &mut dyn Executor, log: &RankedMutex<Vec<u32>>) {
    {
        let _held = log.lock();
    }
    let _ = exec.execute(1.0, &[0; 4]);
}
