//! Fixture (not compiled): `lint: allow` escapes — a live escape
//! suppresses its finding and is inventoried; a stale or
//! unknown-rule escape is itself a finding (rule `stale-allow`).

use std::sync::Mutex; // lint: allow(raw-mutex) — fixture: a live escape

// lint: allow(raw-mutex) — stale: the next code line is clean
pub fn clean() -> u32 {
    7
}

// lint: allow(no-such-rule) — names a rule that does not exist
pub fn also_clean() -> u32 {
    8
}
