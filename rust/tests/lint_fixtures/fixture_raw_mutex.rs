//! Fixture (not compiled): raw `std::sync` lock types in serving
//! scope must be flagged by rule `raw-mutex`.

use std::sync::Mutex;

pub struct RawHolder {
    slots: Mutex<Vec<u32>>,
}

impl RawHolder {
    pub fn push(&self, v: u32) {
        self.slots.lock().unwrap().push(v);
    }
}
