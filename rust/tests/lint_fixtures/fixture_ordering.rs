//! Fixture (not compiled): an atomic `Ordering` variant named in a
//! serving file is checked against that file's allowlist row (rule
//! `ordering-allowlist`); a file with no row fails outright.

pub fn bump(counter: &std::sync::atomic::AtomicUsize) -> usize {
    use std::sync::atomic::Ordering;
    counter.fetch_add(1, Ordering::SeqCst)
}
