//! Fixture (not compiled): `TraceEvent` constructed outside the
//! recorder module must be flagged by rule `trace-confined` —
//! emission goes through the `TraceRecorder` methods only.

pub fn sneak_admit(lane: &mut VecDeque<Stamped>, id: u64) {
    lane.push_back(Stamped { tick_us: 0, event: TraceEvent::Admit { trace_id: id } });
}

pub fn sneak_terminal(lane: &mut VecDeque<Stamped>, id: u64) {
    let event = TraceEvent::Terminal { trace_id: id, cause: "smuggled" };
    lane.push_back(Stamped { tick_us: 0, event });
}
