//! Hermetic perf-trajectory gate: drives the same sim-pipeline
//! comparison as the `hotpath` bench — the pre-sharding shared
//! single-deque admission queue vs the sharded work-stealing queue at
//! 4 workers under a near-zero-latency `SimSpec` (host overhead
//! dominates), plus a heterogeneous fast/slow two-class topology
//! (per-worker-class capacity controllers), a streaming decode point
//! (concurrent sessions through `submit_stream`, tokens/s), and a
//! speculative decode point (draft/verify cycles — accept rate and
//! tokens-per-admission), and a flight-recorder point (tracing on,
//! traced/untraced throughput ratio) — and writes the machine-readable
//! `BENCH_serving.json` at the repo root, so every tier-1 `cargo
//! test` run refreshes the perf record even where `cargo bench` never
//! runs.
//!
//! Debug-build timings on shared CI runners are noisy, so this test
//! asserts *structure* (exactly-once service under both topologies, a
//! parseable record with a finite ratio): the ratio itself is recorded
//! rather than gated here.  The release-mode bench row (CI `bench-smoke`
//! job, or a local `cargo bench --bench hotpath`) is the number the
//! ">= 1.5x sharded over shared at 4 workers" acceptance target is
//! judged by.

use std::path::Path;

use elastiformer::coordinator::serving::sim::{self, BenchRow};
use elastiformer::coordinator::serving::{FaultPlan, SimSpec};
use elastiformer::json;

#[test]
fn bench_gate_records_shared_vs_sharded_pipeline() {
    let n = 1024usize;
    let workers = 4usize;
    let spec = SimSpec {
        base_ms: 0.05,
        ms_per_capacity: 0.05,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let mut rows: Vec<BenchRow> = Vec::new();
    for (label, shards) in [("shared", 1usize), ("sharded", workers)] {
        let report = sim::pipeline_point(spec, workers, shards, n)
            .unwrap_or_else(|e| panic!("{label} pipeline failed: {e:#}"));
        assert_eq!(report.completions.len(), n, "{label}: requests lost");
        let mut ids: Vec<u64> =
            report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(),
                   "{label}: dropped or duplicated requests");
        rows.push(BenchRow { queue: label, workers, shards,
                             classes: String::new(), fault_rate: 0.0,
                             submitted: 0, trace_overhead: 0.0,
                             report });
    }
    // heterogeneous topology: 2 fast + 2 slow (4x latency) workers,
    // one capacity controller per class — the mixed-fleet perf record
    let slow = SimSpec {
        base_ms: spec.base_ms * 4.0,
        ms_per_capacity: spec.ms_per_capacity * 4.0,
        ..spec
    };
    let hetero = sim::pipeline_point_classes(
        &[("fast", spec, 2), ("slow", slow, 2)], workers, n)
        .unwrap_or_else(|e| panic!("hetero pipeline failed: {e:#}"));
    assert_eq!(hetero.completions.len(), n, "hetero: requests lost");
    let mut ids: Vec<u64> =
        hetero.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(),
               "hetero: dropped or duplicated requests");
    assert_eq!(hetero.worker_classes.len(), 2,
               "hetero report must carry both worker classes");
    rows.push(BenchRow { queue: "hetero", workers, shards: workers,
                         classes: "fast=2:slow=2".into(),
                         fault_rate: 0.0, submitted: 0,
                         trace_overhead: 0.0, report: hetero });
    // streaming decode row: concurrent sessions through submit_stream,
    // every token a re-admitted decode step (continuous batching).
    // streaming_point itself asserts every session completes and the
    // session logs reconcile (started == done + shed).
    let (sessions, decode_steps) = (32usize, 8usize);
    // nonzero window-modeling so the recorded tokens/s reflects the
    // arena's O(1)-vs-O(seq_len) row-preparation saving
    let stream_spec =
        SimSpec { recompute_ms_per_token: 0.002, ..spec };
    let streaming =
        sim::streaming_point(stream_spec, workers, workers, sessions,
                             decode_steps)
            .unwrap_or_else(|e| panic!("streaming pipeline failed: {e:#}"));
    assert_eq!(streaming.stream_done.len(), sessions,
               "streaming: sessions lost");
    assert!(streaming.stream_done.iter().all(
        |s| s.steps == decode_steps && s.tiers.len() == decode_steps),
            "streaming: truncated tier trajectories");
    assert!(streaming.tokens_per_s() > 0.0);
    assert!(streaming.cache_hits > 0,
            "the default session arena must serve some decode rows");
    rows.push(BenchRow { queue: "streaming", workers, shards: workers,
                         classes: String::new(), fault_rate: 0.0,
                         submitted: 0, trace_overhead: 0.0,
                         report: streaming });
    // speculative decode row: sessions draft at the cheapest floored
    // tier and verify at the top tier; speculative_point itself
    // asserts the ledger reconciles (drafted == accepted + rejected).
    // Mild divergence keeps the accept rate strictly below 1 while
    // the admission economy stays above plain decode's 1.0.
    let spec_stream =
        SimSpec { divergence: 0.05, ..stream_spec };
    let speculative =
        sim::speculative_point(spec_stream, workers, workers, sessions,
                               decode_steps, 4)
            .unwrap_or_else(|e| {
                panic!("speculative pipeline failed: {e:#}")
            });
    assert_eq!(speculative.stream_done.len(), sessions,
               "speculative: sessions lost");
    assert!(speculative.spec_drafted > 0,
            "speculative point must draft");
    assert!(speculative.spec_accept_rate() > 0.0,
            "mild divergence must still accept most drafts");
    assert!(speculative.tokens_per_admission() > 1.0,
            "speculative decode must beat the one-token-per-item \
             plain economy, got {}",
            speculative.tokens_per_admission());
    rows.push(BenchRow { queue: "speculative", workers, shards: workers,
                         classes: String::new(), fault_rate: 0.0,
                         submitted: 0, trace_overhead: 0.0,
                         report: speculative });
    // chaos row: the same speculative workload under a seeded fault
    // plan — 10% transient failures skewed toward cheap tiers plus one
    // always-poisoned request — records availability and the
    // fault-ladder economy (retries, bisections, quarantines).
    // faults_point itself asserts that only the poison request is
    // quarantined and every session completes its full budget.
    let fault_rate = 0.1;
    let fault_spec = SimSpec {
        fault: FaultPlan {
            fail_p: fault_rate,
            tier_bias: 0.5,
            poison_token: 661,
            ..FaultPlan::default()
        },
        ..spec_stream
    };
    let (fn_oneshots, fn_sessions) = (128usize, 8usize);
    let faults = sim::faults_point(fault_spec, workers, workers,
                                   fn_oneshots, fn_sessions,
                                   decode_steps, 4)
        .unwrap_or_else(|e| panic!("chaos pipeline failed: {e:#}"));
    // the poison one-shot is shed, everything else must survive
    assert_eq!(faults.completions.len(), fn_oneshots - 1,
               "faults: non-poison requests lost");
    assert_eq!(faults.stream_done.len(), fn_sessions,
               "faults: sessions lost");
    assert!(!faults.fault_sections().is_empty(),
            "chaos run must record fault-ladder activity");
    rows.push(BenchRow { queue: "faults", workers, shards: workers,
                         classes: String::new(), fault_rate,
                         submitted: fn_oneshots + fn_sessions,
                         trace_overhead: 0.0, report: faults });
    // flight-recorder row: the one-shot load with tracing on, as a
    // ratio over the untraced sharded baseline recorded above.
    // traced_point itself asserts the ledger reconciles
    // (dropped + exported == emitted); here we assert the run stays
    // lossless and the recorded ratio is a sane number (the release
    // bench judges the "near 1.0" overhead claim — debug timings on
    // shared runners are too noisy to gate).
    let untraced_rps = rows[1].report.throughput_rps();
    let (traced, events, counts) =
        sim::traced_point(spec, workers, workers, n, 0, 0, 0, 1 << 16)
            .unwrap_or_else(|e| panic!("traced pipeline failed: {e:#}"));
    assert_eq!(traced.completions.len(), n, "traced: requests lost");
    assert_eq!(counts.dropped, 0,
               "a 64Ki ring must hold this run's events");
    assert!(!events.is_empty(), "traced run must export events");
    let trace_overhead = traced.throughput_rps() / untraced_rps;
    assert!(trace_overhead.is_finite() && trace_overhead > 0.0,
            "nonsense trace overhead ratio {trace_overhead}");
    rows.push(BenchRow { queue: "trace", workers, shards: workers,
                         classes: String::new(), fault_rate: 0.0,
                         submitted: 0, trace_overhead,
                         report: traced });
    let path = Path::new(
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json"));
    // never stomp an authoritative release-mode record with debug
    // numbers: refresh the file only when it holds the committed seed
    // or a previous debug refresh
    let keep_existing = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| json::parse(&t).ok())
        .and_then(|d| {
            d.req("source").ok().and_then(|s| {
                s.as_str().ok().map(|s| s.contains("(release)"))
            })
        })
        .unwrap_or(false);
    if keep_existing {
        println!("BENCH_serving.json holds a release-mode record; \
                  leaving it in place");
    } else {
        sim::write_bench_json(path, "tests/bench_gate.rs (debug)", spec,
                              n, &rows)
            .expect("BENCH_serving.json must be writable at the repo root");
        // the record must be parseable and carry the 4-worker ratio
        let text = std::fs::read_to_string(path).unwrap();
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.req("bench").unwrap().as_str().unwrap(),
                   "sim_pipeline");
        let results = doc.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 7);
        let trace_row = results
            .iter()
            .find(|r| {
                r.req("queue")
                    .ok()
                    .and_then(|q| q.as_str().ok())
                    .is_some_and(|q| q == "trace")
            })
            .expect("record must carry the flight-recorder row");
        let overhead = trace_row
            .req("trace_overhead").unwrap()
            .as_f64().unwrap();
        assert!(overhead.is_finite() && overhead > 0.0,
                "nonsense recorded trace overhead {overhead}");
        let streaming_row = results
            .iter()
            .find(|r| {
                r.req("queue")
                    .ok()
                    .and_then(|q| q.as_str().ok())
                    .is_some_and(|q| q == "streaming")
            })
            .expect("record must carry the streaming row");
        let tps = streaming_row
            .req("tokens_per_s").unwrap()
            .as_f64().unwrap();
        assert!(tps.is_finite() && tps > 0.0,
                "nonsense streaming tokens/s {tps}");
        assert_eq!(
            streaming_row.req("sessions").unwrap().as_f64().unwrap(),
            32.0);
        assert_eq!(
            streaming_row
                .req("stream_tokens").unwrap()
                .as_f64().unwrap(),
            (32 * 8) as f64);
        let hit_rate = streaming_row
            .req("cache_hit_rate").unwrap()
            .as_f64().unwrap();
        assert!(hit_rate.is_finite() && hit_rate > 0.0,
                "streaming row must record a nonzero session-arena \
                 hit rate, got {hit_rate}");
        let spec_row = results
            .iter()
            .find(|r| {
                r.req("queue")
                    .ok()
                    .and_then(|q| q.as_str().ok())
                    .is_some_and(|q| q == "speculative")
            })
            .expect("record must carry the speculative row");
        let accept = spec_row
            .req("spec_accept_rate").unwrap()
            .as_f64().unwrap();
        assert!(accept.is_finite() && accept > 0.0 && accept <= 1.0,
                "nonsense speculative accept rate {accept}");
        let tpa = spec_row
            .req("tokens_per_admission").unwrap()
            .as_f64().unwrap();
        assert!(tpa.is_finite() && tpa > 1.0,
                "speculative tokens/admission must beat plain decode's \
                 1.0, got {tpa}");
        let hetero_row = results
            .iter()
            .find(|r| {
                r.req("queue")
                    .ok()
                    .and_then(|q| q.as_str().ok())
                    .is_some_and(|q| q == "hetero")
            })
            .expect("record must carry the heterogeneous-topology row");
        assert_eq!(
            hetero_row.req("worker_classes").unwrap().as_str().unwrap(),
            "fast=2:slow=2");
        assert_eq!(
            hetero_row
                .req("class_sections").unwrap()
                .as_arr().unwrap()
                .len(),
            2, "hetero row must carry both per-class sections");
        let faults_row = results
            .iter()
            .find(|r| {
                r.req("queue")
                    .ok()
                    .and_then(|q| q.as_str().ok())
                    .is_some_and(|q| q == "faults")
            })
            .expect("record must carry the chaos-injection row");
        let avail = faults_row
            .req("availability").unwrap()
            .as_f64().unwrap();
        assert!(avail.is_finite() && avail > 0.9 && avail <= 1.0,
                "nonsense chaos availability {avail}");
        let submitted = faults_row
            .req("submitted").unwrap()
            .as_f64().unwrap();
        let poisoned = faults_row
            .req("poisoned").unwrap()
            .as_f64().unwrap();
        assert!(poisoned >= 1.0 && poisoned <= submitted,
                "chaos row must quarantine the poison request and \
                 nothing close to everything: {poisoned} of {submitted}");
        let retries = faults_row
            .req("retries").unwrap()
            .as_f64().unwrap();
        assert!(retries > 0.0,
                "a 10% transient fault rate must exercise the retry \
                 ladder, recorded {retries}");
        let speedup = doc
            .req("speedup_sharded_over_shared").unwrap()
            .req("w4").unwrap()
            .as_f64().unwrap();
        assert!(speedup.is_finite() && speedup > 0.0,
                "nonsense speedup {speedup}");
        println!("sharded/shared 4-worker sim-pipeline speedup \
                  (debug build): {speedup:.2}x");
    }
}
