//! Hermetic perf-trajectory gate: drives the same sim-pipeline
//! comparison as the `hotpath` bench — the pre-sharding shared
//! single-deque admission queue vs the sharded work-stealing queue at
//! 4 workers under a near-zero-latency `SimSpec` (host overhead
//! dominates) — and writes the machine-readable `BENCH_serving.json`
//! at the repo root, so every tier-1 `cargo test` run refreshes the
//! perf record even where `cargo bench` never runs.
//!
//! Debug-build timings on shared CI runners are noisy, so this test
//! asserts *structure* (exactly-once service under both topologies, a
//! parseable record with a finite ratio): the ratio itself is recorded
//! rather than gated here.  The release-mode bench row (CI `bench-smoke`
//! job, or a local `cargo bench --bench hotpath`) is the number the
//! ">= 1.5x sharded over shared at 4 workers" acceptance target is
//! judged by.

use std::path::Path;

use elastiformer::coordinator::serving::sim::{self, BenchRow};
use elastiformer::coordinator::serving::SimSpec;
use elastiformer::json;

#[test]
fn bench_gate_records_shared_vs_sharded_pipeline() {
    let n = 1024usize;
    let workers = 4usize;
    let spec = SimSpec {
        base_ms: 0.05,
        ms_per_capacity: 0.05,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let mut rows: Vec<BenchRow> = Vec::new();
    for (label, shards) in [("shared", 1usize), ("sharded", workers)] {
        let report = sim::pipeline_point(spec, workers, shards, n)
            .unwrap_or_else(|e| panic!("{label} pipeline failed: {e:#}"));
        assert_eq!(report.completions.len(), n, "{label}: requests lost");
        let mut ids: Vec<u64> =
            report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(),
                   "{label}: dropped or duplicated requests");
        rows.push(BenchRow { queue: label, workers, shards, report });
    }
    let path = Path::new(
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json"));
    // never stomp an authoritative release-mode record with debug
    // numbers: refresh the file only when it holds the committed seed
    // or a previous debug refresh
    let keep_existing = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| json::parse(&t).ok())
        .and_then(|d| {
            d.req("source").ok().and_then(|s| {
                s.as_str().ok().map(|s| s.contains("(release)"))
            })
        })
        .unwrap_or(false);
    if keep_existing {
        println!("BENCH_serving.json holds a release-mode record; \
                  leaving it in place");
    } else {
        sim::write_bench_json(path, "tests/bench_gate.rs (debug)", spec,
                              n, &rows)
            .expect("BENCH_serving.json must be writable at the repo root");
        // the record must be parseable and carry the 4-worker ratio
        let text = std::fs::read_to_string(path).unwrap();
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.req("bench").unwrap().as_str().unwrap(),
                   "sim_pipeline");
        let results = doc.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        let speedup = doc
            .req("speedup_sharded_over_shared").unwrap()
            .req("w4").unwrap()
            .as_f64().unwrap();
        assert!(speedup.is_finite() && speedup > 0.0,
                "nonsense speedup {speedup}");
        println!("sharded/shared 4-worker sim-pipeline speedup \
                  (debug build): {speedup:.2}x");
    }
}
