//! Hermetic end-to-end serving tests over the deterministic
//! `SimExecutor` — no artifacts, no XLA runtime.  These exercise the
//! full submit → admit → batch → tier-select → execute → resolve
//! pipeline through the handle-based client API: light load serves the
//! top tier, sustained overload sheds capacity, tight-deadline SLO
//! classes are shed or floor-tiered while relaxed classes on the same
//! queue are served, class-aware batch formation keeps floored and
//! best-effort requests out of each other's batches, admission verdicts
//! only shed when the aggregate bound across all shards is genuinely
//! hit, shutdown drains every admitted request (work stealing included),
//! and N workers beat one worker on wall-clock.  The streaming decode
//! subsystem is exercised end to end: concurrent sessions batch across
//! sessions (continuous batching), tight-budget sessions degrade tiers
//! per step instead of being shed, mid-decode close terminates streams
//! at the step boundary, and engine-side rejections reconcile with the
//! report's shed log.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use elastiformer::coordinator::serving::{
    sim, Admission, ElasticEngine, ExecOutput, Executor, FaultPlan,
    Request, Response, ServeConfig, ServeError, ServeReport, ShedCause,
    ShedReason, SimSpec, SloClass, StreamEvent, StreamRequest,
    WorkerClassStats,
};

mod common;
use common::counting_factory;

fn sim_tokens(id: u64, seq_len: usize) -> Vec<i32> {
    (0..seq_len).map(|i| ((id as usize + i) % 97) as i32).collect()
}

fn assert_ids_exactly_once(report: &ServeReport, n: usize) {
    let mut ids: Vec<u64> =
        report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(),
               "requests dropped or duplicated");
}

#[test]
fn light_load_serves_top_tier_and_replies_carry_logits() {
    // arrivals far slower than service: the backlog never builds, so
    // requests run at capacity 1.0 (teacher-exact under §4.1).  The
    // assertions leave slack for scheduler stalls on loaded CI runners
    // (a descheduled worker briefly fakes a backlog the controller is
    // *supposed* to react to): a majority at the top tier + a high mean
    // still cleanly separates "healthy under light load" from a
    // controller that sheds spuriously (which floors near the bottom
    // tier and fails both).
    let spec = SimSpec {
        batch: 4,
        base_ms: 0.2,
        ms_per_capacity: 0.3,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_depth_per_tier(8.0)
        .with_max_batch_wait(Duration::from_millis(5));
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let n = 60;
    let mut responses = Vec::with_capacity(n);
    for id in 0..n as u64 {
        responses
            .push(engine.submit(Request::new(id, sim_tokens(id, spec.seq_len))));
        std::thread::sleep(Duration::from_millis(2));
    }
    for r in responses {
        let reply = r.wait().expect("light load must serve everything");
        // the sim backend emits one logit per batch slot, valued at the
        // tier served: delivery through the Response is end-to-end real
        assert_eq!(reply.logits.len(), 1);
        assert_eq!(reply.logits[0], reply.completion.tier);
        assert!((reply.completion.queue_ms + reply.completion.exec_ms
                 - reply.completion.total_ms)
                    .abs() < 1e-9,
                "timings must add up on one clock");
        assert!(reply.completion.queue_ms >= 0.0,
                "negative queue wait: {}", reply.completion.queue_ms);
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), n);
    assert_ids_exactly_once(&report, n);
    let full = report
        .completions
        .iter()
        .filter(|c| c.tier == 1.0)
        .count();
    assert!(full * 2 >= n,
            "light load shed capacity on {} of {n}: tier counts {:?}",
            n - full, report.tier_counts);
    assert!(report.mean_capacity() >= 0.7,
            "mean capacity {:.3} under light load",
            report.mean_capacity());
}

#[test]
fn sustained_overload_sheds_to_lower_tiers() {
    // flood submissions into a small queue with an aggressive shed
    // ladder: the controller must observe the standing backlog and drop
    // tiers.  `submit` blocks at the bound, so the flood is throttled
    // to service rate while the queue stays pinned at its bound.
    let spec = SimSpec {
        batch: 2,
        base_ms: 1.0,
        ms_per_capacity: 1.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_queue_bound(32)
        .with_depth_per_tier(2.0)
        .with_max_batch_wait(Duration::from_millis(1));
    let caps = cfg.capacities();
    let lowest = *caps.last().unwrap();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let n = 96;
    let mut responses = Vec::with_capacity(n);
    for id in 0..n as u64 {
        responses
            .push(engine.submit(Request::new(id, sim_tokens(id, spec.seq_len))));
    }
    for r in responses {
        r.wait().expect("no deadlines configured, nothing may be shed");
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), n);
    assert_ids_exactly_once(&report, n);
    let shed = report
        .completions
        .iter()
        .filter(|c| c.tier < 1.0)
        .count();
    assert!(shed > n / 4,
            "only {shed}/{n} shed under flood; tiers {:?}",
            report.tier_counts);
    assert!(report.mean_capacity() < 1.0);
    assert!(report.completions.iter().any(|c| c.tier <= lowest + 1e-6),
            "sustained overload never reached the lowest tier: {:?}",
            report.tier_counts);
}

#[test]
fn tight_deadline_class_shed_while_relaxed_class_served() {
    // acceptance gate: two SLO classes on the same queue.  A 30ms batch
    // occupies the single worker; a 5ms-deadline request queued behind
    // it is unmeetable and must be shed (DeadlineExceeded) without
    // spending compute, while relaxed requests around it are served.
    let spec = SimSpec {
        batch: 1,
        base_ms: 30.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_max_batch_wait(Duration::ZERO);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let relaxed = SloClass::named("relaxed");
    let tight = SloClass::named("tight")
        .with_deadline(Duration::from_millis(5));
    let r0 = engine.submit(
        Request::new(0, sim_tokens(0, spec.seq_len)).with_slo(relaxed.clone()));
    let t1 = engine.submit(
        Request::new(1, sim_tokens(1, spec.seq_len)).with_slo(tight));
    let r2 = engine.submit(
        Request::new(2, sim_tokens(2, spec.seq_len)).with_slo(relaxed));
    assert!(r0.wait().is_ok(), "first relaxed request must be served");
    match t1.wait() {
        Err(ServeError::DeadlineExceeded) => {}
        other => panic!("tight-deadline request must be shed, got {other:?}"),
    }
    assert!(r2.wait().is_ok(), "relaxed request behind the shed one \
                                must still be served");
    let report = engine.shutdown().unwrap();
    let sections = report.class_sections();
    let tight_sec = sections.iter().find(|s| s.class == "tight").unwrap();
    assert_eq!((tight_sec.served, tight_sec.shed), (0, 1));
    let relaxed_sec =
        sections.iter().find(|s| s.class == "relaxed").unwrap();
    assert_eq!((relaxed_sec.served, relaxed_sec.shed), (2, 0));
}

#[test]
fn floor_tier_class_holds_capacity_while_best_effort_sheds() {
    // same queue, sustained overload, aggressive shed ladder: the
    // best-effort class must lose capacity while the floored class is
    // pinned at its floor (batch = 1, so classes never share a batch)
    let spec = SimSpec {
        batch: 1,
        base_ms: 1.0,
        ms_per_capacity: 1.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_queue_bound(128)
        .with_depth_per_tier(0.5)
        .with_max_batch_wait(Duration::ZERO);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let floored = SloClass::named("premium").with_floor_tier(1.0);
    let n = 60;
    let mut responses = Vec::with_capacity(n);
    for id in 0..n as u64 {
        let slo = if id % 2 == 0 {
            floored.clone()
        } else {
            SloClass::best_effort()
        };
        responses.push(engine.submit(
            Request::new(id, sim_tokens(id, spec.seq_len)).with_slo(slo)));
    }
    let mut premium_tiers = Vec::new();
    let mut effort_tiers = Vec::new();
    for r in responses {
        let reply = r.wait().expect("no deadlines: everything is served");
        if reply.completion.class == "premium" {
            premium_tiers.push(reply.completion.tier);
        } else {
            effort_tiers.push(reply.completion.tier);
        }
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), n);
    assert!(premium_tiers.iter().all(|&t| t == 1.0),
            "floored class served below its floor: {premium_tiers:?}");
    assert!(effort_tiers.iter().any(|&t| t < 1.0),
            "best-effort never shed under overload: {effort_tiers:?}");
    let sections = report.class_sections();
    let premium =
        sections.iter().find(|s| s.class == "premium").unwrap();
    let effort =
        sections.iter().find(|s| s.class == "best-effort").unwrap();
    assert!(premium.mean_capacity > effort.mean_capacity,
            "premium {:.3} <= best-effort {:.3}",
            premium.mean_capacity, effort.mean_capacity);
}

#[test]
fn class_aware_batching_shields_best_effort_from_floors() {
    // sustained overload, batch 4, premium (floor 1.0) and best-effort
    // interleaved on one queue: with class-aware batch formation the
    // two classes never share a batch, so premium stays pinned at 1.0
    // while the majority of best-effort requests shed below it.
    // Before this, the strictest floor in a mixed batch dragged every
    // best-effort neighbour up to tier 1.0 with it (which is why the
    // older floor test had to use batch = 1 to mean anything).
    let spec = SimSpec {
        batch: 4,
        base_ms: 1.0,
        ms_per_capacity: 1.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_queue_bound(128)
        .with_depth_per_tier(0.5)
        .with_max_batch_wait(Duration::from_millis(1));
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let floored = SloClass::named("premium").with_floor_tier(1.0);
    let n = 64;
    let mut responses = Vec::with_capacity(n);
    for id in 0..n as u64 {
        let slo = if id % 2 == 0 {
            floored.clone()
        } else {
            SloClass::best_effort()
        };
        responses.push(engine.submit(
            Request::new(id, sim_tokens(id, spec.seq_len)).with_slo(slo)));
    }
    let mut premium_tiers = Vec::new();
    let mut effort_tiers = Vec::new();
    for r in responses {
        let reply = r.wait().expect("no deadlines: everything is served");
        if reply.completion.class == "premium" {
            premium_tiers.push(reply.completion.tier);
        } else {
            effort_tiers.push(reply.completion.tier);
        }
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), n);
    assert_ids_exactly_once(&report, n);
    assert!(premium_tiers.iter().all(|&t| t == 1.0),
            "floored class served below its floor: {premium_tiers:?}");
    let shed = effort_tiers.iter().filter(|&&t| t < 1.0).count();
    assert!(shed * 2 > effort_tiers.len(),
            "best-effort mostly rode premium batches at tier 1.0 \
             ({shed}/{} shed): {effort_tiers:?}",
            effort_tiers.len());
}

#[test]
fn heterogeneous_fleet_isolates_per_class_controllers() {
    // acceptance gate for worker classes: one fast (instant) and one
    // slow (~200ms/batch) executor class behind the same queue, each
    // with its OWN capacity controller.  After both classes have
    // demonstrably executed batches at tier 1.0 (so both latency
    // models are warm), requests with a 120ms deadline are submitted
    // one at a time: a fast worker's own estimate (~0ms) fits the
    // slack, so fast-served requests stay at the top tier; the slow
    // class's 200ms estimate blows it, so slow-served requests are
    // demoted down the ladder.  With the old single shared controller
    // the slow observations inflated the shared tier-1.0 estimate and
    // demoted *every* deadline'd batch, fast workers included — the
    // cross-class pollution this test pins down.
    let cfg0 = ServeConfig::sim();
    let caps = cfg0.capacities();
    let fast_spec = SimSpec { batch: 2, ..SimSpec::instant() };
    let slow_spec = SimSpec {
        batch: 2,
        base_ms: 200.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let fast_count = Arc::new(AtomicUsize::new(0));
    let slow_count = Arc::new(AtomicUsize::new(0));
    let cfg = cfg0
        .with_queue_bound(256)
        .with_depth_per_tier(1e9) // the backlog signal never demotes
        .with_max_batch_wait(Duration::ZERO)
        .with_worker_class(
            "fast", 1,
            counting_factory(fast_spec, caps.clone(), fast_count.clone()))
        .with_worker_class(
            "slow", 1,
            counting_factory(slow_spec, caps.clone(), slow_count.clone()));
    let engine = ElasticEngine::start_fleet(cfg).unwrap();
    let seq = fast_spec.seq_len;
    let mut id = 0u64;

    // phase 1 — warm both latency models with best-effort traffic (all
    // of it runs at tier 1.0: huge depth_per_tier, no deadlines).  Loop
    // until the counters prove both classes executed at least once.
    let mut rounds = 0usize;
    while fast_count.load(Ordering::SeqCst) == 0
        || slow_count.load(Ordering::SeqCst) == 0
    {
        rounds += 1;
        assert!(rounds <= 200,
                "a worker class never executed a warmup batch \
                 (fast {}, slow {})",
                fast_count.load(Ordering::SeqCst),
                slow_count.load(Ordering::SeqCst));
        let warm: Vec<Response> = (0..8)
            .map(|_| {
                let r = engine.submit(Request::new(id, sim_tokens(id, seq)));
                id += 1;
                r
            })
            .collect();
        for r in warm {
            r.wait().expect("warmup request must be served");
        }
    }

    // phase 2 — deadline'd requests, one at a time so the slack at pop
    // is ~the full 120ms budget.  Keep going until the slow class has
    // provably served some of them (its counter moved), so the
    // per-class tier-mix assertions below cannot vacuously pass.
    let slo = SloClass::named("dl").with_deadline(Duration::from_millis(120));
    let slow_before = slow_count.load(Ordering::SeqCst);
    let mut submitted_dl = 0usize;
    while submitted_dl < 12
        || slow_count.load(Ordering::SeqCst) < slow_before + 2
    {
        assert!(submitted_dl <= 400,
                "slow class never served a deadline'd request");
        let r = engine.submit(
            Request::new(id, sim_tokens(id, seq)).with_slo(slo.clone()));
        id += 1;
        submitted_dl += 1;
        // served late is fine (expiry is only checked at pop); what
        // may NOT happen is a shed — slack at pop is ~120ms
        r.wait().expect("one-at-a-time deadline'd request must serve");
    }

    let report = engine.shutdown().unwrap();
    // every submitted request resolved exactly once into the report
    assert_eq!(report.completions.len(), id as usize);
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..id).collect::<Vec<_>>(),
               "requests dropped or duplicated");

    // distinct learned exec estimates per class in the report
    let sections = report.worker_class_sections();
    assert_eq!(sections.len(), 2);
    let fast_sec = sections.iter().find(|s| s.class == "fast").unwrap();
    let slow_sec = sections.iter().find(|s| s.class == "slow").unwrap();
    let top_est = |s: &WorkerClassStats| {
        s.exec_estimates_ms
            .iter()
            .find(|(t, _)| (*t - 1.0).abs() < 1e-6)
            .and_then(|(_, e)| *e)
    };
    let fast_est = top_est(fast_sec).expect("fast class executed at 1.0");
    let slow_est = top_est(slow_sec).expect("slow class executed at 1.0");
    assert!(slow_est >= 150.0,
            "slow estimate {slow_est} ms below its 200ms latency model");
    assert!(fast_est < slow_est,
            "per-class estimates did not diverge: fast {fast_est}, \
             slow {slow_est}");

    // isolation: the slow class's latency model never demoted a
    // fast-served batch; slow-served deadline'd batches ARE demoted
    let mut slow_served_dl = 0usize;
    for c in report.completions.iter().filter(|c| c.class == "dl") {
        if c.worker_class == "fast" {
            assert_eq!(c.tier, 1.0,
                       "slow-class pollution demoted a fast-served \
                        request: {c:?}");
        } else {
            slow_served_dl += 1;
            assert!(c.tier < 1.0,
                    "slow-served deadline'd request not demoted: {c:?}");
        }
    }
    assert!(slow_served_dl >= 1,
            "counter said slow served deadline'd work, report disagrees");
    // ...which is exactly a distinct per-class tier mix
    assert!(slow_sec.mean_capacity < fast_sec.mean_capacity,
            "tier mixes did not diverge: slow {:.3} vs fast {:.3}",
            slow_sec.mean_capacity, fast_sec.mean_capacity);
    assert!(slow_sec.tier_counts.iter().any(|(t, n)| *t < 1.0 && *n > 0),
            "slow class shows no demoted completions: {:?}",
            slow_sec.tier_counts);
}

/// Executor that records, for every row of every batch it runs, the
/// row's session marker (token 0) and its first post-prompt slot — the
/// witness for cross-session continuous batching — and emits 3-logit
/// rows whose argmax is index 2, so sampled decode tokens are the
/// distinctive value 2 (a row whose post-prompt slot holds 2 is
/// provably a decode step, not padding).  Its first `execute` blocks
/// until the shared gate opens, so the test can admit every session
/// before the single worker runs a single batch (deterministic
/// interleaving).
/// `(marker, first post-prompt token)` per row, one entry per batch.
type RowLog = Arc<Mutex<Vec<Vec<(i32, i32)>>>>;

struct BatchSpyExec {
    batch: usize,
    seq_len: usize,
    prompt_len: usize,
    rows_seen: RowLog,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl Executor for BatchSpyExec {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn execute(&mut self, _tier: f32, tokens: &[i32])
               -> Result<ExecOutput> {
        {
            let (lock, cv) = &*self.gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        }
        let rows: Vec<(i32, i32)> = (0..self.batch)
            .map(|r| (tokens[r * self.seq_len],
                      tokens[r * self.seq_len + self.prompt_len]))
            .collect();
        self.rows_seen.lock().unwrap().push(rows);
        // per row: [0, 1, 9] -> argmax index 2 -> sampled token 2
        let mut logits = Vec::with_capacity(self.batch * 3);
        for _ in 0..self.batch {
            logits.extend_from_slice(&[0.0, 1.0, 9.0]);
        }
        Ok(ExecOutput { logits })
    }
}

#[test]
fn streaming_sessions_batch_across_sessions_in_step_order() {
    // tentpole acceptance: N concurrent decode sessions on ONE worker.
    // Step 0 (prefill) batches the four prompts; every later step is a
    // decode item re-admitted by the session table, and since the
    // single worker re-admits all four before its next pop, decode
    // steps from different sessions must share batches (continuous
    // batching).  Each client must see its tokens in strict step order
    // ending in exactly one Done.
    let (batch, seq_len, prompt_len) = (4usize, 16usize, 4usize);
    let steps = 5usize;
    let rows_seen: RowLog = Arc::new(Mutex::new(Vec::new()));
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let spy_rows = rows_seen.clone();
    let spy_gate = gate.clone();
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_max_batch_wait(Duration::from_millis(2));
    let engine = ElasticEngine::start(cfg, move |_| {
        Ok(Box::new(BatchSpyExec {
            batch,
            seq_len,
            prompt_len,
            rows_seen: spy_rows.clone(),
            gate: spy_gate.clone(),
        }) as Box<dyn Executor>)
    })
    .unwrap();
    let n_sessions = 4usize;
    let streams: Vec<_> = (0..n_sessions as u64)
        .map(|id| {
            // marker prompt: every row of session id starts with
            // 100 + id, and stays shorter than seq_len so the marker
            // survives the sliding window
            engine.submit_stream(StreamRequest::new(
                id, vec![100 + id as i32; prompt_len], steps))
        })
        .collect();
    // every session is admitted before the first batch may run: the
    // interleaving below is deterministic, not a race
    open_gate(&gate);
    for s in streams {
        let sid = s.id();
        let mut expect_step = 0usize;
        let mut terminal = 0usize;
        loop {
            match s.recv() {
                Some(StreamEvent::Token { step, tier, token }) => {
                    assert_eq!(step, expect_step,
                               "session {sid}: out-of-order step");
                    assert_eq!(token, 2, "argmax of [0,1,9] is index 2");
                    assert!(tier > 0.0);
                    expect_step += 1;
                }
                Some(StreamEvent::Done(stats)) => {
                    terminal += 1;
                    assert_eq!(stats.id, sid);
                    assert_eq!(stats.steps, steps);
                    assert_eq!(stats.tiers.len(), steps);
                    assert!(stats.total_ms >= stats.first_token_ms);
                }
                Some(StreamEvent::Shed(e)) => {
                    panic!("session {sid} shed on an open engine: {e}")
                }
                None => break,
            }
        }
        assert_eq!(expect_step, steps,
                   "session {sid}: {expect_step} of {steps} tokens");
        assert_eq!(terminal, 1, "exactly one terminal per stream");
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.sessions_started, n_sessions);
    assert_eq!(report.stream_done.len(), n_sessions);
    assert!(report.stream_shed.is_empty());
    assert_eq!(report.sessions_started,
               report.stream_done.len() + report.stream_shed.len(),
               "session logs must reconcile");
    // the continuous-batching witness: some executed batch carried
    // *decode* rows (first post-prompt slot holds the sampled token 2,
    // which zero-padding and raw prompts cannot produce) from at least
    // two distinct sessions (distinct markers)
    let seen = rows_seen.lock().unwrap();
    let interleaved = seen.iter().any(|rows| {
        let mut decode_markers: Vec<i32> = rows
            .iter()
            .filter(|(_, post)| *post == 2)
            .map(|(marker, _)| *marker)
            .collect();
        decode_markers.sort_unstable();
        decode_markers.dedup();
        decode_markers.len() >= 2
    });
    assert!(interleaved,
            "no batch mixed decode steps from two sessions: {seen:?}");
    // and the report aggregates the stream economy
    let sections = report.stream_sections();
    assert_eq!(sections.len(), 1);
    assert_eq!(sections[0].tokens, n_sessions * steps);
    assert!(sections[0].tokens_per_s > 0.0);
    assert_eq!(report.tokens_per_s(), sections[0].tokens_per_s);
}

#[test]
fn arena_hits_make_decode_cheaper_than_recompute() {
    // tentpole acceptance: with window-preparation modeled (a
    // recomputed decode row costs seq_len token-units, an arena-cached
    // row costs 1), the same streaming load must finish measurably
    // faster with a live session arena than with the arena disabled.
    // Modeled gap per decode round here: 4 rows x 32 tokens = 128ms
    // recompute vs 4ms cached — wide enough that scheduler noise
    // cannot flip the comparison.
    let spec = SimSpec {
        batch: 4,
        seq_len: 32,
        base_ms: 0.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        recompute_ms_per_token: 1.0,
        ..SimSpec::standard()
    };
    let (sessions, steps) = (4usize, 6usize);
    let run_with = |pages: usize| -> ServeReport {
        let cfg = ServeConfig::sim()
            .with_workers(1)
            .with_arena_pages(pages)
            .with_max_batch_wait(Duration::from_millis(1));
        let caps = cfg.capacities();
        let engine =
            ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
        let streams: Vec<_> = (0..sessions as u64)
            .map(|id| {
                engine.submit_stream(
                    StreamRequest::new(id, vec![1; 8], steps))
            })
            .collect();
        for s in streams {
            let stats =
                s.wait().expect("open-engine session must complete");
            assert_eq!(stats.steps, steps);
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.stream_done.len(), sessions);
        assert!(report.stream_shed.is_empty());
        report
    };
    let hit = run_with(64);
    let miss = run_with(0);
    assert!(hit.cache_hits > 0,
            "a live arena must serve some decode rows from cache");
    assert!(hit.cache_hit_rate() > 0.5,
            "single-worker affine decode should mostly hit, got {:.2} \
             ({} hits / {} misses)",
            hit.cache_hit_rate(), hit.cache_hits, hit.cache_misses);
    assert_eq!(miss.cache_hits, 0,
               "a disabled arena can never serve a row");
    assert_eq!(miss.cache_hit_rate(), 0.0);
    assert!(miss.wall_secs > hit.wall_secs * 1.5,
            "recompute-only run must pay the modeled window cost: \
             {:.3}s recompute vs {:.3}s cached",
            miss.wall_secs, hit.wall_secs);
    // the per-class report section carries the same economy
    let classes = hit.worker_class_sections();
    assert_eq!(classes.len(), 1);
    assert_eq!(classes[0].cache_hits, hit.cache_hits);
    assert_eq!(classes[0].cache_misses, hit.cache_misses);
}

#[test]
fn tight_deadline_session_degrades_tiers_instead_of_shed() {
    // the graceful-degradation contract: a session whose total budget
    // cannot afford every step at tier 1.0 must be demoted down the
    // ladder step by step (slack / remaining steps shrinks below the
    // learned tier-1.0 exec estimate) and still finish with Done —
    // never a cliff-edge shed.  Latencies are tier-proportional and
    // large relative to scheduler noise: tier 1.0 ~= 64ms/batch,
    // 0.75 ~= 49ms, 0.5 ~= 34ms, 0.25 ~= 19ms.
    let spec = SimSpec {
        batch: 1,
        base_ms: 4.0,
        ms_per_capacity: 60.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_depth_per_tier(1e9) // the backlog signal never demotes
        .with_max_batch_wait(Duration::ZERO);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    // warm the tier-1.0 exec estimate (~64ms) with best-effort traffic
    for id in 0..2u64 {
        engine
            .submit(Request::new(id, sim_tokens(id, spec.seq_len)))
            .wait()
            .expect("warmup must serve");
    }
    // 6 steps at tier 1.0 would cost ~384ms; the 340ms budget cannot
    // afford that (per-step allowance 340/6 ~= 56.7ms < the >= 64ms
    // learned estimate — the sim sleep never undershoots, so the
    // demotion side is noise-proof), so the controller demotes — and
    // at ~49ms per 0.75 step the session's last pop lands ~95ms before
    // the deadline, so even a long scheduler stall cannot shed it
    // (stalls only demote further, which shrinks step cost and grows
    // the margin)
    let steps = 6usize;
    let slo = SloClass::named("tight")
        .with_deadline(Duration::from_millis(340));
    let stats = engine
        .submit_stream(
            StreamRequest::new(50, vec![1; 4], steps).with_slo(slo))
        .wait()
        .expect("tight session must degrade and complete, not shed");
    assert_eq!(stats.steps, steps);
    assert_eq!(stats.tiers.len(), steps);
    assert!(stats.tiers.iter().any(|&t| t < 1.0),
            "no step was demoted: {:?}", stats.tiers);
    let report = engine.shutdown().unwrap();
    assert_eq!(report.stream_done.len(), 1);
    assert!(report.stream_shed.is_empty(),
            "graceful degradation must avoid the shed");
}

#[test]
fn mid_decode_close_sheds_sessions_at_the_step_boundary() {
    // mid-decode shutdown: a long session is decoding when admission
    // closes.  Its already-delivered tokens stay valid and the stream
    // must end in exactly one Shed(ShuttingDown) — at the next step
    // boundary, not after draining hundreds of queued steps.
    let spec = SimSpec {
        batch: 1,
        base_ms: 2.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_max_batch_wait(Duration::ZERO);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let s = engine.submit_stream(
        StreamRequest::new(9, vec![1; 4], 100_000));
    // let a few tokens land first
    let mut got = 0usize;
    while got < 3 {
        match s.recv_timeout(Duration::from_secs(30)) {
            Ok(Some(StreamEvent::Token { .. })) => got += 1,
            other => panic!("want a token, got {other:?}"),
        }
    }
    engine.close();
    let mut terminal = None;
    loop {
        match s.recv_timeout(Duration::from_secs(30)) {
            Ok(Some(StreamEvent::Token { .. })) => got += 1,
            Ok(Some(StreamEvent::Shed(e))) => {
                terminal = Some(e);
            }
            Ok(Some(StreamEvent::Done(_))) => {
                panic!("a 100k-step session cannot have finished")
            }
            Ok(None) => break,
            Err(_) => panic!("stream never terminated after close"),
        }
    }
    assert_eq!(terminal, Some(ServeError::ShuttingDown));
    let report = engine.shutdown().unwrap();
    assert_eq!(report.sessions_started, 1);
    assert_eq!(report.stream_shed.len(), 1);
    assert_eq!(report.stream_shed[0].steps_done, got,
               "shed record must count the delivered tokens");
    assert_eq!(report.sessions_started,
               report.stream_done.len() + report.stream_shed.len());
}

#[test]
fn close_records_engine_side_sheds_that_reconcile_with_verdicts() {
    // satellite acceptance: every client-observed ShuttingDown verdict
    // must have a matching engine-side shed record, so report totals
    // reconcile.  Before this, a try_submit refused during shutdown
    // vanished from the report entirely.
    let spec = SimSpec::instant();
    let cfg = ServeConfig::sim().with_workers(1);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let seq = spec.seq_len;
    let served: Vec<Response> = (0..3u64)
        .map(|id| engine.submit(Request::new(id, sim_tokens(id, seq))))
        .collect();
    for r in served {
        r.wait().expect("pre-close submissions must serve");
    }
    engine.close();
    // count the client-observed ShuttingDown verdicts after close
    let mut observed = 0usize;
    for id in 10..12u64 {
        match engine.try_submit(Request::new(id, sim_tokens(id, seq))) {
            Admission::Shed(ShedReason::ShuttingDown) => observed += 1,
            Admission::Shed(r) => {
                panic!("want ShuttingDown verdict, got {r:?}")
            }
            Admission::Accepted(_) => {
                panic!("closed engine accepted a request")
            }
        }
    }
    match engine
        .submit(Request::new(12, sim_tokens(12, seq)))
        .wait()
    {
        Err(ServeError::ShuttingDown) => observed += 1,
        other => panic!("want ShuttingDown, got {other:?}"),
    }
    // a refused stream session must reconcile too: one started, one
    // engine-shed, terminal Shed(ShuttingDown) on the stream
    match engine
        .submit_stream(StreamRequest::new(13, vec![1; 4], 4))
        .wait()
    {
        Err(ServeError::ShuttingDown) => {}
        other => panic!("want stream ShuttingDown, got {other:?}"),
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), 3);
    let engine_sheds: Vec<_> = report
        .sheds
        .iter()
        .filter(|s| s.cause == ShedCause::ShuttingDown)
        .collect();
    assert_eq!(engine_sheds.len(), observed,
               "shed log must reconcile with client verdicts");
    assert!(engine_sheds.iter().all(|s| s.worker_class == "engine"),
            "engine-side sheds carry the engine pseudo-class");
    assert_eq!(report.sessions_started, 1);
    assert_eq!(report.stream_shed.len(), 1);
    assert_eq!(report.stream_shed[0].reason, ServeError::ShuttingDown);
    // the per-SLO-class sections surface the rejections
    let sections = report.class_sections();
    let be = sections
        .iter()
        .find(|s| s.class == "best-effort")
        .expect("best-effort section");
    assert_eq!(be.shed, observed);
}

/// Executor whose `execute` blocks until the shared gate opens —
/// deterministic queue-full scenarios without sleeping.
struct GatedExec {
    gate: Arc<(Mutex<bool>, Condvar)>,
    seq_len: usize,
}

impl Executor for GatedExec {
    fn batch(&self) -> usize {
        1
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn execute(&mut self, tier: f32, _tokens: &[i32]) -> Result<ExecOutput> {
        let (lock, cv) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(ExecOutput { logits: vec![tier] })
    }
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (lock, cv) = &**gate;
    *lock.lock().unwrap() = true;
    cv.notify_all();
}

#[test]
fn try_submit_sheds_only_when_queue_actually_full() {
    // single worker blocked in execute, bound = 4: after the worker
    // takes its one in-flight request, the next `bound` try_submits
    // must all be accepted (the queue has room); only once the bound is
    // genuinely hit may Shed(QueueFull) appear — and releasing the gate
    // must serve every accepted request.
    let bound = 4usize;
    let seq_len = 8usize;
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let factory_gate = gate.clone();
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_queue_bound(bound)
        .with_max_batch_wait(Duration::ZERO);
    let engine = ElasticEngine::start(cfg, move |_| {
        Ok(Box::new(GatedExec { gate: factory_gate.clone(), seq_len })
            as Box<dyn Executor>)
    })
    .unwrap();

    // first request: the worker pops it and parks inside execute
    let first = engine.submit(Request::new(0, sim_tokens(0, seq_len)));
    while engine.queue_depth() > 0 {
        std::thread::yield_now(); // until the worker holds it
    }

    // with the worker parked, the queue must accept exactly `bound`
    // more before the first QueueFull verdict
    let mut accepted: Vec<Response> = Vec::new();
    for id in 1..=bound as u64 {
        match engine.try_submit(Request::new(id, sim_tokens(id, seq_len))) {
            Admission::Accepted(r) => accepted.push(r),
            Admission::Shed(reason) => panic!(
                "shed verdict ({reason:?}) with only {} of {bound} \
                 queued — queue was not full",
                accepted.len()),
        }
    }
    match engine.try_submit(Request::new(99, sim_tokens(99, seq_len))) {
        Admission::Shed(ShedReason::QueueFull) => {}
        Admission::Shed(other) => panic!("want QueueFull, got {other:?}"),
        Admission::Accepted(_) => panic!(
            "admitted past the bound: the queue held {bound} already"),
    }

    open_gate(&gate);
    assert!(first.wait().is_ok());
    for r in accepted {
        r.wait().expect("accepted request must be served after release");
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), 1 + bound,
               "exactly the accepted requests are served");
}

#[test]
fn shutdown_drains_every_admitted_request() {
    // shutdown must close admission and drain: every already-submitted
    // request resolves Ok, including a final partial batch (37 % 4 != 0)
    let spec = SimSpec {
        batch: 4,
        base_ms: 0.1,
        ms_per_capacity: 0.1,
        jitter_ms: 0.05,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim().with_workers(2);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let sent = 37;
    let responses: Vec<Response> = (0..sent as u64)
        .map(|id| engine.submit(Request::new(id, sim_tokens(id, spec.seq_len))))
        .collect();
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), sent,
               "drain lost admitted requests");
    assert_ids_exactly_once(&report, sent);
    // batch accounting: every completion records a plausible batch size
    assert!(report.completions.iter().all(
        |c| c.batch_size >= 1 && c.batch_size <= 4));
    // and every response resolved Ok — drain means served, not dropped
    for r in responses {
        match r.wait_timeout(Duration::from_secs(10)) {
            Some(Ok(_)) => {}
            other => panic!("admitted request not served: {other:?}"),
        }
    }
}

#[test]
fn responses_outlive_the_handle_across_shutdown() {
    // shutdown consumes the handle, but Response futures obtained
    // before it must still resolve afterwards (the slot is shared
    // state, not borrowed from the handle)
    let spec = SimSpec::instant();
    let cfg = ServeConfig::sim().with_workers(1);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let r = engine.submit(Request::new(0, sim_tokens(0, spec.seq_len)));
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), 1);
    assert!(r.wait().is_ok(), "pre-shutdown submission must resolve Ok");
}

#[test]
fn close_drain_lets_live_sessions_finish_before_shutdown() {
    // graceful-drain satellite: sessions live at close_drain time must
    // run their remaining steps to completion (terminal Done), unlike
    // close() which sheds them at the next step boundary.  Latencies
    // are real enough that the sessions are provably mid-decode when
    // the drain begins.
    let spec = SimSpec {
        batch: 4,
        base_ms: 2.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_max_batch_wait(Duration::from_millis(1));
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let steps = 20usize;
    let streams: Vec<_> = (0..3u64)
        .map(|id| {
            engine.submit_stream(StreamRequest::new(id, vec![1; 4], steps))
        })
        .collect();
    // provably mid-decode: every session has delivered a token but
    // cannot have finished (19 more steps x >= 2ms each remain)
    for s in &streams {
        match s.recv_timeout(Duration::from_secs(30)) {
            Ok(Some(StreamEvent::Token { step: 0, .. })) => {}
            other => panic!("want first token, got {other:?}"),
        }
    }
    let drained = engine.close_drain(Duration::from_secs(60));
    assert!(drained, "bounded-budget sessions must drain in time");
    // after the drain the engine refuses new work like a closed one
    match engine.try_submit(Request::new(90, sim_tokens(90, spec.seq_len)))
    {
        Admission::Shed(ShedReason::ShuttingDown) => {}
        other => panic!("drained engine must refuse, got {other:?}"),
    }
    for s in streams {
        let sid = s.id();
        let mut tokens = 1usize; // the step-0 token consumed above
        let mut done = 0usize;
        loop {
            match s.recv_timeout(Duration::from_secs(30)) {
                Ok(Some(StreamEvent::Token { .. })) => tokens += 1,
                Ok(Some(StreamEvent::Done(stats))) => {
                    done += 1;
                    assert_eq!(stats.steps, steps);
                }
                Ok(Some(StreamEvent::Shed(e))) => {
                    panic!("session {sid} shed during graceful drain: {e}")
                }
                Ok(None) => break,
                Err(_) => panic!("session {sid} never terminated"),
            }
        }
        assert_eq!(tokens, steps, "session {sid} truncated");
        assert_eq!(done, 1, "exactly one terminal per stream");
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.sessions_started, 3);
    assert_eq!(report.stream_done.len(), 3);
    assert!(report.stream_shed.is_empty(),
            "graceful drain must not shed bounded sessions");
}

#[test]
fn close_drain_timeout_falls_back_to_the_hard_close() {
    // the drain budget is a deadline, not a promise: a session that
    // cannot finish inside it is shed at its next step boundary,
    // exactly as close() — the engine never hangs on an unbounded
    // session
    let spec = SimSpec {
        batch: 1,
        base_ms: 2.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_max_batch_wait(Duration::ZERO);
    let caps = cfg.capacities();
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let s = engine.submit_stream(
        StreamRequest::new(7, vec![1; 4], 100_000));
    match s.recv_timeout(Duration::from_secs(30)) {
        Ok(Some(StreamEvent::Token { .. })) => {}
        other => panic!("want a token, got {other:?}"),
    }
    let drained = engine.close_drain(Duration::from_millis(1));
    assert!(!drained, "a 100k-step session cannot drain in 1ms");
    let mut shed = None;
    loop {
        match s.recv_timeout(Duration::from_secs(30)) {
            Ok(Some(StreamEvent::Token { .. })) => {}
            Ok(Some(StreamEvent::Shed(e))) => shed = Some(e),
            Ok(Some(StreamEvent::Done(_))) => {
                panic!("a 100k-step session cannot have finished")
            }
            Ok(None) => break,
            Err(_) => panic!("stream never terminated after drain"),
        }
    }
    assert_eq!(shed, Some(ServeError::ShuttingDown));
    let report = engine.shutdown().unwrap();
    assert_eq!(report.stream_shed.len(), 1);
}

#[test]
fn speculative_sessions_stream_in_order_and_reconcile() {
    // speculative e2e over the sim's tier-dependent divergence model:
    // sessions draft at the cheapest tier and verify at the top tier,
    // clients still see every token in strict step order with exactly
    // one Done, and the report's speculative ledger reconciles.
    let spec = SimSpec {
        batch: 8,
        seq_len: 16,
        divergence: 0.2,
        ..SimSpec::instant()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_spec_k(3)
        .with_max_batch_wait(Duration::from_millis(1));
    let caps = cfg.capacities();
    let top = caps[0];
    let engine =
        ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
    let steps = 10usize;
    let streams: Vec<_> = (0..3u64)
        .map(|id| {
            engine.submit_stream(StreamRequest::new(id, vec![1; 4], steps))
        })
        .collect();
    let mut saw_draft_tier = false;
    for s in streams {
        let sid = s.id();
        let mut expect_step = 0usize;
        let mut done = 0usize;
        loop {
            match s.recv_timeout(Duration::from_secs(30)) {
                Ok(Some(StreamEvent::Token { step, tier, .. })) => {
                    assert_eq!(step, expect_step,
                               "session {sid}: out-of-order step");
                    expect_step += 1;
                    if step > 0 && tier < top {
                        // a token emitted at a sub-top tier after
                        // prefill is an accepted draft riding the
                        // cheap tier
                        saw_draft_tier = true;
                    }
                }
                Ok(Some(StreamEvent::Done(stats))) => {
                    done += 1;
                    assert_eq!(stats.steps, steps);
                    assert_eq!(stats.tiers.len(), steps);
                }
                Ok(Some(StreamEvent::Shed(e))) => {
                    panic!("session {sid} shed on an open engine: {e}")
                }
                Ok(None) => break,
                Err(_) => panic!("session {sid} never terminated"),
            }
        }
        assert_eq!(expect_step, steps,
                   "session {sid}: {expect_step} of {steps} tokens");
        assert_eq!(done, 1, "exactly one terminal per stream");
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.stream_done.len(), 3);
    assert!(report.stream_shed.is_empty());
    assert!(report.spec_drafted > 0, "speculative engine must draft");
    assert_eq!(report.spec_drafted,
               report.spec_accepted + report.spec_rejected,
               "speculative ledger must reconcile");
    assert!(report.spec_accepted > 0,
            "20% divergence must still accept most drafts");
    assert!(saw_draft_tier,
            "accepted drafts must stream at the cheap draft tier");
    let sections = report.spec_sections();
    assert_eq!(sections.len(), 1);
    assert_eq!(sections[0].drafted,
               sections[0].accepted + sections[0].rejected);
    assert!(report.tokens_per_admission() > 1.0,
            "healthy acceptance must beat plain decode's 1.0, got {}",
            report.tokens_per_admission());
}

/// Executor that makes the draft and verify tiers *always* disagree:
/// the top tier argmaxes to token 0, every lower tier to token 1 — the
/// adversarial worst case for speculative decoding.
struct AlwaysRejectExec {
    batch: usize,
    seq_len: usize,
    top: f32,
}

impl Executor for AlwaysRejectExec {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn execute(&mut self, tier: f32, _tokens: &[i32])
               -> Result<ExecOutput> {
        let row: [f32; 2] = if tier >= self.top - 1e-6 {
            [1.0, 0.0] // verifier: token 0
        } else {
            [0.0, 1.0] // any draft tier: token 1
        };
        let mut logits = Vec::with_capacity(self.batch * 2);
        for _ in 0..self.batch {
            logits.extend_from_slice(&row);
        }
        Ok(ExecOutput { logits })
    }
}

#[test]
fn always_rejected_drafts_shrink_k_and_still_finish_every_session() {
    // the no-regret floor: with a verifier that rejects every single
    // proposal, sessions still finish (each verify emits the
    // verifier's own fallback token), and the per-class accept-rate
    // EWMA drags the adaptive k to its floor of 1 — so the wasted
    // drafting is bounded near one proposal per emitted token instead
    // of spec_k per token.
    let (batch, seq_len, spec_k) = (8usize, 16usize, 4usize);
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_spec_k(spec_k)
        .with_max_batch_wait(Duration::from_millis(1));
    let top = cfg.capacities()[0];
    let engine = ElasticEngine::start(cfg, move |_| {
        Ok(Box::new(AlwaysRejectExec { batch, seq_len, top })
            as Box<dyn Executor>)
    })
    .unwrap();
    let (sessions, steps) = (3usize, 8usize);
    let streams: Vec<_> = (0..sessions as u64)
        .map(|id| {
            engine.submit_stream(StreamRequest::new(id, vec![1; 4], steps))
        })
        .collect();
    for s in streams {
        let stats = s.wait().expect(
            "total rejection must degrade to plain decode, not kill \
             the session");
        assert_eq!(stats.steps, steps);
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.stream_done.len(), sessions);
    assert!(report.stream_shed.is_empty());
    assert_eq!(report.spec_accepted, 0, "nothing may be accepted");
    assert!(report.spec_drafted > 0);
    assert_eq!(report.spec_drafted, report.spec_rejected,
               "total rejection: drafted == rejected");
    // every verify emits exactly one fallback token, so there are at
    // most (steps - 1) cycles per session; the first cycles may draft
    // up to spec_k before the EWMA reacts, every later cycle drafts
    // the floor of 1 — comfortably under the all-spec_k worst case
    let cycles = sessions * (steps - 1);
    assert!(report.spec_drafted < cycles * spec_k,
            "adaptive k never shrank: {} drafted over {} cycles \
             at ceiling {}",
            report.spec_drafted, cycles, spec_k);
    assert!(report.spec_drafted <= cycles + sessions * spec_k,
            "draft waste must be bounded near one per cycle, got {} \
             over {} cycles", report.spec_drafted, cycles);
}

#[test]
fn chaos_fleet_absorbs_faults_with_high_availability() {
    // robustness acceptance: 10% injected transient fault rate plus a
    // deterministic poison request, speculative decode on.  The fleet
    // must absorb every fault without ever closing — every innocent
    // submission resolves served, only the poison is quarantined as
    // Poisoned — and availability stays >= 0.99.
    let spec = SimSpec {
        batch: 4,
        seq_len: 16,
        divergence: 0.1,
        fault: FaultPlan {
            fail_p: 0.1,
            tier_bias: 0.5,
            poison_token: 661,
            ..FaultPlan::default()
        },
        ..SimSpec::instant()
    };
    let (n, sessions, steps, spec_k) = (120usize, 8usize, 6usize, 3usize);
    // faults_point itself asserts the hard contracts: no one-shot may
    // resolve anything but Ok (or Poisoned, for the poison id only),
    // every session must run its full budget, and the stream logs must
    // reconcile — any engine closure under way fails it
    let report = sim::faults_point(spec, 4, 4, n, sessions, steps, spec_k)
        .expect("the fleet must absorb injected faults without an outage");
    let submitted = n + sessions;
    let served = report.completions.len() + report.stream_done.len();
    let availability = served as f64 / submitted as f64;
    assert!(availability >= 0.99,
            "availability {availability:.4} under 10% faults \
             ({served}/{submitted})");
    assert_eq!(report.completions.len(), n - 1,
               "exactly the poison one-shot is lost");
    assert_eq!(report.stream_done.len(), sessions,
               "every decode session finished");
    // the quarantine is visible in the shed log with its own cause
    assert!(report.sheds.iter().any(|s| s.cause == ShedCause::Poisoned),
            "poison shed missing from the log: {:?}", report.sheds);
    // and the fault ladder's work is accounted per class
    let faults = report.fault_sections();
    assert!(!faults.is_empty(), "chaos must leave fault sections");
    let retries: usize = faults.iter().map(|f| f.retries).sum();
    let poisoned: usize = faults.iter().map(|f| f.poisoned).sum();
    assert!(retries > 0, "10% fault rate must exercise the retry ladder");
    assert!(poisoned >= 1, "the poison unit must be counted");
    // the speculative ledger still reconciles under chaos
    assert_eq!(report.spec_drafted,
               report.spec_accepted + report.spec_rejected,
               "chaos must not corrupt the speculative ledger");
}

/// Executor whose *bottom* draft rung always disagrees with the
/// verifier while every higher rung always agrees — the accept-rate
/// signal that draft-tier escalation is judged by.
struct RungSensitiveExec {
    batch: usize,
    seq_len: usize,
    bottom: f32,
}

impl Executor for RungSensitiveExec {
    fn batch(&self) -> usize {
        self.batch
    }
    fn seq_len(&self) -> usize {
        self.seq_len
    }
    fn execute(&mut self, tier: f32, _tokens: &[i32])
               -> Result<ExecOutput> {
        let row: [f32; 2] = if (tier - self.bottom).abs() < 1e-6 {
            [0.0, 1.0] // bottom rung: token 1 — always rejected
        } else {
            [1.0, 0.0] // any higher rung (and the verifier): token 0
        };
        let mut logits = Vec::with_capacity(self.batch * 2);
        for _ in 0..self.batch {
            logits.extend_from_slice(&row);
        }
        Ok(ExecOutput { logits })
    }
}

#[test]
fn low_accept_rate_escalates_draft_tier_one_rung() {
    // draft-tier feedback: with the bottom rung's proposals always
    // rejected, the per-class accept-rate EWMA collapses and the
    // drafter must move one rung up — where proposals agree and are
    // accepted.  Any accepted proposal at the second-lowest tier is
    // proof of the escalation (the bottom rung can never be accepted
    // by construction).
    let (batch, seq_len, spec_k) = (8usize, 16usize, 3usize);
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_spec_k(spec_k)
        .with_max_batch_wait(Duration::from_millis(1));
    let caps = cfg.capacities();
    let bottom = *caps.last().unwrap();
    let second = caps[caps.len() - 2];
    let engine = ElasticEngine::start(cfg, move |_| {
        Ok(Box::new(RungSensitiveExec { batch, seq_len, bottom })
            as Box<dyn Executor>)
    })
    .unwrap();
    let (sessions, steps) = (3usize, 12usize);
    let streams: Vec<_> = (0..sessions as u64)
        .map(|id| {
            engine.submit_stream(StreamRequest::new(id, vec![1; 4], steps))
        })
        .collect();
    let mut saw_escalated_accept = false;
    for s in streams {
        let sid = s.id();
        let mut got = 0usize;
        loop {
            match s.recv_timeout(Duration::from_secs(30)) {
                Ok(Some(StreamEvent::Token { step, tier, .. })) => {
                    got += 1;
                    // post-prefill tokens are either accepted drafts
                    // (emitted at the draft tier) or verifier fallback
                    // tokens (top tier); the second-lowest rung can
                    // only mean an accepted escalated draft
                    if step > 0 && (tier - second).abs() < 1e-6 {
                        saw_escalated_accept = true;
                    }
                }
                Ok(Some(StreamEvent::Done(stats))) => {
                    assert_eq!(stats.steps, steps);
                }
                Ok(Some(StreamEvent::Shed(e))) => {
                    panic!("session {sid} shed on an open engine: {e}")
                }
                Ok(None) => break,
                Err(_) => panic!("session {sid} never terminated"),
            }
        }
        assert_eq!(got, steps, "session {sid} truncated");
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.stream_done.len(), sessions);
    assert!(report.spec_rejected > 0,
            "bottom-rung drafts must be rejected first");
    assert!(report.spec_accepted > 0,
            "escalated drafts at tier {second} must be accepted — the \
             accept-rate feedback never escalated");
    assert!(saw_escalated_accept,
            "accepted drafts must stream at the escalated rung");
    assert_eq!(report.spec_drafted,
               report.spec_accepted + report.spec_rejected);
}

#[test]
fn four_workers_at_least_double_one_worker_throughput() {
    // acceptance gate: same synthetic load, 4 workers vs 1 — requests
    // per wall-second must at least double.  depth_per_tier is huge so
    // both runs serve tier 1.0 and per-batch cost is identical.
    let spec = SimSpec {
        batch: 8,
        base_ms: 1.5,
        ms_per_capacity: 0.5,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let n = 256;
    let run_with = |workers: usize| -> ServeReport {
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_bound(64)
            .with_depth_per_tier(1e9)
            .with_max_batch_wait(Duration::from_millis(1));
        let caps = cfg.capacities();
        let engine =
            ElasticEngine::start(cfg, sim::factory(spec, caps)).unwrap();
        let responses: Vec<Response> = (0..n as u64)
            .map(|id| {
                engine.submit(Request::new(id, sim_tokens(id, spec.seq_len)))
            })
            .collect();
        for r in responses {
            r.wait().unwrap();
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.completions.len(), n);
        assert_ids_exactly_once(&report, n);
        report
    };
    let one = run_with(1);
    let four = run_with(4);
    // all four workers actually executed work
    assert!(four.worker_counts().iter().all(|&c| c > 0),
            "idle worker: {:?}", four.worker_counts());
    let speedup = four.throughput_rps() / one.throughput_rps().max(1e-9);
    assert!(speedup >= 2.0,
            "4 workers only {speedup:.2}x of 1 worker \
             ({:.0} vs {:.0} req/s)",
            four.throughput_rps(), one.throughput_rps());
}
