//! Hermetic end-to-end serving tests over the deterministic
//! `SimExecutor` — no artifacts, no XLA runtime.  These exercise the
//! full admission → batch → tier-select → execute → complete pipeline
//! that `tests/integration.rs` can only reach after `make artifacts`:
//! light load serves the top tier, sustained overload sheds capacity,
//! the drain path completes every admitted request, and N workers beat
//! one worker on wall-clock.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use elastiformer::coordinator::serving::{
    sim, ElasticServer, Request, ServeConfig, ServeReport, SimSpec,
};

fn sim_tokens(id: u64, seq_len: usize) -> Vec<i32> {
    (0..seq_len).map(|i| ((id as usize + i) % 97) as i32).collect()
}

/// Producer thread sending `n` requests with a fixed inter-arrival gap.
fn producer(n: usize, seq_len: usize, gap: Duration)
            -> mpsc::Receiver<Request> {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        for id in 0..n as u64 {
            let req = Request {
                id,
                tokens: sim_tokens(id, seq_len),
                submitted: Instant::now(),
            };
            if tx.send(req).is_err() {
                return;
            }
            if !gap.is_zero() {
                std::thread::sleep(gap);
            }
        }
    });
    rx
}

fn assert_ids_exactly_once(report: &ServeReport, n: usize) {
    let mut ids: Vec<u64> =
        report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(),
               "requests dropped or duplicated");
}

#[test]
fn light_load_serves_top_tier() {
    // arrivals far slower than service: the backlog never builds, so
    // requests run at capacity 1.0 (teacher-exact under §4.1).  The
    // assertions leave slack for scheduler stalls on loaded CI runners
    // (a descheduled worker briefly fakes a backlog the controller is
    // *supposed* to react to): a majority at the top tier + a high mean
    // still cleanly separates "healthy under light load" from a
    // controller that sheds spuriously (which floors near the bottom
    // tier and fails both).
    let spec = SimSpec {
        batch: 4,
        base_ms: 0.2,
        ms_per_capacity: 0.3,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_depth_per_tier(8.0)
        .with_max_batch_wait(Duration::from_millis(5));
    let caps = cfg.capacities();
    let server = ElasticServer::new(cfg);
    let n = 60;
    let rx = producer(n, spec.seq_len, Duration::from_millis(2));
    let report = server.run(sim::factory(spec, caps), rx, n).unwrap();
    assert_eq!(report.completions.len(), n);
    assert_ids_exactly_once(&report, n);
    let full = report
        .completions
        .iter()
        .filter(|c| c.tier == 1.0)
        .count();
    assert!(full * 2 >= n,
            "light load shed capacity on {} of {n}: tier counts {:?}",
            n - full, report.tier_counts);
    assert!(report.mean_capacity() >= 0.7,
            "mean capacity {:.3} under light load",
            report.mean_capacity());
}

#[test]
fn sustained_overload_sheds_to_lower_tiers() {
    // flood arrivals into a small queue with an aggressive shed ladder:
    // the controller must observe the standing backlog and drop tiers
    let spec = SimSpec {
        batch: 2,
        base_ms: 1.0,
        ms_per_capacity: 1.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim()
        .with_workers(1)
        .with_queue_bound(32)
        .with_depth_per_tier(2.0)
        .with_max_batch_wait(Duration::from_millis(1));
    let caps = cfg.capacities();
    let lowest = *caps.last().unwrap();
    let server = ElasticServer::new(cfg);
    let n = 96;
    let rx = producer(n, spec.seq_len, Duration::ZERO);
    let report = server.run(sim::factory(spec, caps), rx, n).unwrap();
    assert_eq!(report.completions.len(), n);
    assert_ids_exactly_once(&report, n);
    let shed = report
        .completions
        .iter()
        .filter(|c| c.tier < 1.0)
        .count();
    assert!(shed > n / 4,
            "only {shed}/{n} shed under flood; tiers {:?}",
            report.tier_counts);
    assert!(report.mean_capacity() < 1.0);
    assert!(report.completions.iter().any(|c| c.tier <= lowest + 1e-6),
            "sustained overload never reached the lowest tier: {:?}",
            report.tier_counts);
}

#[test]
fn drain_completes_every_admitted_request() {
    // producer dies early (channel disconnect before `expected`): the
    // engine must close the queue and drain every admitted request,
    // including a final partial batch (37 % 4 != 0)
    let spec = SimSpec {
        batch: 4,
        base_ms: 0.1,
        ms_per_capacity: 0.1,
        jitter_ms: 0.05,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim().with_workers(2);
    let caps = cfg.capacities();
    let server = ElasticServer::new(cfg);
    let sent = 37;
    let rx = producer(sent, spec.seq_len, Duration::ZERO);
    let report = server
        .run(sim::factory(spec, caps), rx, 1000 /* never reached */)
        .unwrap();
    assert_eq!(report.completions.len(), sent,
               "drain lost admitted requests");
    assert_ids_exactly_once(&report, sent);
    // batch accounting: every completion records a plausible batch size
    assert!(report.completions.iter().all(
        |c| c.batch_size >= 1 && c.batch_size <= 4));
}

#[test]
fn four_workers_at_least_double_one_worker_throughput() {
    // acceptance gate: same synthetic load, 4 workers vs 1 — requests
    // per wall-second must at least double.  depth_per_tier is huge so
    // both runs serve tier 1.0 and per-batch cost is identical.
    let spec = SimSpec {
        batch: 8,
        base_ms: 1.5,
        ms_per_capacity: 0.5,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let n = 256;
    let run_with = |workers: usize| -> ServeReport {
        let cfg = ServeConfig::sim()
            .with_workers(workers)
            .with_queue_bound(64)
            .with_depth_per_tier(1e9)
            .with_max_batch_wait(Duration::from_millis(1));
        let caps = cfg.capacities();
        let server = ElasticServer::new(cfg);
        let rx = producer(n, spec.seq_len, Duration::ZERO);
        let report = server.run(sim::factory(spec, caps), rx, n).unwrap();
        assert_eq!(report.completions.len(), n);
        assert_ids_exactly_once(&report, n);
        report
    };
    let one = run_with(1);
    let four = run_with(4);
    // all four workers actually executed work
    assert!(four.worker_counts().iter().all(|&c| c > 0),
            "idle worker: {:?}", four.worker_counts());
    let speedup = four.throughput_rps() / one.throughput_rps().max(1e-9);
    assert!(speedup >= 2.0,
            "4 workers only {speedup:.2}x of 1 worker \
             ({:.0} vs {:.0} req/s)",
            four.throughput_rps(), one.throughput_rps());
}

#[test]
fn expected_count_caps_admission() {
    // the engine admits exactly `expected` requests even when producers
    // keep sending; admission is FIFO, so the first `expected` ids win
    let spec = SimSpec {
        batch: 4,
        base_ms: 0.0,
        ms_per_capacity: 0.0,
        jitter_ms: 0.0,
        ..SimSpec::standard()
    };
    let cfg = ServeConfig::sim().with_workers(2);
    let caps = cfg.capacities();
    let server = ElasticServer::new(cfg);
    let sent = 50;
    let expected = 30;
    // pre-buffer every request so all 50 are available to admit
    let (tx, rx) = mpsc::channel();
    for id in 0..sent as u64 {
        tx.send(Request {
            id,
            tokens: sim_tokens(id, spec.seq_len),
            submitted: Instant::now(),
        })
        .unwrap();
    }
    drop(tx);
    let report =
        server.run(sim::factory(spec, caps), rx, expected).unwrap();
    assert_eq!(report.completions.len(), expected);
    assert_ids_exactly_once(&report, expected);
}
