//! Helpers shared by the integration-test crates (each declares
//! `mod common;`).  Cargo does not build `tests/common/` as its own
//! test target — only direct `tests/*.rs` files.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use elastiformer::coordinator::serving::{
    ExecOutput, Executor, SimExecutor, SimSpec,
};

/// Sim executor that counts its executed batches — lets
/// heterogeneous-fleet tests *know* (not hope) that a given worker
/// class participated before asserting on its learned estimates.
pub struct CountingSim {
    inner: SimExecutor,
    count: Arc<AtomicUsize>,
}

impl Executor for CountingSim {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput> {
        let out = self.inner.execute(tier, tokens)?;
        self.count.fetch_add(1, Ordering::SeqCst);
        Ok(out)
    }

    fn supports(&self, tier: f32) -> bool {
        self.inner.supports(tier)
    }

    fn name(&self) -> &'static str {
        "counting-sim"
    }

    fn note_batch_mix(&mut self, recompute_rows: usize,
                      cached_rows: usize) {
        self.inner.note_batch_mix(recompute_rows, cached_rows);
    }
}

/// Worker-class executor factory over [`CountingSim`]: one fresh
/// counting sim executor per worker, all feeding one shared counter.
pub fn counting_factory(spec: SimSpec, caps: Vec<f32>,
                        count: Arc<AtomicUsize>)
                        -> impl Fn(usize) -> Result<Box<dyn Executor>>
                            + Send + Sync + 'static {
    move |worker| {
        Ok(Box::new(CountingSim {
            inner: SimExecutor::new(spec, &caps, worker).record_log(false),
            count: count.clone(),
        }) as Box<dyn Executor>)
    }
}
