//! Integration tests over the real AOT artifacts (requires
//! `make artifacts`; every test skips gracefully when artifacts are
//! absent so `cargo test` stays green in a fresh checkout).
//!
//! These exercise the full stack: HLO text -> PJRT compile -> execute,
//! the §4.1 equivalence oracle end-to-end, training-step semantics, and
//! the serving engine.  The whole suite is compiled out without the
//! `pjrt` feature (the default build enables it): everything here needs
//! the runtime layer the feature gates.
#![cfg(feature = "pjrt")]

use elastiformer::coordinator::serving::{
    CapacityController, ElasticEngine, Request, Response, ServeConfig,
    XlaExecutor,
};
use elastiformer::coordinator::trainer::{Caps, Trainer};
use elastiformer::data::{mathgen, Tokenizer};
use elastiformer::runtime::client::Arg;
use elastiformer::runtime::Runtime;

fn artifacts_dir() -> Option<String> {
    for cand in ["artifacts", "../artifacts"] {
        if std::path::Path::new(cand).join("lm_tiny/manifest.json").exists() {
            return Some(cand.to_string());
        }
    }
    None
}

/// Artifacts on disk are necessary but not sufficient: the default
/// build resolves `xla` to the in-tree stub, whose PJRT client always
/// errors.  Probe it (once per process) so these tests skip instead of
/// panicking on stub builds even when `make artifacts` has run.
fn backend_available() -> bool {
    static AVAILABLE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *AVAILABLE.get_or_init(|| xla::PjRtClient::cpu().is_ok())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => {
                if !backend_available() {
                    eprintln!("skipping: xla backend unavailable \
                               (stub build — vendor real xla-rs)");
                    return;
                }
                d
            }
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn runtime(config: &str) -> Runtime {
    Runtime::load(&artifacts_dir().unwrap(), config).unwrap()
}

fn token_batch(rt: &Runtime, seed: u64) -> Vec<i32> {
    let tok = Tokenizer::new();
    let b = rt.manifest.batch();
    let t = rt.manifest.seq_len();
    let problems = mathgen::dataset(b, seed);
    let mut flat = Vec::with_capacity(b * t);
    for p in &problems {
        flat.extend(tok.encode_padded(&p.full_text(), t));
    }
    flat
}

#[test]
fn all_entries_compile_on_pjrt() {
    // The hard contract: every lowered artifact of every config must parse
    // under xla_extension 0.5.1's HLO text parser and compile on the CPU
    // PJRT client.  (Guards against ops like `topk` / batched-operand
    // gathers that post-date the runtime.)
    require_artifacts!();
    for config in ["lm_tiny", "vit_tiny", "vlm_tiny"] {
        let rt = runtime(config);
        let entries: Vec<String> =
            rt.manifest.entries.keys().cloned().collect();
        let refs: Vec<&str> = entries.iter().map(|s| s.as_str()).collect();
        rt.warmup(&refs)
            .unwrap_or_else(|e| panic!("{config}: {e:#}"));
    }
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    require_artifacts!();
    let rt = runtime("lm_tiny");
    let trainer = Trainer::new(&rt);
    let a = trainer.init_params("init", 7).unwrap();
    let b = trainer.init_params("init", 7).unwrap();
    let c = trainer.init_params("init", 8).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert_eq!(a.len(), rt.manifest.teacher_params.total());
    assert!(a.iter().all(|x| x.is_finite()));
}

#[test]
fn equivalence_capacity_one_through_full_stack() {
    // §4.1: bypass-mode elastic forward == teacher forward, bit-for-bit up
    // to fp reassociation, measured through PJRT (not jax).
    require_artifacts!();
    let rt = runtime("lm_tiny");
    let trainer = Trainer::new(&rt);
    let params = trainer.init_params("init", 1).unwrap();
    let router = trainer.init_params("router_init_r8", 2).unwrap();
    let tokens = token_batch(&rt, 3);
    let l = rt.manifest.n_layers();
    let h = rt.manifest.n_heads();

    let head_mask = vec![1.0f32; l * h];
    let ones = vec![1.0f32; l];
    let t_out = rt
        .exec("teacher_forward", &[
            Arg::F32(&params),
            Arg::I32(&tokens),
            Arg::F32(&head_mask),
            Arg::F32(&ones),
            Arg::F32(&ones),
        ])
        .unwrap();
    let t_logits = t_out.f32(0).unwrap();

    let caps = Caps::full();
    let e_out = rt
        .exec("elastic_forward_r8", &[
            Arg::F32(&params),
            Arg::F32(&router),
            Arg::I32(&tokens),
            Arg::F32(&caps.0),
            Arg::F32(&ones),
            Arg::ScalarF32(2.0), // bypass
        ])
        .unwrap();
    let e_logits = e_out.f32(0).unwrap();
    let max_diff = t_logits
        .iter()
        .zip(&e_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "equivalence violated: max diff {max_diff}");

    // serve tier 1.0 must match too
    let router0 = trainer.init_params("router_init_r0", 2).unwrap();
    let s_out = rt
        .exec("serve_cap100", &[
            Arg::F32(&params),
            Arg::F32(&router0),
            Arg::I32(&tokens),
        ])
        .unwrap();
    let s_logits = s_out.f32(0).unwrap();
    let max_diff_s = t_logits
        .iter()
        .zip(&s_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff_s < 1e-4, "serve tier 1.0 differs: {max_diff_s}");
}

#[test]
fn pretrain_steps_reduce_loss() {
    require_artifacts!();
    let rt = runtime("lm_tiny");
    let mut trainer = Trainer::new(&rt);
    let init = trainer.init_params("init", 11).unwrap();
    let mut seed = 100u64;
    let (_, losses) = trainer
        .pretrain("pretrain_step", init, 25, 3e-3, || {
            seed += 1;
            vec![elastiformer::coordinator::trainer::BatchArg::Tokens(
                token_batch(&rt, seed))]
        })
        .unwrap();
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first - 0.2,
            "pretrain did not learn: {first:.3} -> {last:.3}");
}

#[test]
fn distill_reduces_distill_loss_and_respects_capacity() {
    require_artifacts!();
    let rt = runtime("lm_tiny");
    let mut trainer = Trainer::new(&rt);
    let teacher = trainer.init_params("init", 21).unwrap();
    let router = trainer.init_params("router_init_r0", 22).unwrap();
    let l = rt.manifest.n_layers();
    let caps = Caps([0.75, 0.5, 1.0, 0.5]);
    let layer_en = vec![1.0f32; l];
    let mut seed = 200u64;
    let (router2, hist) = trainer
        .distill_lm("distill_step_r0", &teacher, &teacher, router.clone(),
                    20, 2e-3, caps, &layer_en, 1.0, || {
                        seed += 1;
                        token_batch(&rt, seed)
                    })
        .unwrap();
    assert_eq!(router2.len(), router.len());
    assert!(hist.last().unwrap().distill < hist.first().unwrap().distill,
            "distill loss did not move down");
    // fraction of MLP tokens selected must track the capacity (0.5)
    let frac = hist.last().unwrap().frac_tokens;
    assert!((frac - 0.5).abs() < 0.05, "frac_tokens {frac} vs cap 0.5");
}

#[test]
fn elastic_forward_stats_respect_topk_counts() {
    require_artifacts!();
    let rt = runtime("lm_tiny");
    let trainer = Trainer::new(&rt);
    let params = trainer.init_params("init", 31).unwrap();
    let router = trainer.init_params("router_init_r0", 32).unwrap();
    let tokens = token_batch(&rt, 33);
    let l = rt.manifest.n_layers();
    let t = rt.manifest.seq_len();
    let b = rt.manifest.batch();
    let ones = vec![1.0f32; l];
    let caps = Caps([0.5, 0.25, 1.0, 1.0]);
    let out = rt
        .exec("elastic_forward_r0", &[
            Arg::F32(&params),
            Arg::F32(&router),
            Arg::I32(&tokens),
            Arg::F32(&caps.0),
            Arg::F32(&ones),
            Arg::ScalarF32(0.0),
        ])
        .unwrap();
    let m_mha = out.f32(4).unwrap(); // [B, L, T]
    let m_mlp = out.f32(5).unwrap();
    for bi in 0..b {
        for li in 0..l {
            let row = &m_mha[(bi * l + li) * t..(bi * l + li + 1) * t];
            let count: f32 = row.iter().sum();
            assert_eq!(count as usize, t / 2,
                       "mha mask count {count} != {}", t / 2);
            let row2 = &m_mlp[(bi * l + li) * t..(bi * l + li + 1) * t];
            let count2: f32 = row2.iter().sum();
            assert_eq!(count2 as usize, t / 4);
        }
    }
}

#[test]
fn serve_tiers_run_and_lower_capacity_changes_output() {
    require_artifacts!();
    let rt = runtime("lm_tiny");
    let trainer = Trainer::new(&rt);
    let params = trainer.init_params("init", 41).unwrap();
    let router = trainer.init_params("router_init_r0", 42).unwrap();
    let tokens = token_batch(&rt, 43);
    let mut outs = Vec::new();
    for entry in ["serve_cap100", "serve_cap50", "serve_cap25"] {
        let out = rt
            .exec(entry, &[
                Arg::F32(&params),
                Arg::F32(&router),
                Arg::I32(&tokens),
            ])
            .unwrap();
        outs.push(out.f32(0).unwrap());
    }
    assert!(outs[0].iter().zip(&outs[1]).any(|(a, b)| (a - b).abs() > 1e-3),
            "cap 0.5 identical to cap 1.0?");
    assert!(outs.iter().all(|o| o.iter().all(|x| x.is_finite())));
}

#[test]
fn serving_engine_end_to_end() {
    // full stack through the Executor trait: each worker thread loads
    // its own PJRT runtime via XlaExecutor::load (handles are not Send)
    let dir = require_artifacts!();
    let rt = runtime("lm_tiny");
    let trainer = Trainer::new(&rt);
    let params = trainer.init_params("init", 51).unwrap();
    let router = trainer.init_params("router_init_r0", 52).unwrap();
    let t = rt.manifest.seq_len();
    let cfg = ServeConfig::standard();
    let factory = XlaExecutor::factory(dir, "lm_tiny".to_string(), params,
                                       router, cfg.tiers.clone());
    let engine = ElasticEngine::start(cfg, factory).unwrap();
    let n = 24;
    let tok = Tokenizer::new();
    let responses: Vec<Response> = (0..n as u64)
        .map(|id| {
            let text = format!("request number {id}");
            engine.submit(Request::new(id, tok.encode_padded(&text, t)))
        })
        .collect();
    for r in responses {
        let reply = r.wait().unwrap();
        assert!(!reply.logits.is_empty(),
                "PJRT reply must deliver the request's logits row");
        assert!(reply.logits.iter().all(|x| x.is_finite()));
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.completions.len(), n);
    assert!(report.throughput_rps() > 0.0);
    let served: usize = report.tier_counts.iter().map(|(_, c)| c).sum();
    assert_eq!(served, n);
    // all ids served exactly once
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
}

#[test]
fn vit_bypass_cosine_is_one_through_stack() {
    require_artifacts!();
    let rt = runtime("vit_tiny");
    let trainer = Trainer::new(&rt);
    let params = trainer.init_params("init", 61).unwrap();
    let router = trainer.init_params("router_init", 62).unwrap();
    let b = rt.manifest.batch();
    let size = rt.manifest.cfg_usize("img_size").unwrap();
    let images: Vec<f32> =
        elastiformer::data::imagen::dataset(b, size, None, 63)
            .into_iter()
            .flat_map(|(im, _)| im)
            .collect();
    let l = rt.manifest.n_layers();
    let ones = vec![1.0f32; l];
    let caps = Caps::full();
    let out = rt
        .exec("elastic_forward", &[
            Arg::F32(&params),
            Arg::F32(&router),
            Arg::F32(&images),
            Arg::F32(&caps.0),
            Arg::F32(&ones),
            Arg::ScalarF32(2.0),
        ])
        .unwrap();
    let cos = out.f32(3).unwrap();
    for c in cos {
        assert!((c - 1.0).abs() < 1e-4, "bypass cosine {c}");
    }
}

#[test]
fn vlm_forward_and_mask_counts() {
    require_artifacts!();
    let rt = runtime("vlm_tiny");
    let trainer = Trainer::new(&rt);
    let params = trainer.init_params("init", 71).unwrap();
    let router = trainer.init_params("router_init_lin", 72).unwrap();
    let b = rt.manifest.batch();
    let n_img = rt.manifest.cfg_usize("n_img_tokens").unwrap();
    let text_len = rt.manifest.cfg_usize("text_len").unwrap();
    let size = rt.manifest.cfg_usize("img_size").unwrap();
    let images: Vec<f32> =
        elastiformer::data::imagen::dataset(b, size, None, 73)
            .into_iter()
            .flat_map(|(im, _)| im)
            .collect();
    let tok = Tokenizer::new();
    let texts: Vec<i32> = (0..b)
        .flat_map(|i| tok.encode_padded(&format!("caption {i}"), text_len))
        .collect();
    let out = rt
        .exec("elastic_forward_lin", &[
            Arg::F32(&params),
            Arg::F32(&router),
            Arg::F32(&images),
            Arg::I32(&texts),
            Arg::ScalarF32(0.5),
            Arg::ScalarF32(0.0),
        ])
        .unwrap();
    let mask = out.f32(3).unwrap(); // [B, n_img]
    for bi in 0..b {
        let count: f32 = mask[bi * n_img..(bi + 1) * n_img].iter().sum();
        assert_eq!(count as usize, n_img.div_ceil(2));
    }
}

#[test]
fn capacity_controller_property_monotone() {
    // in-repo property harness over the controller invariant
    elastiformer::proptest::check("controller_monotone", 50, |rng| {
        let n_tiers = 2 + rng.below(4);
        let tiers: Vec<f32> =
            (0..n_tiers).map(|i| 1.0 - 0.2 * i as f32).collect();
        let c = CapacityController::new(tiers, 1.0 + rng.f64() * 10.0);
        let mut prev = f32::INFINITY;
        let mut depth = 0.0f64;
        for step in 0..30 {
            depth += rng.f64() * 3.0; // monotone increasing load
            let t = c.tier_for_depth(depth);
            if t > prev + 1e-9 {
                return Err(format!("tier rose: {prev} -> {t} at {step}"));
            }
            prev = t;
        }
        Ok(())
    });
}
