//! Benchmark harness (criterion is not in the vendored crate set).
//!
//! `cargo bench` targets are `harness = false` binaries that drive this
//! module: warmup, timed iterations, and mean / p50 / p99 / throughput
//! reporting, plus a tabular printer used by the per-figure paper benches.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn throughput_per_s(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Runner with warmup and a soft time budget.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(5),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget: Duration::from_secs(2),
        }
    }

    /// Time `f` repeatedly; `f` should perform one full operation.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.budget && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p99: samples[(n * 99 / 100).min(n - 1)],
            min: samples[0],
            max: samples[n - 1],
        }
    }
}

/// Fixed-width table printer for bench/experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    /// Markdown is the same format; alias for call-site clarity.
    pub fn to_markdown(&self) -> String {
        self.to_string()
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

pub fn fmt_f(v: f64, prec: usize) -> String {
    format!("{:.*}", prec, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleepy_fn() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 3,
            max_iters: 5,
            budget: Duration::from_millis(100),
        };
        let r = b.run("sleep", || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.mean >= Duration::from_millis(2));
        assert!(r.iters >= 3);
        assert!(r.p50 <= r.p99);
        assert!(r.min <= r.max);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.to_string();
        assert!(s.contains("| a | long_header |"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["x"]);
        t.row(vec!["a,b\"c".into()]);
        assert_eq!(t.to_csv(), "x\n\"a,b\"\"c\"\n");
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
