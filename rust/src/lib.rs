//! ElastiFormer — learned redundancy reduction in transformers via
//! self-distillation (paper reproduction; see DESIGN.md).
//!
//! Layer 3 of the three-layer stack: the Rust coordinator owning training
//! orchestration, elastic serving, data, checkpoints and every experiment
//! driver.  Layers 1–2 (Pallas kernels + JAX model) are compiled AOT into
//! `artifacts/` and executed through [`runtime`].

// The whole coordinator is safe Rust (checked since PR 9); the
// invariant-lint layer and the ranked-lock discipline in [`sync`]
// assume safe-Rust semantics, so keep it that way permanently.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod eval;
#[cfg(feature = "pjrt")]
pub mod experiments;
pub mod json;
pub mod lint;
pub mod metrics;
pub mod proptest;
pub mod rng;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sync;
pub mod tensor;
