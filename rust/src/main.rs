//! ElastiFormer CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   exp <fig2|fig4|fig5|fig6|fig7|fig8|fig9|table1|qualitative|all>
//!       [--config C] [--steps N] [--pretrain-steps N] [--caps a,b,c]
//!       [--seed S]
//!   train-teacher  --config C [--steps N] [--seed S]
//!   distill        --config C [--steps N] [--caps a,b,c,d] [--rank R]
//!                  [--layers all|even] [--seed S]
//!   serve          --config C [--requests N] [--rate RPS] [--seed S]
//!   info           --config C
//!
//! Everything runs off the AOT artifacts in `artifacts/` (`make artifacts`).

use anyhow::{bail, Result};

use elastiformer::checkpoint::Checkpoint;
use elastiformer::cli::Args;
use elastiformer::coordinator::serving::{ElasticServer, Request, ServeConfig};
use elastiformer::coordinator::trainer::{layer_enable, Caps, Trainer};
use elastiformer::data::{mathgen, Batcher, TextDataset};
use elastiformer::experiments::{
    common, fig2, fig4, fig5, fig6, fig7, fig8, fig9, qualitative, table1,
};
use elastiformer::rng::Rng;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("exp") => cmd_exp(args),
        Some("train-teacher") => cmd_train_teacher(args),
        Some("distill") => cmd_distill(args),
        Some("serve") => cmd_serve(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
elastiformer — ElastiFormer reproduction (see DESIGN.md)

  elastiformer exp <id>            regenerate a paper figure/table
       ids: fig2 fig4 fig5 fig6 fig7 fig8 fig9 table1 qualitative all
       flags: --config C --steps N --pretrain-steps N --caps a,b,c --seed S
  elastiformer train-teacher --config lm_tiny --steps 300
  elastiformer distill --config lm_tiny --caps 0.75,0.75,1.0,0.5 --rank 1
  elastiformer serve --config lm_tiny --requests 64 --rate 100
  elastiformer info --config lm_tiny";

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let seed = args.u64_or("seed", 42)?;
    let run_one = |id: &str| -> Result<()> {
        println!("=== experiment {id} ===");
        match id {
            "fig2" => {
                let mut o = fig2::Fig2Opts { seed, ..Default::default() };
                if let Some(c) = args.str_opt("config") {
                    o.config = c.into();
                }
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                fig2::run(&o)?.print();
            }
            "fig4" => {
                let mut o = fig4::Fig4Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                fig4::run(&o)?.print();
            }
            "fig5" => {
                let mut o = fig5::Fig5Opts { seed, ..Default::default() };
                if let Some(c) = args.str_opt("config") {
                    o.config = c.into();
                }
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.caps = args.f64_list_or("caps", &o.caps)?;
                fig5::run(&o)?.print();
            }
            "fig6" => {
                let mut o = fig6::Fig6Opts { seed, ..Default::default() };
                if let Some(c) = args.str_opt("config") {
                    o.config = c.into();
                }
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.token_caps = args.f64_list_or("caps", &o.token_caps)?;
                fig6::run(&o)?.print();
            }
            "fig7" => {
                let mut o = fig7::Fig7Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.caps = args.f64_list_or("caps", &o.caps)?;
                fig7::run(&o)?.print();
            }
            "fig8" => {
                let mut o = fig8::Fig8Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.n_classes = args.usize_or("classes", o.n_classes)?;
                let (t, report) = fig8::run(&o)?;
                t.print();
                println!("{report}");
            }
            "fig9" => {
                let mut o = fig9::Fig9Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.caps = args.f64_list_or("caps", &o.caps)?;
                fig9::run(&o)?.print();
            }
            "table1" => {
                table1::run(&["lm_tiny", "lm_base", "vit_tiny", "vlm_tiny"])?
                    .print();
            }
            "qualitative" => {
                let mut o = qualitative::QualOpts { seed,
                                                    ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                qualitative::run(&o)?;
            }
            other => bail!("unknown experiment {other:?}"),
        }
        Ok(())
    };
    if id == "all" {
        for id in ["table1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8",
                   "fig9", "qualitative"] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(id)
    }
}

fn cmd_train_teacher(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let steps = args.usize_or("steps", 300)?;
    let seed = args.u64_or("seed", 42)?;
    let ctx = common::Ctx::load(config, seed)?;
    let params = ctx.teacher(steps)?;
    println!("teacher ready: {} params (cached under results/ckpt)",
             params.len());
    Ok(())
}

fn cmd_distill(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let steps = args.usize_or("steps", 100)?;
    let pretrain = args.usize_or("pretrain-steps", 300)?;
    let rank = args.usize_or("rank", 0)?;
    let seed = args.u64_or("seed", 42)?;
    let caps_v = args.f64_list_or("caps", &[0.75, 0.75, 1.0, 0.5])?;
    if caps_v.len() != 4 {
        bail!("--caps wants 4 comma-separated values");
    }
    let caps = Caps([caps_v[0] as f32, caps_v[1] as f32, caps_v[2] as f32,
                     caps_v[3] as f32]);
    let ctx = common::Ctx::load(config, seed)?;
    if ctx.rt.manifest.kind() != "lm" {
        bail!("distill subcommand currently drives LM configs; use \
               `exp fig7`/`exp fig9` for ViT/VLM distillation");
    }
    let teacher = ctx.teacher(pretrain)?;
    let layer_en = layer_enable(ctx.rt.manifest.n_layers(),
                                args.str_or("layers", "all"))?;
    let router = ctx.router_init(&format!("router_init_r{rank}"),
                                 seed as i32)?;
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let ds = TextDataset::from_texts(&common::gsm_train_texts(600, seed), t);
    let mut batcher = Batcher::new(ds.len(), b, seed);
    let mut trainer = Trainer::with_logger(
        &ctx.rt,
        common::results_dir().join("distill_log.jsonl").to_str().unwrap())?;
    let (router, hist) = trainer.distill_lm(
        &format!("distill_step_r{rank}"), &teacher, &teacher, router, steps,
        1e-3, caps, &layer_en, 1.0, || batcher.next_tokens(&ds))?;
    let out = common::results_dir().join(format!("{config}_router_r{rank}.bin"));
    Checkpoint::new(config, &format!("router_r{rank}"), steps as u64, router)
        .save(&out)?;
    println!("distilled {steps} steps: distill {:.4} -> {:.4}; router saved \
              to {out:?}",
             hist.first().map(|m| m.distill).unwrap_or(0.0),
             hist.last().map(|m| m.distill).unwrap_or(0.0));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let n_requests = args.usize_or("requests", 64)?;
    let rate = args.f64_or("rate", 100.0)?;
    let pretrain = args.usize_or("pretrain-steps", 300)?;
    let seed = args.u64_or("seed", 42)?;
    let ctx = common::Ctx::load(config, seed)?;
    let teacher = ctx.teacher(pretrain)?;
    let router = ctx.router_init("router_init_r0", seed as i32)?;
    let t = ctx.rt.manifest.seq_len();

    let mut server = ElasticServer::new(&ctx.rt, &teacher, &router,
                                        ServeConfig::standard())?;
    let (tx, rx) = std::sync::mpsc::channel();
    let producer = std::thread::spawn(move || {
        let tok = elastiformer::data::Tokenizer::new();
        let mut rng = Rng::new(seed ^ 0x5E12);
        for id in 0..n_requests as u64 {
            let p = mathgen::gen_problem(&mut rng);
            let req = Request {
                id,
                tokens: tok.encode_padded(&p.full_text(), t),
                submitted: std::time::Instant::now(),
            };
            if tx.send(req).is_err() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_secs_f64(
                1.0 / rate.max(1.0)));
        }
    });
    let report = server.run(rx, n_requests)?;
    producer.join().ok();
    println!("served {} requests in {:.2}s — {:.1} req/s, p50 {:.1} ms, \
              p99 {:.1} ms, mean capacity {:.2}",
             report.completions.len(), report.wall_secs,
             report.throughput_rps(), report.latency_p(0.5),
             report.latency_p(0.99), report.mean_capacity());
    for (tier, count) in &report.tier_counts {
        println!("  tier {tier:.2}: {count} requests");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let ctx = common::Ctx::load(config, 0)?;
    let m = &ctx.rt.manifest;
    println!("config {} (kind {})", m.name(), m.kind());
    println!("  teacher params: {}", m.teacher_params.total());
    for (k, t) in &m.router_params {
        println!("  router table {k}: {} params", t.total());
    }
    println!("  entries:");
    for (name, e) in &m.entries {
        println!("    {name} ({} args, {} outputs)", e.args.len(),
                 e.outputs.len());
    }
    if let Ok(dims) = m.dims() {
        use elastiformer::analysis::flops::{self, Capacity};
        let t = flops::teacher_macs(&dims);
        println!("  teacher MACs/seq: {t}");
        for c in [0.75, 0.5, 0.25] {
            let e = flops::elastic_macs(&dims, &Capacity::uniform(c));
            println!("  elastic@{c}: {e} ({:.1}% of teacher)",
                     100.0 * e as f64 / t as f64);
        }
    }
    Ok(())
}
