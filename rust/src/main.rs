//! ElastiFormer CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   exp <fig2|fig4|fig5|fig6|fig7|fig8|fig9|table1|qualitative|all>
//!       [--config C] [--steps N] [--pretrain-steps N] [--caps a,b,c]
//!       [--seed S]
//!   train-teacher  --config C [--steps N] [--seed S]
//!   distill        --config C [--steps N] [--caps a,b,c,d] [--rank R]
//!                  [--layers all|even] [--seed S]
//!   serve          --config C [--requests N] [--rate RPS] [--workers W]
//!                  [--seed S]
//!   serve-sim      [--requests N] [--rates a,b,c] [--workers W]
//!                  [--batch B] [--seq-len T] [--queue-bound Q]
//!                  [--queue-shards K] [--depth-per-tier D] [--seed S]
//!                  [--worker-classes fast=2:slow=2@4]
//!                  [--stream N] [--decode-steps K]
//!                  [--spec-k K] [--divergence D] [--fault-rate P]
//!                  [--trace FILE] [--snapshot-every-ms N]
//!   info           --config C
//!
//! Everything except `serve-sim` runs off the AOT artifacts in
//! `artifacts/` (`make artifacts`); `serve-sim` drives the full serving
//! pipeline hermetically through the deterministic `SimExecutor`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use anyhow::{bail, Result};

use elastiformer::cli::Args;
use elastiformer::coordinator::serving::{
    sim, trace_export, Admission, ElasticEngine, EngineHandle,
    EngineSnapshot, Request, Response, ServeConfig, ServeError,
    ServeReport, SimSpec, StreamRequest,
};
use elastiformer::rng::Rng;

#[cfg(feature = "pjrt")]
use elastiformer::checkpoint::Checkpoint;
#[cfg(feature = "pjrt")]
use elastiformer::coordinator::serving::XlaExecutor;
#[cfg(feature = "pjrt")]
use elastiformer::coordinator::trainer::{layer_enable, Caps, Trainer};
#[cfg(feature = "pjrt")]
use elastiformer::data::{mathgen, Batcher, TextDataset};
#[cfg(feature = "pjrt")]
use elastiformer::experiments::{
    common, fig2, fig4, fig5, fig6, fig7, fig8, fig9, qualitative, table1,
};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("exp") => cmd_exp(args),
        Some("train-teacher") => cmd_train_teacher(args),
        Some("distill") => cmd_distill(args),
        Some("serve") => cmd_serve(args),
        Some("serve-sim") => cmd_serve_sim(args),
        Some("info") => cmd_info(args),
        Some(other) => bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
elastiformer — ElastiFormer reproduction (see DESIGN.md)

  elastiformer exp <id>            regenerate a paper figure/table
       ids: fig2 fig4 fig5 fig6 fig7 fig8 fig9 table1 qualitative all
       flags: --config C --steps N --pretrain-steps N --caps a,b,c --seed S
  elastiformer train-teacher --config lm_tiny --steps 300
  elastiformer distill --config lm_tiny --caps 0.75,0.75,1.0,0.5 --rank 1
  elastiformer serve --config lm_tiny --requests 64 --rate 100 --workers 1
  elastiformer serve-sim --requests 512 --rates 250,1000,4000 --workers 4
       flags: --batch B --seq-len T --queue-bound Q --depth-per-tier D
              --worker-classes name=count[@latency-mult]:...
              (e.g. fast=2:slow=2@4 — a heterogeneous fleet with
               per-class capacity controllers; overrides --workers)
              --stream N --decode-steps K
              (N streaming decode sessions of K tokens each ride along
               with the one-shot load — continuous batching with
               per-step tier decisions; per-class stream report lines)
              --arena-pages P
              (session-arena pages per worker class: cached decode
               windows with shard-affine placement; 0 disables the
               arena — every decode step recomputes its window)
              --spec-k K --divergence D
              (speculative decode: each session drafts up to K tokens
               per admission at the cheapest floored tier and verifies
               them in one top-tier pass; K adapts to the learned
               accept rate.  D in [0,1] makes floored tiers disagree
               with the verifier, scaled by tier distance; 0 = always
               agree)
              --fault-rate P
              (chaos injection: per-execute transient failure
               probability in the sim backend, skewed toward cheap
               tiers.  The fault ladder retries with backoff, bisects
               still-failing batches, and quarantines poison requests;
               survived faults land in the report's fault sections)
              --trace FILE
              (flight recorder: record request-lifecycle events and
               write Chrome trace_event JSON to FILE after each rate
               point — open at chrome://tracing or ui.perfetto.dev;
               with several --rates the file holds the last point)
              --snapshot-every-ms N
              (print a live engine snapshot — queue depth, served/shed,
               per-class latency percentiles, breaker states — every
               N ms while the point runs; 0 disables)
  elastiformer info --config lm_tiny";

/// The artifact-backed subcommands need the PJRT runtime layer; when
/// the `pjrt` feature is off they compile to a clear error instead of
/// silently vanishing from the CLI.
#[cfg(not(feature = "pjrt"))]
fn needs_pjrt(what: &str) -> Result<()> {
    bail!("`{what}` needs the PJRT runtime layer, but this binary was \
           built without the `pjrt` feature; rebuild with \
           `--features pjrt` (default builds enable it)")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_exp(_args: &Args) -> Result<()> {
    needs_pjrt("exp")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_teacher(_args: &Args) -> Result<()> {
    needs_pjrt("train-teacher")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_distill(_args: &Args) -> Result<()> {
    needs_pjrt("distill")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) -> Result<()> {
    needs_pjrt("serve")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_info(_args: &Args) -> Result<()> {
    needs_pjrt("info")
}

#[cfg(feature = "pjrt")]
fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let seed = args.u64_or("seed", 42)?;
    let run_one = |id: &str| -> Result<()> {
        println!("=== experiment {id} ===");
        match id {
            "fig2" => {
                let mut o = fig2::Fig2Opts { seed, ..Default::default() };
                if let Some(c) = args.str_opt("config") {
                    o.config = c.into();
                }
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                fig2::run(&o)?.print();
            }
            "fig4" => {
                let mut o = fig4::Fig4Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                fig4::run(&o)?.print();
            }
            "fig5" => {
                let mut o = fig5::Fig5Opts { seed, ..Default::default() };
                if let Some(c) = args.str_opt("config") {
                    o.config = c.into();
                }
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.caps = args.f64_list_or("caps", &o.caps)?;
                fig5::run(&o)?.print();
            }
            "fig6" => {
                let mut o = fig6::Fig6Opts { seed, ..Default::default() };
                if let Some(c) = args.str_opt("config") {
                    o.config = c.into();
                }
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.token_caps = args.f64_list_or("caps", &o.token_caps)?;
                fig6::run(&o)?.print();
            }
            "fig7" => {
                let mut o = fig7::Fig7Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.caps = args.f64_list_or("caps", &o.caps)?;
                fig7::run(&o)?.print();
            }
            "fig8" => {
                let mut o = fig8::Fig8Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.n_classes = args.usize_or("classes", o.n_classes)?;
                let (t, report) = fig8::run(&o)?;
                t.print();
                println!("{report}");
            }
            "fig9" => {
                let mut o = fig9::Fig9Opts { seed, ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                o.pretrain_steps =
                    args.usize_or("pretrain-steps", o.pretrain_steps)?;
                o.caps = args.f64_list_or("caps", &o.caps)?;
                fig9::run(&o)?.print();
            }
            "table1" => {
                table1::run(&["lm_tiny", "lm_base", "vit_tiny", "vlm_tiny"])?
                    .print();
            }
            "qualitative" => {
                let mut o = qualitative::QualOpts { seed,
                                                    ..Default::default() };
                o.distill_steps = args.usize_or("steps", o.distill_steps)?;
                qualitative::run(&o)?;
            }
            other => bail!("unknown experiment {other:?}"),
        }
        Ok(())
    };
    if id == "all" {
        for id in ["table1", "fig2", "fig4", "fig5", "fig6", "fig7", "fig8",
                   "fig9", "qualitative"] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(id)
    }
}

#[cfg(feature = "pjrt")]
fn cmd_train_teacher(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let steps = args.usize_or("steps", 300)?;
    let seed = args.u64_or("seed", 42)?;
    let ctx = common::Ctx::load(config, seed)?;
    let params = ctx.teacher(steps)?;
    println!("teacher ready: {} params (cached under results/ckpt)",
             params.len());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_distill(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let steps = args.usize_or("steps", 100)?;
    let pretrain = args.usize_or("pretrain-steps", 300)?;
    let rank = args.usize_or("rank", 0)?;
    let seed = args.u64_or("seed", 42)?;
    let caps_v = args.f64_list_or("caps", &[0.75, 0.75, 1.0, 0.5])?;
    if caps_v.len() != 4 {
        bail!("--caps wants 4 comma-separated values");
    }
    let caps = Caps([caps_v[0] as f32, caps_v[1] as f32, caps_v[2] as f32,
                     caps_v[3] as f32]);
    let ctx = common::Ctx::load(config, seed)?;
    if ctx.rt.manifest.kind() != "lm" {
        bail!("distill subcommand currently drives LM configs; use \
               `exp fig7`/`exp fig9` for ViT/VLM distillation");
    }
    let teacher = ctx.teacher(pretrain)?;
    let layer_en = layer_enable(ctx.rt.manifest.n_layers(),
                                args.str_or("layers", "all"))?;
    let router = ctx.router_init(&format!("router_init_r{rank}"),
                                 seed as i32)?;
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let ds = TextDataset::from_texts(&common::gsm_train_texts(600, seed), t);
    let mut batcher = Batcher::new(ds.len(), b, seed);
    let mut trainer = Trainer::with_logger(
        &ctx.rt,
        common::results_dir().join("distill_log.jsonl").to_str().unwrap())?;
    let (router, hist) = trainer.distill_lm(
        &format!("distill_step_r{rank}"), &teacher, &teacher, router, steps,
        1e-3, caps, &layer_en, 1.0, || batcher.next_tokens(&ds))?;
    let out = common::results_dir().join(format!("{config}_router_r{rank}.bin"));
    Checkpoint::new(config, &format!("router_r{rank}"), steps as u64, router)
        .save(&out)?;
    println!("distilled {steps} steps: distill {:.4} -> {:.4}; router saved \
              to {out:?}",
             hist.first().map(|m| m.distill).unwrap_or(0.0),
             hist.last().map(|m| m.distill).unwrap_or(0.0));
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let n_requests = args.usize_or("requests", 64)?;
    let rate = args.f64_or("rate", 100.0)?;
    let pretrain = args.usize_or("pretrain-steps", 300)?;
    let workers = args.usize_or("workers", 1)?;
    let seed = args.u64_or("seed", 42)?;
    let ctx = common::Ctx::load(config, seed)?;
    let teacher = ctx.teacher(pretrain)?;
    let router = ctx.router_init("router_init_r0", seed as i32)?;
    let t = ctx.rt.manifest.seq_len();

    let cfg = ServeConfig::standard().with_workers(workers);
    // each worker compiles its own tier executables on its own thread
    // (PJRT handles are not Send); start() returns once every worker is
    // warm, so request latency stamps measure serving, not compile
    let factory = XlaExecutor::factory(common::artifacts_dir(),
                                       config.to_string(), teacher, router,
                                       cfg.tiers.clone());
    let engine = ElasticEngine::start(cfg, factory)?;
    let tok = elastiformer::data::Tokenizer::new();
    let mut rng = Rng::new(seed ^ 0x5E12);
    let mut responses = Vec::with_capacity(n_requests);
    for id in 0..n_requests as u64 {
        let p = mathgen::gen_problem(&mut rng);
        responses.push(engine.submit(
            Request::new(id, tok.encode_padded(&p.full_text(), t))));
        std::thread::sleep(Duration::from_secs_f64(1.0 / rate.max(1.0)));
    }
    let failed = drain_responses(responses);
    let report = engine.shutdown()?;
    print_report(&report, failed);
    Ok(())
}

/// Wait out every per-request response; returns how many resolved to a
/// serve error (shed deadline, worker failure, shutdown).
#[cfg(feature = "pjrt")]
fn drain_responses(responses: Vec<Response>) -> usize {
    let mut failed = 0usize;
    for r in responses {
        if r.wait().is_err() {
            failed += 1;
        }
    }
    failed
}

#[cfg(feature = "pjrt")]
fn print_report(report: &ServeReport, failed: usize) {
    println!("served {} requests in {:.2}s on {} worker(s) — {:.1} req/s, \
              p50 {:.1} ms, p99 {:.1} ms, mean capacity {:.2}",
             report.completions.len(), report.wall_secs, report.workers,
             report.throughput_rps(), report.latency_p(0.5),
             report.latency_p(0.99), report.mean_capacity());
    for (tier, count) in &report.tier_counts {
        println!("  tier {tier:.2}: {count} requests");
    }
    if report.workers > 1 {
        let counts = report.worker_counts();
        let joined: Vec<String> =
            counts.iter().map(|c| c.to_string()).collect();
        println!("  per-worker completions: [{}]", joined.join(", "));
    }
    let sections = report.class_sections();
    if sections.len() > 1 || sections.iter().any(|s| s.shed > 0) {
        for s in sections {
            println!("  class {:<12} served {:>5}  shed {:>4}  \
                      p50 {:>7.2} ms  p99 {:>7.2} ms  mean cap {:.2}",
                     s.class, s.served, s.shed, s.p50_ms, s.p99_ms,
                     s.mean_capacity);
        }
    }
    if failed > 0 {
        println!("  {failed} request(s) resolved with a serve error");
    }
}

/// Synthetic open-loop load sweep over the deterministic simulation
/// backend: Poisson-ish arrivals (exponential inter-arrival gaps from
/// the seeded `Rng`) pushed through the non-blocking `try_submit`
/// front-end, so overload surfaces as explicit `Shed(QueueFull)`
/// admission verdicts instead of a stalled arrival process.  One report
/// row per offered rate.  Runs anywhere — no artifacts, no XLA runtime.
fn cmd_serve_sim(args: &Args) -> Result<()> {
    args.check_known(&["requests", "rates", "workers", "batch", "seq-len",
                       "queue-bound", "queue-shards", "depth-per-tier",
                       "seed", "worker-classes", "stream",
                       "decode-steps", "arena-pages", "spec-k",
                       "divergence", "fault-rate", "trace",
                       "snapshot-every-ms"])?;
    let n = args.usize_or("requests", 512)?;
    let workers = args.usize_or("workers", 4)?;
    let seed = args.u64_or("seed", 42)?;
    let queue_bound = args.usize_or("queue-bound", 64)?;
    // streaming sidecar load: N decode sessions of K tokens each,
    // interleaved with the one-shot arrivals (continuous batching)
    let stream_n = args.usize_or("stream", 0)?;
    let decode_steps = args.usize_or("decode-steps", 16)?;
    // session-arena pages per worker class (0 = decode steps always
    // recompute their window from the session table)
    let arena_pages =
        args.usize_or("arena-pages", ServeConfig::standard().arena_pages)?;
    // speculative decode: draft ceiling per admission (0 = plain
    // decode) and the sim's tier-dependent disagreement probability
    let spec_k = args.usize_or("spec-k", 0)?;
    let divergence = args.f64_or("divergence", 0.0)?;
    if !(0.0..=1.0).contains(&divergence) {
        bail!("--divergence must be in [0, 1], got {divergence}");
    }
    // chaos injection: per-execute transient failure probability for
    // the sim backend; the fault ladder (retry -> bisect -> quarantine)
    // must absorb it without an outage
    let fault_rate = args.f64_or("fault-rate", 0.0)?;
    if !(0.0..1.0).contains(&fault_rate) {
        bail!("--fault-rate must be in [0, 1), got {fault_rate}");
    }
    // flight recorder: --trace FILE turns the recorder on for every
    // rate point and writes the last point's Chrome trace to FILE;
    // --snapshot-every-ms N prints live engine snapshots while a
    // point runs (both default off — the hot path stays branch-only)
    let trace_out = args.str_opt("trace");
    let snapshot_every_ms = args.u64_or("snapshot-every-ms", 0)?;
    // 0 = auto (one admission shard per worker); 1 = the classic
    // shared queue, kept for A/B comparison
    let queue_shards = args.usize_or("queue-shards", 0)?;
    let depth_per_tier = args.f64_or("depth-per-tier", 8.0)?;
    // heterogeneous fleet: "fast=2:slow=2@4" = 2 fast workers plus 2
    // workers whose sim latency model is scaled 4x, each class under
    // its own capacity controller; None = homogeneous --workers fleet
    let classes = match args.str_opt("worker-classes") {
        Some(s) => Some(parse_worker_classes(s)?),
        None => None,
    };
    let rates = args.f64_list_or("rates", &[250.0, 1000.0, 4000.0])?;
    if rates.iter().any(|r| !r.is_finite() || *r <= 0.0) {
        bail!("--rates must all be finite and > 0 (req/s), got {rates:?}");
    }
    if !depth_per_tier.is_finite() || depth_per_tier <= 0.0 {
        bail!("--depth-per-tier must be finite and > 0, \
               got {depth_per_tier}");
    }
    let mut spec = SimSpec::standard();
    spec.batch = args.usize_or("batch", spec.batch)?;
    spec.seq_len = args.usize_or("seq-len", spec.seq_len)?;
    spec.seed = seed;
    spec.divergence = divergence;
    if fault_rate > 0.0 {
        spec.fault.fail_p = fault_rate;
        spec.fault.tier_bias = 0.5; // cheap tiers proportionally flakier
    }
    if spec.batch == 0 || spec.seq_len == 0 {
        bail!("--batch and --seq-len must be >= 1");
    }
    if stream_n > 0 && decode_steps == 0 {
        bail!("--decode-steps must be >= 1 when --stream is set");
    }
    if spec_k > 0 && stream_n == 0 {
        bail!("--spec-k needs --stream N: speculative decode only \
               applies to streaming sessions");
    }

    let total_workers = match &classes {
        Some(cs) => cs.iter().map(|(_, w, _)| *w).sum::<usize>(),
        None => workers,
    };
    let topology = match &classes {
        Some(cs) => cs
            .iter()
            .map(|(name, w, mult)| format!("{name}={w}@{mult}"))
            .collect::<Vec<_>>()
            .join(":"),
        None => "homogeneous".into(),
    };
    println!("serve-sim: {n} requests per point, {total_workers} \
              worker(s) ({topology}), batch {} x seq {}, queue bound \
              {queue_bound}, {} admission shard(s){}",
             spec.batch, spec.seq_len,
             if queue_shards == 0 { total_workers } else { queue_shards },
             if stream_n > 0 {
                 format!(", {stream_n} decode session(s) x \
                          {decode_steps} step(s)")
             } else {
                 String::new()
             });
    for rate in rates {
        let (report, shed, poisoned) =
            run_sim_point(spec, workers, queue_bound, queue_shards,
                          depth_per_tier, classes.as_deref(), n, rate,
                          seed, stream_n, decode_steps, arena_pages,
                          spec_k, snapshot_every_ms, trace_out)?;
        let tiers: Vec<String> = report
            .tier_counts
            .iter()
            .map(|(t, c)| format!("{t:.2}:{c}"))
            .collect();
        println!("offered {rate:>8.0} req/s | served {:>5} in {:>6.2}s | \
                  shed {shed:>4} at admission | {:>8.1} req/s | \
                  p50 {:>7.2} ms | p99 {:>7.2} ms | mean cap {:.2} | \
                  tiers {}",
                 report.completions.len(), report.wall_secs,
                 report.throughput_rps(), report.latency_p(0.5),
                 report.latency_p(0.99), report.mean_capacity(),
                 tiers.join(" "));
        if stream_n > 0 {
            // streaming economy per SLO class: session split, token
            // throughput, first-token latency, and how the per-step
            // tier trajectory distributed over the ladder
            for s in report.stream_sections() {
                let tiers: Vec<String> = s
                    .tier_step_counts
                    .iter()
                    .map(|(t, c)| format!("{t:.2}:{c}"))
                    .collect();
                println!("    stream {:<10} done {:>4} shed {:>3} | \
                          {:>6} tok {:>8.1} tok/s | first-token \
                          {:>7.2} ms | p99 session {:>8.2} ms | \
                          step tiers {}",
                         s.class, s.completed, s.shed, s.tokens,
                         s.tokens_per_s, s.mean_first_token_ms,
                         s.p99_session_ms, tiers.join(" "));
            }
            // session-arena economy: decode rows served from cached
            // windows vs recomputed from the session table ("n/a"
            // when the run produced no lookups at all, rather than a
            // misleading 0.0%)
            let hit = match report.cache_hit_rate_opt() {
                Some(r) => format!("{:>5.1}%", r * 100.0),
                None => "   n/a".into(),
            };
            println!("    arena  hit rate {hit} | {} cached row(s), \
                      {} recomputed",
                     report.cache_hits, report.cache_misses);
            if spec_k > 0 {
                // speculative economy: how often the cheap draft tier
                // agreed with the verifier, and the admission-item
                // payoff (1.0 = plain decode)
                let accept = match report.spec_accept_rate_opt() {
                    Some(r) => format!("{:>5.1}%", r * 100.0),
                    None => "   n/a".into(),
                };
                println!("    spec   accept {accept} | drafted {} \
                          accepted {} rejected {} | {:.2} \
                          tok/admission",
                         report.spec_drafted, report.spec_accepted,
                         report.spec_rejected,
                         report.tokens_per_admission());
            }
        }
        // fault-tolerance economy: what the ladder absorbed on the way
        // to this row (retries, bisections, quarantines, respawns),
        // plus anything the fleet survived but recorded
        for f in report.fault_sections() {
            println!("    faults {:<10} retries {:>4} | splits {:>3} | \
                      quarantined {:>3} | respawns {:>2} | \
                      breaker trips {:>2}",
                     f.class, f.retries, f.splits, f.poisoned,
                     f.respawns, f.breaker_trips);
        }
        if poisoned > 0 {
            println!("    {poisoned} request(s) quarantined as poison");
        }
        for e in &report.worker_errors {
            println!("    worker error (survived): {e}");
        }
        if classes.is_some() {
            // per-worker-class split: each class's share, tier mix and
            // the exec-time model its own controller learned
            for s in report.worker_class_sections() {
                let est = s
                    .exec_estimates_ms
                    .first()
                    .and_then(|(_, e)| *e)
                    .map(|e| format!("{e:.2} ms"))
                    .unwrap_or_else(|| "-".into());
                let arena = if s.cache_hits + s.cache_misses > 0 {
                    format!(" | arena {:.1}% of {}",
                            100.0 * s.cache_hits as f64
                                / (s.cache_hits + s.cache_misses) as f64,
                            s.cache_hits + s.cache_misses)
                } else {
                    String::new()
                };
                println!("    class {:<10} ({} workers) | served {:>5} | \
                          p99 {:>7.2} ms | mean cap {:.2} | \
                          est@top {est}{arena}",
                         s.class, s.workers, s.served, s.p99_ms,
                         s.mean_capacity);
            }
        }
    }
    Ok(())
}

/// Parse `--worker-classes fast=2:slow=2@4` into `(name, workers,
/// latency multiplier)` triples; the multiplier scales the sim spec's
/// `base_ms`/`ms_per_capacity` for that class (default 1.0).
fn parse_worker_classes(s: &str) -> Result<Vec<(String, usize, f64)>> {
    let mut out = Vec::new();
    for part in s.split(':').filter(|p| !p.is_empty()) {
        let (name, rest) = part.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("--worker-classes wants \
                             name=count[@latency-mult] entries \
                             separated by ':', got {part:?}")
        })?;
        let (count_s, mult_s) = match rest.split_once('@') {
            Some((c, m)) => (c, Some(m)),
            None => (rest, None),
        };
        let count: usize = count_s.parse().map_err(
            |_| anyhow::anyhow!("bad worker count in {part:?}"))?;
        let mult: f64 = match mult_s {
            Some(m) => m.parse().map_err(|_| {
                anyhow::anyhow!("bad latency multiplier in {part:?}")
            })?,
            None => 1.0,
        };
        anyhow::ensure!(count >= 1,
                        "worker count must be >= 1 in {part:?}");
        anyhow::ensure!(!name.is_empty(), "empty class name in {part:?}");
        anyhow::ensure!(mult.is_finite() && mult > 0.0,
                        "latency multiplier must be finite and > 0 \
                         in {part:?}");
        out.push((name.to_string(), count, mult));
    }
    anyhow::ensure!(!out.is_empty(), "--worker-classes is empty");
    Ok(out)
}

/// Ring capacity per recorder lane when `--trace` is set: generous
/// enough that the seeded sweeps export losslessly, small enough that
/// a long overload run degrades by dropping oldest (and says so in
/// the ledger) instead of growing without bound.
const TRACE_CAPACITY: usize = 65_536;

#[allow(clippy::too_many_arguments)]
fn run_sim_point(spec: SimSpec, workers: usize, queue_bound: usize,
                 queue_shards: usize, depth_per_tier: f64,
                 classes: Option<&[(String, usize, f64)]>, n: usize,
                 rate: f64, seed: u64, stream_n: usize,
                 decode_steps: usize, arena_pages: usize,
                 spec_k: usize, snapshot_every_ms: u64,
                 trace_out: Option<&str>)
                 -> Result<(ServeReport, usize, usize)> {
    let mut cfg = ServeConfig::sim()
        .with_workers(workers)
        .with_queue_bound(queue_bound)
        .with_queue_shards(queue_shards)
        .with_depth_per_tier(depth_per_tier)
        .with_arena_pages(arena_pages)
        .with_spec_k(spec_k)
        .with_trace_capacity(
            if trace_out.is_some() { TRACE_CAPACITY } else { 0 })
        .with_max_batch_wait(Duration::from_millis(2));
    let caps = cfg.capacities();
    let engine = match classes {
        None => ElasticEngine::start(cfg, sim::factory(spec, caps))?,
        Some(cs) => {
            for (name, class_workers, mult) in cs {
                let class_spec = SimSpec {
                    base_ms: spec.base_ms * mult,
                    ms_per_capacity: spec.ms_per_capacity * mult,
                    ..spec
                };
                cfg = cfg.with_worker_class(
                    name, *class_workers,
                    sim::factory(class_spec, caps.clone()));
            }
            ElasticEngine::start_fleet(cfg)?
        }
    };
    // the export path drains after shutdown consumes the handle, so
    // hold the recorder Arc now
    let recorder = engine.trace_recorder();
    // live snapshot printer: borrows the engine for the lifetime of
    // the point, so the scope must end (stop flag set on every path)
    // before `shutdown(self)` can consume the handle
    let stop = AtomicBool::new(false);
    let (shed, poisoned) = std::thread::scope(|scope| {
        if snapshot_every_ms > 0 {
            let (engine, stop) = (&engine, &stop);
            scope.spawn(move || {
                loop {
                    std::thread::sleep(
                        Duration::from_millis(snapshot_every_ms));
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    print_snapshot(&engine.snapshot());
                }
            });
        }
        let result =
            drive_sim_point(&engine, spec, n, rate, seed, stream_n,
                            decode_steps);
        stop.store(true, Ordering::Relaxed);
        result
    })?;
    let report = engine.shutdown()?;
    if let Some(path) = trace_out {
        if let Some(rec) = &recorder {
            // drain only after shutdown joined the workers: the
            // ledger is quiescent, so exported + dropped == emitted
            let events = rec.drain();
            std::fs::write(path,
                           trace_export::chrome_json(&events,
                                                     rec.classes()))?;
            let c = rec.counts();
            println!("    trace  {} event(s) -> {path} | emitted {} \
                      dropped {}",
                     events.len(), c.emitted, c.dropped);
        }
    }
    Ok((report, shed, poisoned))
}

/// One live `EngineSnapshot`, printed as a heartbeat line plus one
/// line per worker class — the CLI face of the same struct a
/// multi-node control plane would ship over the wire (ROADMAP).
fn print_snapshot(s: &EngineSnapshot) {
    let trace = match &s.trace {
        Some(t) => format!(" | trace {}/{} dropped {}",
                           t.exported, t.emitted, t.dropped),
        None => String::new(),
    };
    println!("  [snapshot +{:>8.0} ms] queue {:>3} (urgent {}) | \
              workers {} | served {:>5} shed {:>3} | sessions \
              {}/{} shed {}{trace}",
             s.uptime_ms, s.queue_depth, s.urgent_depth,
             s.live_workers, s.served, s.shed, s.sessions_done,
             s.sessions_started, s.sessions_shed);
    for c in &s.classes {
        println!("  [snapshot] class {:<10} served {:>5} shed {:>3} | \
                  p50 {:>7.2} ms p99 {:>7.2} ms ({} samples) | \
                  breaker {} (trips {})",
                 c.class, c.served, c.shed, c.p50_ms, c.p99_ms,
                 c.latency_samples, c.breaker, c.breaker_trips);
    }
}

/// The open-loop body of one rate point: Poisson arrivals through the
/// non-blocking front-end, the streaming sidecar, then the waits.
/// Split out of `run_sim_point` so it can run under the snapshot
/// printer's borrow scope and still early-exit with `bail!`.
fn drive_sim_point(engine: &EngineHandle, spec: SimSpec, n: usize,
                   rate: f64, seed: u64, stream_n: usize,
                   decode_steps: usize) -> Result<(usize, usize)> {
    let seq_len = spec.seq_len;
    let mut rng = Rng::new(seed ^ 0xA11F);
    let mut responses = Vec::with_capacity(n);
    let mut streams = Vec::with_capacity(stream_n);
    let stream_every =
        if stream_n > 0 { (n / stream_n).max(1) } else { usize::MAX };
    let mut shed = 0usize;
    for id in 0..n as u64 {
        // streaming sidecar: spread session starts across the arrival
        // process so decode steps overlap (and batch) with one-shot
        // prefill traffic — the continuous-batching demonstration
        if streams.len() < stream_n && id as usize % stream_every == 0 {
            let prompt: Vec<i32> = (0..seq_len.min(8))
                .map(|i| ((id as usize + i) % 97) as i32)
                .collect();
            streams.push(engine.submit_stream(StreamRequest::new(
                1_000_000 + id, prompt, decode_steps)));
        }
        let tokens: Vec<i32> = (0..seq_len)
            .map(|i| ((id as usize + i) % 97) as i32)
            .collect();
        // non-blocking admission keeps the offered rate honest: a full
        // queue sheds the arrival instead of stalling the process
        match engine.try_submit(Request::new(id, tokens)) {
            Admission::Accepted(r) => responses.push(r),
            Admission::Shed(_) => shed += 1,
        }
        // open-loop Poisson process: exponential inter-arrival gap
        let gap = -(1.0 - rng.f64()).ln() / rate;
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
    }
    // --stream larger than --requests (or a sparse interleave) leaves
    // sessions unstarted after the arrival loop: start the remainder
    // now rather than silently capping the requested streaming load
    while streams.len() < stream_n {
        let id = 2_000_000 + streams.len() as u64;
        let prompt: Vec<i32> = (0..seq_len.min(8))
            .map(|i| ((id as usize + i) % 97) as i32)
            .collect();
        streams.push(engine.submit_stream(StreamRequest::new(
            id, prompt, decode_steps)));
    }
    let (mut failed, mut poisoned) = (0usize, 0usize);
    for r in responses {
        match r.wait() {
            Ok(_) => {}
            // a quarantined request is the fault ladder working as
            // designed — the bisection isolated a poison batch and
            // shed only it — so it is counted, not fatal
            Err(ServeError::Poisoned(_)) => poisoned += 1,
            Err(_) => failed += 1,
        }
    }
    if failed > 0 {
        bail!("{failed} admitted sim requests resolved with an error");
    }
    // best-effort sessions on an open engine must complete; drain
    // their terminals before shutdown so the report sees them as Done
    let mut stream_failed = 0usize;
    for s in streams {
        if s.wait().is_err() {
            stream_failed += 1;
        }
    }
    if stream_failed > 0 {
        bail!("{stream_failed} decode session(s) were shed unexpectedly");
    }
    Ok((shed, poisoned))
}

#[cfg(feature = "pjrt")]
fn cmd_info(args: &Args) -> Result<()> {
    let config = args.str_or("config", "lm_tiny");
    let ctx = common::Ctx::load(config, 0)?;
    let m = &ctx.rt.manifest;
    println!("config {} (kind {})", m.name(), m.kind());
    println!("  teacher params: {}", m.teacher_params.total());
    for (k, t) in &m.router_params {
        println!("  router table {k}: {} params", t.total());
    }
    println!("  entries:");
    for (name, e) in &m.entries {
        println!("    {name} ({} args, {} outputs)", e.args.len(),
                 e.outputs.len());
    }
    if let Ok(dims) = m.dims() {
        use elastiformer::analysis::flops::{self, Capacity};
        let t = flops::teacher_macs(&dims);
        println!("  teacher MACs/seq: {t}");
        for c in [0.75, 0.5, 0.25] {
            let e = flops::elastic_macs(&dims, &Capacity::uniform(c));
            println!("  elastic@{c}: {e} ({:.1}% of teacher)",
                     100.0 * e as f64 / t as f64);
        }
    }
    Ok(())
}
