//! Ranked synchronization primitives for the serving subsystem.
//!
//! Every mutex in `coordinator/serving/**` is a [`RankedMutex`] carrying
//! one of the [`Rank`]s below.  Two guarantees follow:
//!
//! * **Deadlock freedom by construction.**  Under
//!   `cfg(debug_assertions)` each thread tracks the ranks it currently
//!   holds; acquiring a lock whose rank is not strictly greater than
//!   every held rank panics at the acquisition site, so any
//!   cycle-capable acquisition order dies in the first debug run that
//!   exercises it instead of deadlocking one production run in a
//!   million.  Release builds compile the check away entirely — the
//!   lock is a plain `std::sync::Mutex` passthrough.
//!
//! * **Poison absorption.**  [`RankedMutex::lock`] recovers the inner
//!   value from a poisoned mutex via `into_inner`-style recovery
//!   instead of unwrapping, so one panicking worker cannot cascade
//!   into every later `lock().unwrap()` on the same log (the
//!   teardown path drains those logs and must complete even after a
//!   fault).  Data-level invariants are the callers' business; every
//!   protected structure here is a log, gauge, map or state machine
//!   that tolerates a torn last entry.
//!
//! The rank table is the machine-checked form of the prose lock-order
//! invariants in `coordinator/serving/README.md` ("Enforced
//! invariants"); `invariant-lint` (rule `raw-mutex`) keeps new code
//! from bypassing it with a raw `std::sync::Mutex`.

use std::cell::RefCell;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard,
                RwLockWriteGuard};
use std::time::Duration;

/// Global lock-acquisition order for the serving subsystem, smallest
/// first.  A thread may only acquire a lock whose rank is **strictly
/// greater** than every rank it already holds, so same-rank re-entry
/// (two queue shards at once, two session entries at once) is refused
/// along with genuine inversions.
///
/// The nestings that fixed this order:
///
/// * `SessionTable::advance`/`spec::resolve_verify` call
///   `StreamSender::token` while holding the **SessionEntry** lock, so
///   the stream channel ranks *above* the entry.
/// * The map guard and an entry guard are never held together (the map
///   lookup clones the `Arc` out as a temporary), but map → entry is
///   the documented direction, so the map ranks below.
/// * Workers append to the shed/completion logs only after every
///   queue/session/controller lock is released — the logs rank last.
/// * `ResponseSlot` and `InitLatch` are leaves (nothing is acquired
///   while they are held, and they are never acquired under another
///   serving lock on the engine side); they slot above the controller
///   so a future "resolve under controller lock" refactor still
///   type-checks the order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Rank {
    /// one admission-queue shard's deque (`queue::Shard::items`)
    QueueShard = 10,
    /// a doorbell gate (`queue::Doorbell::gate`) — both the pop
    /// doorbell and the vacancy doorbell
    Doorbell = 20,
    /// the session table's key → entry map (`SessionTable::sessions`)
    SessionMap = 30,
    /// one live decode session's state (`SessionEntry::state`)
    SessionEntry = 40,
    /// one stream channel's event queue (`Chan::inner`) — above
    /// `SessionEntry` because `advance` emits tokens under the entry
    StreamChan = 50,
    /// a worker-class session arena's page pool (`SessionArena`)
    ArenaPool = 60,
    /// a worker class's capacity controller
    Controller = 70,
    /// a one-shot response's resolution slot (`Slot::state`)
    ResponseSlot = 80,
    /// the startup init latch (`InitLatch::state`)
    InitLatch = 90,
    /// the report logs: completions, sheds, stream_done, stream_shed
    ShedLog = 100,
    /// the worker-error log (appended by supervision paths that may
    /// already hold a shed log in future refactors — keep it last)
    Errors = 110,
    /// one flight-recorder event lane (`trace::TraceRecorder`) —
    /// strictly last among all serving locks: trace events are emitted
    /// from sites that may hold any of the locks above (controller,
    /// session entry, shed log), and nothing is ever acquired while a
    /// trace lane is held
    TraceRing = 120,
}

#[cfg(debug_assertions)]
thread_local! {
    /// Ranks currently held by this thread (duplicates impossible:
    /// acquisition is strictly increasing).  A `Vec`, not a single
    /// max, because guards may drop in any order.
    static HELD_RANKS: RefCell<Vec<Rank>> = const { RefCell::new(Vec::new()) };
}

/// Debug-only acquisition check: `rank` must exceed every held rank.
#[cfg(debug_assertions)]
#[inline]
fn rank_acquire(rank: Rank) {
    HELD_RANKS.with(|held| {
        let mut held = held.borrow_mut();
        if let Some(&worst) = held.iter().max() {
            assert!(
                rank > worst,
                "lock rank inversion: acquiring {rank:?} while already \
                 holding {worst:?} (acquisition order must be strictly \
                 increasing; see the Rank table in sync.rs)"
            );
        }
        held.push(rank);
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn rank_acquire(_rank: Rank) {}

/// Debug-only release: drop one occurrence of `rank` from the stack.
#[cfg(debug_assertions)]
#[inline]
fn rank_release(rank: Rank) {
    HELD_RANKS.with(|held| {
        let mut held = held.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&r| r == rank) {
            held.swap_remove(pos);
        }
    });
}

#[cfg(not(debug_assertions))]
#[inline(always)]
fn rank_release(_rank: Rank) {}

/// Mutex with a global acquisition rank and poison absorption.  See
/// the module docs; use [`RankedCondvar`] where `std::sync::Condvar`
/// would pair with the inner mutex.
pub struct RankedMutex<T> {
    rank: Rank,
    inner: Mutex<T>,
}

impl<T> RankedMutex<T> {
    pub fn new(rank: Rank, value: T) -> RankedMutex<T> {
        RankedMutex { rank, inner: Mutex::new(value) }
    }

    /// Acquire the lock.  Panics (debug builds only) if this thread
    /// already holds a lock of equal or greater rank; absorbs
    /// poisoning from a previous holder's panic instead of
    /// propagating it.
    pub fn lock(&self) -> RankedGuard<'_, T> {
        rank_acquire(self.rank);
        let guard =
            self.inner.lock().unwrap_or_else(|poison| poison.into_inner());
        RankedGuard { guard: Some(guard), rank: self.rank }
    }

    /// The rank this mutex was constructed with.
    pub fn rank(&self) -> Rank {
        self.rank
    }

    /// Consume the mutex, absorbing poison.
    pub fn into_inner(self) -> T {
        self.inner
            .into_inner()
            .unwrap_or_else(|poison| poison.into_inner())
    }
}

impl<T: fmt::Debug> fmt::Debug for RankedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RankedMutex")
            .field("rank", &self.rank)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Guard for a [`RankedMutex`].  The inner `Option` exists so
/// [`RankedCondvar`] can take the raw guard out across a wait and put
/// it back — the rank stays on the held stack for the whole wait
/// (this thread is blocked; it cannot acquire anything else anyway).
pub struct RankedGuard<'a, T> {
    guard: Option<MutexGuard<'a, T>>,
    rank: Rank,
}

impl<T> Deref for RankedGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard taken by condvar wait")
    }
}

impl<T> DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard taken by condvar wait")
    }
}

impl<T> Drop for RankedGuard<'_, T> {
    fn drop(&mut self) {
        rank_release(self.rank);
    }
}

/// Condvar companion to [`RankedMutex`]: `std::sync::Condvar::wait`
/// needs the raw `MutexGuard`, so the wait methods take it out of the
/// [`RankedGuard`], wait, and put it back — absorbing poison on
/// re-acquisition exactly like [`RankedMutex::lock`].
#[derive(Default)]
pub struct RankedCondvar {
    cv: Condvar,
}

impl RankedCondvar {
    pub fn new() -> RankedCondvar {
        RankedCondvar { cv: Condvar::new() }
    }

    /// Block until notified, releasing the lock for the duration.
    pub fn wait<'a, T>(&self, mut guard: RankedGuard<'a, T>)
                       -> RankedGuard<'a, T> {
        let raw = guard.guard.take().expect("guard taken by condvar wait");
        let raw =
            self.cv.wait(raw).unwrap_or_else(|poison| poison.into_inner());
        guard.guard = Some(raw);
        guard
    }

    /// Block until notified or `timeout` elapses; the bool is `true`
    /// iff the wait timed out.
    pub fn wait_timeout<'a, T>(&self, mut guard: RankedGuard<'a, T>,
                               timeout: Duration)
                               -> (RankedGuard<'a, T>, bool) {
        let raw = guard.guard.take().expect("guard taken by condvar wait");
        let (raw, res) = self
            .cv
            .wait_timeout(raw, timeout)
            .unwrap_or_else(|poison| poison.into_inner());
        guard.guard = Some(raw);
        (guard, res.timed_out())
    }

    pub fn notify_one(&self) {
        self.cv.notify_one();
    }

    pub fn notify_all(&self) {
        self.cv.notify_all();
    }
}

impl fmt::Debug for RankedCondvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("RankedCondvar")
    }
}

/// RwLock sibling of [`RankedMutex`]: both `read()` and `write()`
/// participate in the same rank discipline (a read guard can still
/// deadlock against a writer, so reads get no special dispensation)
/// and both absorb poisoning.  Nothing in `serving/` needs one today;
/// it exists so the first future reader/writer split starts ranked
/// instead of raw.
pub struct RankedRwLock<T> {
    rank: Rank,
    inner: RwLock<T>,
}

impl<T> RankedRwLock<T> {
    pub fn new(rank: Rank, value: T) -> RankedRwLock<T> {
        RankedRwLock { rank, inner: RwLock::new(value) }
    }

    pub fn read(&self) -> RankedReadGuard<'_, T> {
        rank_acquire(self.rank);
        let guard =
            self.inner.read().unwrap_or_else(|poison| poison.into_inner());
        RankedReadGuard { guard, rank: self.rank }
    }

    pub fn write(&self) -> RankedWriteGuard<'_, T> {
        rank_acquire(self.rank);
        let guard =
            self.inner.write().unwrap_or_else(|poison| poison.into_inner());
        RankedWriteGuard { guard, rank: self.rank }
    }

    pub fn rank(&self) -> Rank {
        self.rank
    }

    pub fn into_inner(self) -> T {
        self.inner
            .into_inner()
            .unwrap_or_else(|poison| poison.into_inner())
    }
}

pub struct RankedReadGuard<'a, T> {
    guard: RwLockReadGuard<'a, T>,
    rank: Rank,
}

impl<T> Deref for RankedReadGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> Drop for RankedReadGuard<'_, T> {
    fn drop(&mut self) {
        rank_release(self.rank);
    }
}

pub struct RankedWriteGuard<'a, T> {
    guard: RwLockWriteGuard<'a, T>,
    rank: Rank,
}

impl<T> Deref for RankedWriteGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T> DerefMut for RankedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

impl<T> Drop for RankedWriteGuard<'_, T> {
    fn drop(&mut self) {
        rank_release(self.rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::AssertUnwindSafe;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn in_order_acquisition_and_reacquisition_pass() {
        let shard = RankedMutex::new(Rank::QueueShard, 1usize);
        let log = RankedMutex::new(Rank::ShedLog, Vec::<usize>::new());
        {
            let s = shard.lock();
            let mut l = log.lock();
            l.push(*s);
        }
        // both released: a fresh acquisition at any rank is fine again
        let l = log.lock();
        assert_eq!(*l, vec![1]);
    }

    /// The acceptance-criteria test: a deliberately inverted
    /// acquisition (high rank held, low rank requested) must be caught
    /// by the debug-mode checker at the acquisition site.
    #[test]
    #[cfg(debug_assertions)]
    fn rank_inversion_is_caught() {
        let ctl = RankedMutex::new(Rank::Controller, ());
        let shard = RankedMutex::new(Rank::QueueShard, ());
        let _hi = ctl.lock();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _lo = shard.lock(); // Controller > QueueShard: inverted
        }));
        assert!(caught.is_err(), "inverted acquisition must panic");
        // the failed acquisition must not corrupt the held stack:
        // in-order acquisition still works while _hi is held
        let _log = RankedMutex::new(Rank::ShedLog, ()).lock();
    }

    #[test]
    #[cfg(debug_assertions)]
    fn same_rank_double_hold_is_caught() {
        let a = RankedMutex::new(Rank::SessionEntry, ());
        let b = RankedMutex::new(Rank::SessionEntry, ());
        let _ga = a.lock();
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let _gb = b.lock(); // equal rank: refused (strictly greater)
        }));
        assert!(caught.is_err(), "same-rank double hold must panic");
    }

    #[test]
    fn lock_absorbs_poison_from_a_panicked_holder() {
        let m = Arc::new(RankedMutex::new(Rank::ShedLog, vec![1, 2]));
        let m2 = m.clone();
        let t = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the log");
        });
        assert!(t.join().is_err(), "holder must have panicked");
        // pre-RankedMutex this was `.lock().unwrap()` → second panic
        let mut g = m.lock();
        g.push(3);
        assert_eq!(*g, vec![1, 2, 3]);
    }

    #[test]
    fn rwlock_orders_and_absorbs_like_the_mutex() {
        let rw = Arc::new(RankedRwLock::new(Rank::Controller, 7usize));
        {
            let r = rw.read();
            assert_eq!(*r, 7);
        }
        {
            let mut w = rw.write();
            *w = 8;
        }
        let rw2 = rw.clone();
        let t = std::thread::spawn(move || {
            let _w = rw2.write();
            panic!("poison the rwlock");
        });
        assert!(t.join().is_err());
        assert_eq!(*rw.read(), 8);
    }

    #[test]
    fn condvar_roundtrips_the_guard_and_wakes() {
        let state = Arc::new((
            RankedMutex::new(Rank::ResponseSlot, false),
            RankedCondvar::new(),
        ));
        let s2 = state.clone();
        let t = std::thread::spawn(move || {
            let mut g = s2.0.lock();
            *g = true;
            drop(g);
            s2.1.notify_all();
        });
        let mut g = state.0.lock();
        let deadline = Instant::now() + Duration::from_secs(5);
        while !*g {
            let now = Instant::now();
            assert!(now < deadline, "wakeup lost");
            let (back, _timed_out) =
                state.1.wait_timeout(g, deadline - now);
            g = back;
        }
        drop(g);
        t.join().unwrap();
    }

    #[test]
    fn condvar_wait_keeps_rank_held_but_releases_the_lock() {
        // While one thread waits on a ResponseSlot-ranked mutex,
        // another thread must be able to take that same mutex (the
        // wait released it) — proving the rank stack tracks the
        // logical hold, not the physical one.
        let state = Arc::new((
            RankedMutex::new(Rank::ResponseSlot, 0u32),
            RankedCondvar::new(),
        ));
        let s2 = state.clone();
        let waiter = std::thread::spawn(move || {
            let mut g = s2.0.lock();
            while *g == 0 {
                g = s2.1.wait(g);
            }
            *g
        });
        // busy-wait until the waiter almost certainly parked, then
        // write through the same mutex and wake it
        std::thread::sleep(Duration::from_millis(10));
        {
            let mut g = state.0.lock();
            *g = 42;
        }
        state.1.notify_all();
        assert_eq!(waiter.join().unwrap(), 42);
    }
}
