//! Minimal host-side f32 tensor used by checkpoints, eval and analysis.
//!
//! This is deliberately not an ML library — device compute happens inside
//! the AOT XLA executables.  `Tensor` exists so the coordinator can slice
//! named parameters out of flat buffers, compute metrics over outputs, and
//! build similarity matrices without hand-rolled index math at every call
//! site.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product::<usize>().max(1);
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor of {} elems", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Row `i` of a 2-D (or higher: leading-index slice) tensor.
    pub fn index(&self, i: usize) -> Result<Tensor> {
        if self.shape.is_empty() {
            bail!("index() on scalar");
        }
        let stride: usize = self.shape[1..].iter().product::<usize>().max(1);
        if i >= self.shape[0] {
            bail!("index {} out of bounds for dim {}", i, self.shape[0]);
        }
        Ok(Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * stride..(i + 1) * stride].to_vec(),
        })
    }

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product::<usize>().max(1);
        if n != self.data.len() {
            bail!("cannot reshape {} elems to {:?}", self.data.len(), shape);
        }
        self.shape = shape;
        Ok(self)
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn max(&self) -> f32 {
        self.data.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn dot(&self, other: &Tensor) -> Result<f32> {
        if self.data.len() != other.data.len() {
            bail!("dot: {} vs {} elems", self.data.len(), other.data.len());
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .sum())
    }

    pub fn cosine(&self, other: &Tensor) -> Result<f32> {
        let d = self.dot(other)?;
        let n = self.l2_norm() * other.l2_norm();
        Ok(if n > 0.0 { d / n } else { 0.0 })
    }

    /// argmax over the last axis; returns indices shaped like the leading axes.
    pub fn argmax_last(&self) -> Result<Vec<usize>> {
        if self.shape.is_empty() {
            bail!("argmax on scalar");
        }
        let last = *self.shape.last().unwrap();
        let rows = self.data.len() / last;
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &self.data[r * last..(r + 1) * last];
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            out.push(best);
        }
        Ok(out)
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::new(vec![], vec![1.0]).is_ok());
    }

    #[test]
    fn index_rows() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.index(1).unwrap().data, vec![3.0, 4.0, 5.0]);
        assert!(t.index(2).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.mean(), 2.5);
        assert_eq!(t.max(), 4.0);
        assert!((t.l2_norm() - 30.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn cosine_identity_and_orthogonal() {
        let a = Tensor::new(vec![2], vec![1.0, 0.0]).unwrap();
        let b = Tensor::new(vec![2], vec![0.0, 1.0]).unwrap();
        assert!((a.cosine(&a).unwrap() - 1.0).abs() < 1e-6);
        assert!(a.cosine(&b).unwrap().abs() < 1e-6);
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 5.0, 1.0, 9.0, 2.0, 3.0]).unwrap();
        assert_eq!(t.argmax_last().unwrap(), vec![1, 0]);
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(&[6]);
        assert!(t.clone().reshape(vec![2, 3]).is_ok());
        assert!(t.reshape(vec![4]).is_err());
    }
}
