//! Tiny argument parser (no `clap` in the vendored crate set).
//!
//! Supports `binary <subcommand> [--flag value] [--switch] [positional...]`
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: one subcommand, flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  Flags may be `--key value` or `--key=value`;
    /// a flag without a following value is a boolean switch.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str_opt(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?}: not an integer ({e})")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?}: not an integer ({e})")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?}: not a number ({e})")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
            || self.flags.get(switch).map(|v| v == "true").unwrap_or(false)
    }

    /// Comma-separated list flag, e.g. `--caps 0.25,0.5,1.0`.
    pub fn f64_list_or(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.flags.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .map_err(|e| anyhow!("--{key}: bad element {p:?} ({e})"))
                })
                .collect(),
        }
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.str_opt(key)
            .ok_or_else(|| anyhow!("missing required flag --{key}"))
    }

    /// Reject unknown flags (catches typos in experiment invocations).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --verbose --config lm_tiny --steps 100 out.bin");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_opt("config"), Some("lm_tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["out.bin"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("exp --lr=0.001 --caps=0.25,0.5");
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.001).abs() < 1e-12);
        assert_eq!(a.f64_list_or("caps", &[]).unwrap(), vec![0.25, 0.5]);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("config", "lm_tiny"), "lm_tiny");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --steps abc");
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --stepz 5");
        assert!(a.check_known(&["steps"]).is_err());
        assert!(a.check_known(&["stepz"]).is_ok());
    }

    #[test]
    fn trailing_switch() {
        let a = parse("x --flag");
        assert!(a.has("flag"));
    }
}
