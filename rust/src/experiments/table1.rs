//! Table 1 — trainable parameters introduced by ElastiFormer, per routing
//! module and as a percentage of the pretrained base model.
//!
//! Counts come straight from the AOT manifests (the same layout tables the
//! runtime executes against), so the table is ground truth for this build,
//! not a re-derivation.

use anyhow::Result;

use crate::bench::{fmt_f, Table};
use crate::runtime::Manifest;

use super::common::{artifacts_dir, save_table};

fn family_of(name: &str) -> &'static str {
    if name.contains("r_mha_in") {
        "input/MHA"
    } else if name.contains("r_mlp_in") {
        "input/MLP"
    } else if name.contains("r_heads") {
        "param/MHA(heads)"
    } else if name.contains("r_experts") {
        "param/MLP(experts)"
    } else if name.contains("lora") {
        "LoRA(q,v)"
    } else if name.contains("r_img") {
        "input/VLM(img)"
    } else {
        "other"
    }
}

pub fn run(configs: &[&str]) -> Result<Table> {
    let mut table = Table::new(&[
        "config", "router_table", "family", "params", "pct_of_teacher",
    ]);
    for cfg in configs {
        let man = match Manifest::load(
            std::path::Path::new(&artifacts_dir()).join(cfg)) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("[table1] skipping {cfg}: {e}");
                continue;
            }
        };
        let teacher_total = man.teacher_params.total() as f64;
        for (key, tab) in &man.router_params {
            let mut fam_counts: Vec<(&'static str, usize)> = Vec::new();
            for e in &tab.entries {
                let fam = family_of(&e.name);
                match fam_counts.iter_mut().find(|(f, _)| *f == fam) {
                    Some((_, c)) => *c += e.size,
                    None => fam_counts.push((fam, e.size)),
                }
            }
            for (fam, count) in &fam_counts {
                table.row(vec![
                    cfg.to_string(),
                    key.clone(),
                    fam.to_string(),
                    count.to_string(),
                    format!("{}%", fmt_f(100.0 * *count as f64 / teacher_total, 4)),
                ]);
            }
            table.row(vec![
                cfg.to_string(),
                key.clone(),
                "TOTAL".into(),
                tab.total().to_string(),
                format!("{}%",
                        fmt_f(100.0 * tab.total() as f64 / teacher_total, 4)),
            ]);
        }
    }
    save_table(
        "table1_router_params", &table,
        "Paper Table 1: trainable parameters introduced by ElastiFormer \
         (counts from the AOT manifests; percentages of the frozen teacher). \
         The paper reports 0.00006%-0.25% at 2B-7B scale; at this repro's \
         model sizes the same formulas give larger ratios since router cost \
         scales as L*D while the model scales as L*D^2.")?;
    Ok(table)
}
