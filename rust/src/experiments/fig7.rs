//! Figure 7 — Elasti-ViT: capacity scaling with all-layer vs even-layer
//! routing.
//!
//! The ViT-MAE stand-in (autoencoder teacher, see DESIGN.md §2) is routed
//! per scheme and capacity; the metric is cosine similarity between the
//! frozen decoder's outputs on student vs teacher encodings, with 0.95 as
//! the paper's recovery threshold.  Even-layer routing (half the layers
//! dense) should dominate all-layer routing at equal capacity.

use anyhow::Result;

use crate::bench::{fmt_f, Table};
use crate::coordinator::trainer::{layer_enable, Caps, Trainer};
use crate::data::{imagen, Batcher};
use crate::runtime::client::Arg;

use super::common::{self, Ctx};
use super::fig5::Scheme;

pub struct Fig7Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub distill_steps: usize,
    pub eval_batches: usize,
    pub caps: Vec<f64>,
    pub seed: u64,
}

impl Default for Fig7Opts {
    fn default() -> Self {
        Fig7Opts {
            config: "vit_tiny".into(),
            pretrain_steps: 250,
            distill_steps: 60,
            eval_batches: 3,
            caps: vec![0.25, 0.5, 0.75],
            seed: 42,
        }
    }
}

/// Mean decoder-output cosine similarity over eval image batches.
pub fn vit_cosine(ctx: &Ctx, params: &[f32], router: &[f32],
                  batches: &[Vec<f32>], caps: Caps, layer_en: &[f32],
                  mode: f32) -> Result<f64> {
    let b = ctx.rt.manifest.batch();
    let mut acc = 0.0f64;
    for images in batches {
        let out = ctx.rt.exec("elastic_forward", &[
            Arg::F32(params),
            Arg::F32(router),
            Arg::F32(images),
            Arg::F32(&caps.0),
            Arg::F32(layer_en),
            Arg::ScalarF32(mode),
        ])?;
        let cos = out.f32(3)?; // [B]
        acc += cos.iter().map(|&c| c as f64).sum::<f64>() / b as f64;
    }
    Ok(acc / batches.len() as f64)
}

/// Train a ViT router at `caps`/`layer_en`, return (cosine, router).
#[allow(clippy::too_many_arguments)]
pub fn distill_and_eval_vit(ctx: &Ctx, teacher: &[f32], steps: usize,
                            caps: Caps, layer_en: &[f32],
                            train_class: Option<usize>,
                            eval_batches: &[Vec<f32>], seed: u64)
                            -> Result<(f64, Vec<f32>)> {
    let router = ctx.router_init("router_init", seed as i32)?;
    let b = ctx.rt.manifest.batch();
    let size = ctx.rt.manifest.cfg_usize("img_size")?;
    let imgs: Vec<Vec<f32>> =
        imagen::dataset(400, size, train_class, seed ^ 0x7114)
            .into_iter()
            .map(|(im, _)| im)
            .collect();
    let mut batcher = Batcher::new(imgs.len(), b, seed ^ 6);
    let mut trainer = Trainer::new(&ctx.rt);
    let (router, _) = trainer.distill_vit(
        "distill_step", teacher, router, steps, 1e-3, caps, layer_en,
        || batcher.next_f32(&imgs))?;
    let cos = vit_cosine(ctx, teacher, &router, eval_batches, caps,
                         layer_en, 0.0)?;
    Ok((cos, router))
}

pub fn eval_image_batches(ctx: &Ctx, n_batches: usize, seed: u64)
                          -> Result<Vec<Vec<f32>>> {
    let b = ctx.rt.manifest.batch();
    let size = ctx.rt.manifest.cfg_usize("img_size")?;
    let imgs: Vec<Vec<f32>> = imagen::dataset(n_batches * b, size, None, seed)
        .into_iter()
        .map(|(im, _)| im)
        .collect();
    let mut batcher = Batcher::new(imgs.len(), b, seed ^ 7);
    Ok((0..n_batches).map(|_| batcher.next_f32(&imgs)).collect())
}

pub fn run(opts: &Fig7Opts) -> Result<Table> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps)?;
    let l = ctx.rt.manifest.n_layers();
    let eval_batches = eval_image_batches(&ctx, opts.eval_batches, 0xE7A1)?;

    let mut table = Table::new(&[
        "layers", "scheme", "capacity", "decoder_cosine", "above_0.95",
    ]);
    for layers in ["all", "even"] {
        let layer_en = layer_enable(l, layers)?;
        for scheme in Scheme::ALL {
            for &c in &opts.caps {
                let caps = scheme.caps(c as f32);
                let (cos, _) = distill_and_eval_vit(
                    &ctx, &teacher, opts.distill_steps, caps, &layer_en,
                    None, &eval_batches,
                    opts.seed ^ (c * 997.0) as u64
                        ^ ((layers == "even") as u64) << 32)?;
                println!("[fig7] {layers}/{} cap={c:.2}: cosine {cos:.4}",
                         scheme.name());
                table.row(vec![
                    layers.into(),
                    scheme.name().into(),
                    fmt_f(c, 3),
                    fmt_f(cos, 4),
                    (if cos > 0.95 { "yes" } else { "no" }).into(),
                ]);
            }
        }
    }
    common::save_table(
        "fig7_elasti_vit_scaling", &table,
        "Paper Fig. 7: Elasti-ViT decoder-output cosine similarity vs \
         capacity, all-layer vs even-layer routing (0.95 = recovery \
         threshold). Expected shape: even-layer routing achieves higher \
         cosine at the same per-layer capacity, and input/MLP token routing \
         is the most tolerant scheme.")?;
    Ok(table)
}
