//! Figure 5 — Elasti-LLM: performance vs capacity for each of the four
//! routing schemes (input/MHA, input/MLP, param/heads, param/experts).
//!
//! For every (scheme, capacity) point a fresh router is trained by
//! self-distillation against the frozen teacher (only that scheme's
//! capacity is reduced; the others stay at 1.0 where distillation drives
//! them to identity), then the elastic LM loss is measured on held-out
//! math problems and reported next to the teacher's loss and the analytic
//! compute ratio — the paper's y/x axes.

use anyhow::Result;

use crate::analysis::flops::{self, Capacity};
use crate::bench::{fmt_f, Table};
use crate::coordinator::trainer::{Caps, Trainer};
use crate::data::{Batcher, TextDataset};

use super::common::{self, Ctx};

pub struct Fig5Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub distill_steps: usize,
    pub eval_batches: usize,
    pub caps: Vec<f64>,
    pub seed: u64,
}

impl Default for Fig5Opts {
    fn default() -> Self {
        Fig5Opts {
            config: "lm_tiny".into(),
            pretrain_steps: 300,
            distill_steps: 80,
            eval_batches: 4,
            caps: vec![0.25, 0.5, 0.75, 1.0],
            seed: 42,
        }
    }
}

/// Which single routing scheme a sweep point constrains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    InputMha,
    InputMlp,
    ParamHeads,
    ParamExperts,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [
        Scheme::InputMha, Scheme::InputMlp, Scheme::ParamHeads,
        Scheme::ParamExperts,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::InputMha => "input/MHA",
            Scheme::InputMlp => "input/MLP",
            Scheme::ParamHeads => "param/heads",
            Scheme::ParamExperts => "param/experts",
        }
    }

    pub fn caps(&self, c: f32) -> Caps {
        let mut v = [1.0f32; 4];
        match self {
            Scheme::InputMha => v[0] = c,
            Scheme::InputMlp => v[1] = c,
            Scheme::ParamHeads => v[2] = c,
            Scheme::ParamExperts => v[3] = c,
        }
        Caps(v)
    }

    pub fn capacity_struct(&self, c: f64) -> Capacity {
        let mut cap = Capacity::full();
        match self {
            Scheme::InputMha => cap.mha_tokens = c,
            Scheme::InputMlp => cap.mlp_tokens = c,
            Scheme::ParamHeads => cap.heads = c,
            Scheme::ParamExperts => cap.experts = c,
        }
        cap
    }
}

/// Train a router at `caps` by self-distillation, then return the held-out
/// elastic loss and the trained router.  Shared by the fig4/5/6 sweeps.
#[allow(clippy::too_many_arguments)]
pub fn distill_and_eval(ctx: &Ctx, entry_distill: &str, entry_fwd: &str,
                        router_init_entry: &str, teacher: &[f32],
                        student: &[f32], steps: usize, caps: Caps,
                        layer_en: &[f32], temp: f32,
                        eval_batches: &[Vec<i32>], seed: u64)
                        -> Result<(f64, Vec<f32>)> {
    let router = ctx.router_init(router_init_entry, seed as i32)?;
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let train_ds = TextDataset::from_texts(
        &common::gsm_train_texts(600, seed ^ 0x6590), t);
    let mut batcher = Batcher::new(train_ds.len(), b, seed ^ 4);
    let mut trainer = Trainer::new(&ctx.rt);
    let (router, _) = trainer.distill_lm(
        entry_distill, teacher, student, router, steps, 1e-3, caps,
        layer_en, temp, || batcher.next_tokens(&train_ds))?;
    let loss = ctx.lm_elastic_loss(entry_fwd, student, &router, eval_batches,
                                   caps, layer_en, 0.0)?;
    Ok((loss, router))
}

pub fn run(opts: &Fig5Opts) -> Result<Table> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps)?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let eval_batches = ctx.lm_eval_batches(
        &common::gsm_eval_texts(200), opts.eval_batches, 7);
    let teacher_loss = ctx.lm_teacher_loss(&teacher, &eval_batches)?;
    let dims = ctx.rt.manifest.dims()?;

    let mut table = Table::new(&[
        "scheme", "capacity", "elastic_lm_loss", "teacher_lm_loss",
        "macs_ratio",
    ]);
    for scheme in Scheme::ALL {
        for &c in &opts.caps {
            let caps = scheme.caps(c as f32);
            let (loss, _) = distill_and_eval(
                &ctx, "distill_step_r0", "elastic_forward_r0",
                "router_init_r0", &teacher, &teacher, opts.distill_steps,
                caps, &layer_en, 1.0, &eval_batches,
                opts.seed ^ (c * 1000.0) as u64)?;
            let ratio = flops::elastic_macs(&dims, &scheme.capacity_struct(c))
                as f64
                / flops::teacher_macs(&dims) as f64;
            println!("[fig5] {} cap={c:.2}: loss {loss:.4} (teacher \
                      {teacher_loss:.4}), macs {ratio:.3}",
                     scheme.name());
            table.row(vec![
                scheme.name().into(),
                fmt_f(c, 3),
                fmt_f(loss, 4),
                fmt_f(teacher_loss, 4),
                fmt_f(ratio, 4),
            ]);
        }
    }
    common::save_table(
        "fig5_elasti_llm_scaling", &table,
        "Paper Fig. 5: Elasti-LLM loss vs capacity per routing scheme. \
         Expected shape: param/heads and param/experts recover teacher loss \
         well below capacity 1 (paper: 38% heads, 56% experts); input/MLP \
         tolerates ~20% token drop; input/MHA degrades fastest and does not \
         reach teacher loss without LoRA (cf. Fig. 6).")?;
    Ok(table)
}
