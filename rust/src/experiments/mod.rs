//! One driver per paper figure/table (experiment index in DESIGN.md §4).
//!
//! Every driver prints the table the paper's artifact reports and writes
//! `results/<name>.{md,csv}`; EXPERIMENTS.md records paper-vs-measured.

pub mod common;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod qualitative;
pub mod table1;
