//! Figure 9 — Elasti-VLM: image-token capacity vs caption quality,
//! linear vs MLP router.
//!
//! The VLM teacher (vision tower -> projector -> LM decoder) is distilled
//! with an image-token selection router at each capacity; evaluation
//! generates captions at inference-time routing (0.5 threshold) and scores
//! them with (a) the LLaVA-Bench stand-in — teacher-match F1 with a 95%
//! bootstrap CI over 100 resamples, exactly the paper's protocol — and
//! (b) the OpenCHAIR stand-in — exact attribute recall / hallucination
//! against the generator's ground-truth scenes.

use anyhow::Result;

use crate::bench::{fmt_f, Table};
use crate::coordinator::generation::generate_vlm;
use crate::coordinator::trainer::Trainer;
use crate::data::capgen;
use crate::metrics::bootstrap_ci;
use crate::rng::Rng;

use super::common::{self, vlm_dataset, vlm_scenes, Ctx};

pub struct Fig9Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub distill_steps: usize,
    pub caps: Vec<f64>,
    pub n_eval_images: usize,
    pub seed: u64,
}

impl Default for Fig9Opts {
    fn default() -> Self {
        Fig9Opts {
            config: "vlm_tiny".into(),
            pretrain_steps: 400,
            distill_steps: 60,
            caps: vec![0.25, 0.5, 0.75, 1.0],
            n_eval_images: 32,
            seed: 42,
        }
    }
}

struct EvalScores {
    match_mean: f64,
    match_lo: f64,
    match_hi: f64,
    recall: f64,
    halluc: f64,
}

#[allow(clippy::too_many_arguments)]
fn eval_captions(ctx: &Ctx, entry_fwd: &str, teacher: &[f32], router: &[f32],
                 capacity: f32, eval_imgs: &[Vec<f32>],
                 scenes: &[crate::data::imagen::Scene],
                 teacher_caps: &[String], seed: u64) -> Result<EvalScores> {
    let b = ctx.rt.manifest.batch();
    let mut match_scores = Vec::new();
    let mut recalls = Vec::new();
    let mut hallucs = Vec::new();
    for (chunk_i, chunk) in eval_imgs.chunks(b).enumerate() {
        if chunk.len() < b {
            break;
        }
        let flat: Vec<f32> = chunk.iter().flatten().copied().collect();
        let caps_out = generate_vlm(&ctx.rt, entry_fwd, teacher, router,
                                    &flat, capacity, 1.0, 24)?;
        for (i, cap) in caps_out.iter().enumerate() {
            let global = chunk_i * b + i;
            match_scores.push(capgen::teacher_match_score(
                cap, &teacher_caps[global]));
            let sc = capgen::score_caption(cap, &scenes[global]);
            recalls.push(sc.recall);
            hallucs.push(sc.hallucination);
        }
    }
    let (mean, lo, hi) = bootstrap_ci(&match_scores, 100, 0.95, seed);
    Ok(EvalScores {
        match_mean: mean,
        match_lo: lo,
        match_hi: hi,
        recall: recalls.iter().sum::<f64>() / recalls.len().max(1) as f64,
        halluc: hallucs.iter().sum::<f64>() / hallucs.len().max(1) as f64,
    })
}

pub fn run(opts: &Fig9Opts) -> Result<Table> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps)?;
    let b = ctx.rt.manifest.batch();
    let n_eval = (opts.n_eval_images / b) * b;

    // held-out eval images + ground-truth scenes
    let eval_seed = 0xE9A3u64;
    let (eval_imgs, _) = vlm_dataset(&ctx.rt, n_eval, eval_seed)?;
    let scenes = vlm_scenes(&ctx.rt, n_eval, eval_seed)?;

    // teacher reference captions (capacity 1, bypass) via the linear entry
    let r_lin_init = ctx.router_init("router_init_lin", opts.seed as i32)?;
    let mut teacher_caps = Vec::with_capacity(n_eval);
    for chunk in eval_imgs.chunks(b) {
        let flat: Vec<f32> = chunk.iter().flatten().copied().collect();
        teacher_caps.extend(generate_vlm(
            &ctx.rt, "elastic_forward_lin", &teacher, &r_lin_init, &flat,
            1.0, 2.0, 24)?);
    }

    // training data stream
    let (train_imgs, train_caps) =
        vlm_dataset(&ctx.rt, 600, opts.seed ^ 0x99A)?;

    let mut table = Table::new(&[
        "router", "capacity", "llava_bench_like(F1)", "ci95",
        "openchair_recall", "openchair_halluc",
    ]);
    for (router_kind, init_entry, distill_entry, fwd_entry) in [
        ("linear", "router_init_lin", "distill_step_lin",
         "elastic_forward_lin"),
        ("mlp", "router_init_mlp", "distill_step_mlp",
         "elastic_forward_mlp"),
    ] {
        for &c in &opts.caps {
            let router = if c >= 1.0 {
                ctx.router_init(init_entry, opts.seed as i32)?
            } else {
                let r0 = ctx.router_init(init_entry, opts.seed as i32)?;
                let mut rng = Rng::new(opts.seed ^ 8 ^ (c * 100.0) as u64);
                let mut trainer = Trainer::new(&ctx.rt);
                let (r, _) = trainer.distill_vlm(
                    distill_entry, &teacher, r0, opts.distill_steps, 1e-3,
                    c as f32, 1.0, || {
                        let mut fi = Vec::new();
                        let mut ft = Vec::new();
                        for _ in 0..b {
                            let i = rng.below(train_imgs.len());
                            fi.extend_from_slice(&train_imgs[i]);
                            ft.extend_from_slice(&train_caps[i]);
                        }
                        (fi, ft)
                    })?;
                r
            };
            let mode_cap = if c >= 1.0 { 2.0 } else { 1.0 };
            let scores = eval_captions(
                &ctx, fwd_entry, &teacher, &router, c as f32, &eval_imgs,
                &scenes, &teacher_caps,
                opts.seed ^ (c * 31.0) as u64)?;
            // capacity 1 bypass for reference rows
            let _ = mode_cap;
            println!("[fig9] {router_kind} cap={c:.2}: match \
                      {:.3} [{:.3},{:.3}], recall {:.3}, halluc {:.3}",
                     scores.match_mean, scores.match_lo, scores.match_hi,
                     scores.recall, scores.halluc);
            table.row(vec![
                router_kind.into(),
                fmt_f(c, 2),
                fmt_f(scores.match_mean, 3),
                format!("[{}, {}]", fmt_f(scores.match_lo, 3),
                        fmt_f(scores.match_hi, 3)),
                fmt_f(scores.recall, 3),
                fmt_f(scores.halluc, 3),
            ]);
        }
    }
    common::save_table(
        "fig9_elasti_vlm", &table,
        "Paper Fig. 9: Elasti-VLM caption quality vs image-token capacity \
         (linear vs MLP router; 95% bootstrap CI, 100 resamples). Expected \
         shape: ~60-70% of image tokens suffice to match the base model on \
         the LLaVA-Bench-like score; detail-oriented metrics (recall / \
         hallucination) degrade at low capacity; the MLP router is at or \
         above the linear router.")?;
    Ok(table)
}
