//! Shared experiment context: artifact/runtime loading, teacher
//! provisioning (pretrain-once-and-cache), eval-set construction and the
//! result-file conventions every figure driver uses.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::coordinator::trainer::{BatchArg, Caps, Trainer};
use crate::data::{imagen, mathgen, textgen, Batcher, TextDataset};
use crate::data::capgen;
use crate::metrics::write_file;
use crate::rng::Rng;
use crate::runtime::client::Arg;
use crate::runtime::Runtime;

pub fn artifacts_dir() -> String {
    std::env::var("ELASTIFORMER_ARTIFACTS").unwrap_or_else(|_| {
        // works from the repo root and from target/ subdirs
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            if Path::new(cand).join("lm_tiny/manifest.json").exists() {
                return cand.to_string();
            }
        }
        "artifacts".to_string()
    })
}

pub fn results_dir() -> PathBuf {
    PathBuf::from(
        std::env::var("ELASTIFORMER_RESULTS")
            .unwrap_or_else(|_| "results".to_string()),
    )
}

/// Write both .md and .csv renderings of a results table.
pub fn save_table(name: &str, table: &crate::bench::Table, note: &str)
                  -> Result<()> {
    let dir = results_dir();
    let md = format!("# {name}\n\n{note}\n\n{}", table.to_markdown());
    write_file(dir.join(format!("{name}.md")), &md)?;
    write_file(dir.join(format!("{name}.csv")), &table.to_csv())?;
    Ok(())
}

/// Experiment context for one artifact config.
pub struct Ctx {
    pub rt: Runtime,
    pub seed: u64,
}

impl Ctx {
    pub fn load(config: &str, seed: u64) -> Result<Ctx> {
        let rt = Runtime::load(&artifacts_dir(), config)
            .with_context(|| format!("loading artifacts for {config}"))?;
        Ok(Ctx { rt, seed })
    }

    fn ckpt_path(&self, kind: &str) -> PathBuf {
        results_dir()
            .join("ckpt")
            .join(format!("{}_{kind}.bin", self.rt.manifest.name()))
    }

    /// Teacher params: load the cached checkpoint if its provenance
    /// matches, otherwise pretrain `steps` steps on the synthetic corpus
    /// and cache.  All experiments for a config share this teacher, like
    /// the paper's shared pretrained base model.
    pub fn teacher(&self, steps: usize) -> Result<Vec<f32>> {
        let path = self.ckpt_path(&format!("teacher_s{steps}"));
        let expect_n = self.rt.manifest.teacher_params.total();
        if let Ok(ck) = Checkpoint::load(&path) {
            if ck.expect(self.rt.manifest.name(), "teacher", expect_n).is_ok() {
                return Ok(ck.params);
            }
        }
        let kind = self.rt.manifest.kind().to_string();
        eprintln!("[ctx] pretraining {} teacher for {steps} steps ...",
                  self.rt.manifest.name());
        let params = match kind.as_str() {
            "lm" => self.pretrain_lm(steps)?,
            "vit" => self.pretrain_vit(steps)?,
            "vlm" => self.pretrain_vlm(steps)?,
            k => anyhow::bail!("unknown model kind {k}"),
        };
        Checkpoint::new(self.rt.manifest.name(), "teacher", steps as u64,
                        params.clone())
            .save(&path)?;
        Ok(params)
    }

    fn pretrain_lm(&self, steps: usize) -> Result<Vec<f32>> {
        let mut trainer = Trainer::new(&self.rt);
        let init = trainer.init_params("init", self.seed as i32)?;
        let b = self.rt.manifest.batch();
        let t = self.rt.manifest.seq_len();
        let ds = TextDataset::from_texts(
            &textgen::dataset(2000, self.seed ^ 0x7e47), t);
        let mut batcher = Batcher::new(ds.len(), b, self.seed ^ 1);
        let (params, losses) = trainer.pretrain(
            "pretrain_step", init, steps, 3e-3,
            || vec![BatchArg::Tokens(batcher.next_tokens(&ds))])?;
        eprintln!("[ctx] lm pretrain: loss {:.3} -> {:.3}",
                  losses.first().unwrap_or(&0.0),
                  losses.last().unwrap_or(&0.0));
        Ok(params)
    }

    fn pretrain_vit(&self, steps: usize) -> Result<Vec<f32>> {
        let mut trainer = Trainer::new(&self.rt);
        let init = trainer.init_params("init", self.seed as i32)?;
        let b = self.rt.manifest.batch();
        let size = self.rt.manifest.cfg_usize("img_size")?;
        let imgs: Vec<Vec<f32>> = imagen::dataset(800, size, None,
                                                  self.seed ^ 0x1147)
            .into_iter()
            .map(|(im, _)| im)
            .collect();
        let mut batcher = Batcher::new(imgs.len(), b, self.seed ^ 2);
        let (params, losses) = trainer.pretrain(
            "pretrain_step", init, steps, 3e-3,
            || vec![BatchArg::Floats(batcher.next_f32(&imgs))])?;
        eprintln!("[ctx] vit pretrain: loss {:.4} -> {:.4}",
                  losses.first().unwrap_or(&0.0),
                  losses.last().unwrap_or(&0.0));
        Ok(params)
    }

    fn pretrain_vlm(&self, steps: usize) -> Result<Vec<f32>> {
        let mut trainer = Trainer::new(&self.rt);
        let init = trainer.init_params("init", self.seed as i32)?;
        let b = self.rt.manifest.batch();
        let (imgs, caps) = vlm_dataset(&self.rt, 800, self.seed ^ 0x9a21)?;
        let mut batcher = Batcher::new(imgs.len(), b, self.seed ^ 3);
        let (params, losses) = trainer.pretrain(
            "pretrain_step", init, steps, 3e-3, || {
                let idx = batcher.next_indices();
                let mut fi = Vec::new();
                let mut ft = Vec::new();
                for &i in &idx {
                    fi.extend_from_slice(&imgs[i]);
                    ft.extend_from_slice(&caps[i]);
                }
                vec![BatchArg::Floats(fi), BatchArg::Tokens(ft)]
            })?;
        eprintln!("[ctx] vlm pretrain: loss {:.3} -> {:.3}",
                  losses.first().unwrap_or(&0.0),
                  losses.last().unwrap_or(&0.0));
        Ok(params)
    }

    /// Router init via the AOT entry (e.g. "router_init_r0").
    pub fn router_init(&self, entry: &str, seed: i32) -> Result<Vec<f32>> {
        Trainer::new(&self.rt).init_params(entry, seed)
    }

    /// Held-out LM eval batches (flat [B*T] token rows) from a generator.
    pub fn lm_eval_batches(&self, texts: &[String], n_batches: usize,
                           seed: u64) -> Vec<Vec<i32>> {
        let b = self.rt.manifest.batch();
        let t = self.rt.manifest.seq_len();
        let ds = TextDataset::from_texts(texts, t);
        let mut batcher = Batcher::new(ds.len(), b, seed);
        (0..n_batches).map(|_| batcher.next_tokens(&ds)).collect()
    }

    /// Mean elastic LM loss over eval batches (mode matches the paper's
    /// training-phase top-k selection for scaling figures).
    #[allow(clippy::too_many_arguments)]
    pub fn lm_elastic_loss(&self, entry: &str, params: &[f32], router: &[f32],
                           batches: &[Vec<i32>], caps: Caps,
                           layer_en: &[f32], mode: f32) -> Result<f64> {
        let mut acc = 0.0f64;
        for tokens in batches {
            let out = self.rt.exec(entry, &[
                Arg::F32(params),
                Arg::F32(router),
                Arg::I32(tokens),
                Arg::F32(&caps.0),
                Arg::F32(layer_en),
                Arg::ScalarF32(mode),
            ])?;
            acc += out.scalar_f32(1)? as f64;
        }
        Ok(acc / batches.len() as f64)
    }

    /// Mean teacher LM loss over eval batches (no pruning).
    pub fn lm_teacher_loss(&self, params: &[f32], batches: &[Vec<i32>])
                           -> Result<f64> {
        let l = self.rt.manifest.n_layers();
        let h = self.rt.manifest.n_heads();
        let head_mask = vec![1.0f32; l * h];
        let ones = vec![1.0f32; l];
        let mut acc = 0.0f64;
        for tokens in batches {
            let out = self.rt.exec("teacher_forward", &[
                Arg::F32(params),
                Arg::I32(tokens),
                Arg::F32(&head_mask),
                Arg::F32(&ones),
                Arg::F32(&ones),
            ])?;
            acc += out.scalar_f32(1)? as f64;
        }
        Ok(acc / batches.len() as f64)
    }
}

/// Paired (image, caption-token) VLM dataset with scenes recoverable by
/// seed (the Fig. 9 eval regenerates scenes from the same seed).
pub fn vlm_dataset(rt: &Runtime, n: usize, seed: u64)
                   -> Result<(Vec<Vec<f32>>, Vec<Vec<i32>>)> {
    let size = rt.manifest.cfg_usize("img_size")?;
    let text_len = rt.manifest.cfg_usize("text_len")?;
    let tok = crate::data::Tokenizer::new();
    let mut rng = Rng::new(seed);
    let mut imgs = Vec::with_capacity(n);
    let mut texts = Vec::with_capacity(n);
    for (img, scene) in imagen::dataset(n, size, None, seed) {
        let cap = capgen::caption(&scene, &mut rng);
        imgs.push(img);
        texts.push(tok.encode_padded(&cap, text_len));
    }
    Ok((imgs, texts))
}

/// The scenes matching `vlm_dataset(rt, n, seed)` (same seed => same scenes).
pub fn vlm_scenes(rt: &Runtime, n: usize, seed: u64)
                  -> Result<Vec<imagen::Scene>> {
    let size = rt.manifest.cfg_usize("img_size")?;
    Ok(imagen::dataset(n, size, None, seed)
        .into_iter()
        .map(|(_, s)| s)
        .collect())
}

/// Eval text corpora for Fig. 2 / Fig. 5 (held-out seeds).
pub fn gsm_eval_texts(n: usize) -> Vec<String> {
    mathgen::dataset(n, 0xEEE1)
        .into_iter()
        .map(|p| p.full_text())
        .collect()
}

pub fn code_eval_texts(n: usize) -> Vec<String> {
    crate::data::codegen::dataset(n, 0xEEE2)
        .into_iter()
        .map(|s| s.full_text())
        .collect()
}

pub fn gsm_train_texts(n: usize, seed: u64) -> Vec<String> {
    mathgen::dataset(n, seed)
        .into_iter()
        .map(|p| p.full_text())
        .collect()
}
