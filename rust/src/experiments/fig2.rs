//! Figure 2 — structural redundancy in the pretrained LM.
//!
//! Progressively removes random attention heads / skips MLP layers from the
//! frozen teacher (no additional training, Appendix A) and measures, on
//! both the math (GSM8K-like) and code (HumanEval-like) corpora:
//!   * Δ LM loss  = loss(pruned) - loss(base)
//!   * Top-1 token prediction agreement with the base model
//! Each configuration averages 5 random removal groups, as in the paper.

use anyhow::Result;

use crate::bench::{fmt_f, Table};
use crate::eval;
use crate::rng::Rng;
use crate::runtime::client::Arg;

use super::common::{self, Ctx};

pub struct Fig2Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub groups: usize,
    pub eval_batches: usize,
    pub seed: u64,
}

impl Default for Fig2Opts {
    fn default() -> Self {
        Fig2Opts {
            config: "lm_tiny".into(),
            pretrain_steps: 300,
            groups: 5,
            eval_batches: 4,
            seed: 42,
        }
    }
}

struct PrunedEval {
    d_loss: f64,
    top1: f64,
}

fn eval_pruned(ctx: &Ctx, params: &[f32], batches: &[Vec<i32>],
               head_mask: &[f32], attn_on: &[f32], mlp_on: &[f32],
               base_logits: &[Vec<f32>], base_loss: f64) -> Result<PrunedEval> {
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let v = ctx.rt.manifest.vocab();
    let mut loss = 0.0f64;
    let mut top1 = 0.0f64;
    for (i, tokens) in batches.iter().enumerate() {
        let out = ctx.rt.exec("teacher_forward", &[
            Arg::F32(params),
            Arg::I32(tokens),
            Arg::F32(head_mask),
            Arg::F32(attn_on),
            Arg::F32(mlp_on),
        ])?;
        let logits = out.f32(0)?;
        loss += out.scalar_f32(1)? as f64;
        top1 += eval::top1_match(&logits, &base_logits[i], tokens, b, t, v)?;
    }
    let n = batches.len() as f64;
    Ok(PrunedEval { d_loss: loss / n - base_loss, top1: top1 / n })
}

pub fn run(opts: &Fig2Opts) -> Result<Table> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let params = ctx.teacher(opts.pretrain_steps)?;
    let l = ctx.rt.manifest.n_layers();
    let h = ctx.rt.manifest.n_heads();
    let ones_lh = vec![1.0f32; l * h];
    let ones_l = vec![1.0f32; l];

    let datasets: Vec<(&str, Vec<Vec<i32>>)> = vec![
        ("gsm8k-like",
         ctx.lm_eval_batches(&common::gsm_eval_texts(200),
                             opts.eval_batches, 7)),
        ("humaneval-like",
         ctx.lm_eval_batches(&common::code_eval_texts(200),
                             opts.eval_batches, 8)),
    ];

    // base logits + loss per dataset
    let mut base: Vec<(f64, Vec<Vec<f32>>)> = Vec::new();
    for (_, batches) in &datasets {
        let mut loss = 0.0f64;
        let mut logits_all = Vec::new();
        for tokens in batches {
            let out = ctx.rt.exec("teacher_forward", &[
                Arg::F32(&params),
                Arg::I32(tokens),
                Arg::F32(&ones_lh),
                Arg::F32(&ones_l),
                Arg::F32(&ones_l),
            ])?;
            logits_all.push(out.f32(0)?);
            loss += out.scalar_f32(1)? as f64;
        }
        base.push((loss / batches.len() as f64, logits_all));
    }

    let mut table = Table::new(&[
        "dataset", "component", "n_removed", "delta_lm_loss", "top1_match",
    ]);
    let mut rng = Rng::new(opts.seed ^ 0xF162);

    // --- remove attention heads ---
    let head_grid: Vec<usize> =
        (0..=l * h).step_by(((l * h) / 6).max(1)).collect();
    for (di, (dname, batches)) in datasets.iter().enumerate() {
        for &n_remove in &head_grid {
            let mut dl = 0.0;
            let mut tm = 0.0;
            for _ in 0..opts.groups {
                let mut hm = vec![1.0f32; l * h];
                for idx in rng.sample_indices(l * h, n_remove) {
                    hm[idx] = 0.0;
                }
                let e = eval_pruned(&ctx, &params, batches, &hm, &ones_l,
                                    &ones_l, &base[di].1, base[di].0)?;
                dl += e.d_loss;
                tm += e.top1;
            }
            let g = opts.groups as f64;
            table.row(vec![
                dname.to_string(),
                "attention-head".into(),
                n_remove.to_string(),
                fmt_f(dl / g, 4),
                fmt_f(tm / g, 4),
            ]);
        }
    }

    // --- skip MLP layers ---
    for (di, (dname, batches)) in datasets.iter().enumerate() {
        for n_skip in 0..=l {
            let mut dl = 0.0;
            let mut tm = 0.0;
            for _ in 0..opts.groups {
                let mut mlp_on = vec![1.0f32; l];
                for idx in rng.sample_indices(l, n_skip) {
                    mlp_on[idx] = 0.0;
                }
                let e = eval_pruned(&ctx, &params, batches, &ones_lh,
                                    &ones_l, &mlp_on, &base[di].1,
                                    base[di].0)?;
                dl += e.d_loss;
                tm += e.top1;
            }
            let g = opts.groups as f64;
            table.row(vec![
                dname.to_string(),
                "mlp-layer".into(),
                n_skip.to_string(),
                fmt_f(dl / g, 4),
                fmt_f(tm / g, 4),
            ]);
        }
    }

    common::save_table(
        "fig2_pruning_redundancy", &table,
        "Paper Fig. 2: random structural pruning of the pretrained teacher, \
         5 groups per point, no retraining.  Expected shape: small removals \
         are nearly free; MLP-layer skipping degrades faster than head \
         removal; curves differ between the two corpora (data-dependent \
         redundancy).")?;
    Ok(table)
}
