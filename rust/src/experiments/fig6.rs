//! Figure 6 — LoRA rescue of token-capacity routing.
//!
//! The ElastiFormer module is trained with input subset selection for both
//! MHA and MLP plus parameter subset selection for the MLP (top-2-of-4 in
//! the paper; half the experts here), sweeping the token capacity, for
//! several LoRA ranks r on the q/v projections.  The paper's claim: even
//! r = 1 recovers teacher-level loss at 80% token capacity, and the
//! rescued Elasti-LLM can dip *below* the teacher's loss.

use anyhow::Result;

use crate::bench::{fmt_f, Table};
use crate::coordinator::trainer::Caps;

use super::common::{self, Ctx};
use super::fig5::distill_and_eval;

pub struct Fig6Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub distill_steps: usize,
    pub eval_batches: usize,
    pub token_caps: Vec<f64>,
    pub ranks: Vec<usize>,
    pub seed: u64,
}

impl Default for Fig6Opts {
    fn default() -> Self {
        Fig6Opts {
            config: "lm_tiny".into(),
            pretrain_steps: 300,
            distill_steps: 80,
            eval_batches: 4,
            token_caps: vec![0.5, 0.7, 0.9],
            ranks: vec![0, 1, 8],
            seed: 42,
        }
    }
}

pub fn run(opts: &Fig6Opts) -> Result<Table> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps)?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let eval_batches = ctx.lm_eval_batches(
        &common::gsm_eval_texts(200), opts.eval_batches, 7);
    let teacher_loss = ctx.lm_teacher_loss(&teacher, &eval_batches)?;

    let mut table = Table::new(&[
        "lora_rank", "token_capacity", "elastic_lm_loss", "teacher_lm_loss",
        "delta",
    ]);
    for &rank in &opts.ranks {
        let distill_entry = format!("distill_step_r{rank}");
        let fwd_entry = format!("elastic_forward_r{rank}");
        let init_entry = format!("router_init_r{rank}");
        if !ctx.rt.has_entry(&distill_entry) {
            eprintln!("[fig6] skipping rank {rank}: {distill_entry} not \
                       lowered for {}", opts.config);
            continue;
        }
        for &c in &opts.token_caps {
            // paper setup: token routing on MHA+MLP, experts at half.
            let caps = Caps([c as f32, c as f32, 1.0, 0.5]);
            let (loss, _) = distill_and_eval(
                &ctx, &distill_entry, &fwd_entry, &init_entry, &teacher,
                &teacher, opts.distill_steps, caps, &layer_en, 1.0,
                &eval_batches,
                opts.seed ^ (rank as u64) << 16 ^ (c * 1000.0) as u64)?;
            println!("[fig6] r={rank} cap={c:.2}: loss {loss:.4} (teacher \
                      {teacher_loss:.4})");
            table.row(vec![
                rank.to_string(),
                fmt_f(c, 2),
                fmt_f(loss, 4),
                fmt_f(teacher_loss, 4),
                fmt_f(loss - teacher_loss, 4),
            ]);
        }
    }
    common::save_table(
        "fig6_lora_rank_rescue", &table,
        "Paper Fig. 6: token-capacity sweep (input selection on MHA+MLP, \
         experts at half capacity) for several LoRA(q,v) ranks. Expected \
         shape: rank 0 degrades visibly at low capacity; rank >= 1 recovers \
         close to (or below) teacher loss, with higher ranks strictly \
         better.")?;
    Ok(table)
}
