//! Figure 8 — robustness of learned routing across training domains.
//!
//! Ten Elasti-ViT router instances are trained, each on a single image
//! class (the ImageNet-subset stand-in); their MLP-token router scores on a
//! shared held-out image set form activation vectors whose 10x10 pairwise
//! cosine matrix the paper plots, plus per-image patch-selection heatmaps
//! across instances.

use anyhow::Result;

use crate::analysis::similarity::{ascii_heatmap, cosine_matrix, mask_iou};
use crate::bench::{fmt_f, Table};
use crate::coordinator::trainer::Caps;
use crate::data::imagen;
use crate::metrics::write_file;
use crate::runtime::client::Arg;

use super::common::{self, Ctx};
use super::fig7::distill_and_eval_vit;

pub struct Fig8Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub distill_steps: usize,
    pub capacity: f64,
    pub n_classes: usize,
    pub seed: u64,
}

impl Default for Fig8Opts {
    fn default() -> Self {
        Fig8Opts {
            config: "vit_tiny".into(),
            pretrain_steps: 250,
            distill_steps: 40,
            capacity: 0.5,
            n_classes: 10,
            seed: 42,
        }
    }
}

/// Router activations of one instance on one eval batch:
/// (flattened s_mlp scores over [L,N] per image, mask of layer 0).
fn router_activations(ctx: &Ctx, teacher: &[f32], router: &[f32],
                      images: &[f32], caps: Caps, layer_en: &[f32])
                      -> Result<(Vec<f32>, Vec<f32>)> {
    let out = ctx.rt.exec("elastic_forward", &[
        Arg::F32(teacher),
        Arg::F32(router),
        Arg::F32(images),
        Arg::F32(&caps.0),
        Arg::F32(layer_en),
        Arg::ScalarF32(0.0),
    ])?;
    let scores = out.f32(4)?; // s_mlp [B, L, N]
    let masks = out.f32(5)?;  // m_mlp [B, L, N]
    let b = ctx.rt.manifest.batch();
    let l = ctx.rt.manifest.n_layers();
    let n = scores.len() / (b * l);
    // first image, first layer mask -> heatmap
    let heat = masks[..n].to_vec();
    Ok((scores, heat))
}

pub fn run(opts: &Fig8Opts) -> Result<(Table, String)> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps)?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let caps = Caps([1.0, opts.capacity as f32, 1.0, 1.0]);

    // shared held-out eval batch (mixed classes)
    let eval = super::fig7::eval_image_batches(&ctx, 1, 0xE8A2)?;
    let eval_imgs = &eval[0];
    let n_classes = opts.n_classes.min(imagen::NUM_CLASSES);

    let mut activations = Vec::with_capacity(n_classes);
    let mut heatmaps = Vec::with_capacity(n_classes);
    for class in 0..n_classes {
        let (cos, router) = distill_and_eval_vit(
            &ctx, &teacher, opts.distill_steps, caps, &layer_en,
            Some(class), &eval, opts.seed ^ (class as u64) << 8)?;
        let (act, heat) = router_activations(&ctx, &teacher, &router,
                                             eval_imgs, caps, &layer_en)?;
        println!("[fig8] router trained on {:12}: eval cosine {cos:.4}",
                 imagen::CLASS_NAMES[class]);
        activations.push(act);
        heatmaps.push(heat);
    }

    let matrix = cosine_matrix(&activations)?;
    let mut table = Table::new(
        &std::iter::once("trained_on")
            .chain(imagen::CLASS_NAMES.iter().copied().take(n_classes))
            .collect::<Vec<_>>());
    for (i, row) in matrix.iter().enumerate() {
        let mut cells = vec![imagen::CLASS_NAMES[i].to_string()];
        cells.extend(row.iter().map(|v| fmt_f(*v, 3)));
        table.row(cells);
    }

    // patch heatmaps for the first eval image across instances + IoUs
    let side = (heatmaps[0].len() as f64).sqrt() as usize;
    let mut report = String::from(
        "# fig8 patch-selection heatmaps (first eval image)\n\n");
    let mut mean_iou = 0.0;
    let mut n_pairs = 0usize;
    for (i, heat) in heatmaps.iter().enumerate() {
        report.push_str(&format!("router trained on {}:\n```\n{}```\n",
                                 imagen::CLASS_NAMES[i],
                                 ascii_heatmap(heat, side)?));
        for other in heatmaps.iter().skip(i + 1) {
            mean_iou += mask_iou(heat, other)?;
            n_pairs += 1;
        }
    }
    if n_pairs > 0 {
        mean_iou /= n_pairs as f64;
    }
    report.push_str(&format!(
        "\nmean pairwise selection IoU across instances: {mean_iou:.3}\n"));

    common::save_table(
        "fig8_router_similarity", &table,
        "Paper Fig. 8 (left): pairwise cosine similarity of router logits \
         across Elasti-ViT instances trained on different image classes. \
         Expected shape: uniformly high similarity (routing is robust to \
         the training domain), with visually-related classes slightly more \
         similar.")?;
    write_file(common::results_dir().join("fig8_heatmaps.md"), &report)?;
    Ok((table, report))
}
