//! Qualitative outputs (paper Figures 10–12): side-by-side teacher vs
//! elastic generations for the LM, per-capacity reconstruction similarity
//! for the ViT, and per-capacity captions for the VLM.  Written to
//! `results/qualitative.md`.

use anyhow::Result;

use crate::coordinator::generation::{generate_lm, generate_vlm};
use crate::coordinator::trainer::{Caps, Trainer};
use crate::data::{mathgen, Batcher, TextDataset};
use crate::metrics::write_file;
use crate::rng::Rng;

use super::common::{self, vlm_dataset, vlm_scenes, Ctx};
use super::fig7;

pub struct QualOpts {
    pub pretrain_steps_lm: usize,
    pub pretrain_steps_vit: usize,
    pub pretrain_steps_vlm: usize,
    pub distill_steps: usize,
    pub seed: u64,
}

impl Default for QualOpts {
    fn default() -> Self {
        QualOpts {
            pretrain_steps_lm: 300,
            pretrain_steps_vit: 250,
            pretrain_steps_vlm: 400,
            distill_steps: 60,
            seed: 42,
        }
    }
}

fn fig10_lm(opts: &QualOpts, out: &mut String) -> Result<()> {
    let ctx = Ctx::load("lm_tiny", opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps_lm)?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];

    // Fig. 10 setup: input selection on MHA+MLP at 0.75, experts at half.
    let caps = Caps([0.75, 0.75, 1.0, 0.5]);
    let router0 = ctx.router_init("router_init_r1", opts.seed as i32)?;
    let b = ctx.rt.manifest.batch();
    let t = ctx.rt.manifest.seq_len();
    let ds = TextDataset::from_texts(
        &common::gsm_train_texts(600, opts.seed ^ 0x10F1), t);
    let mut batcher = Batcher::new(ds.len(), b, opts.seed ^ 9);
    let mut trainer = Trainer::new(&ctx.rt);
    let (router, _) = trainer.distill_lm(
        "distill_step_r1", &teacher, &teacher, router0, opts.distill_steps,
        1e-3, caps, &layer_en, 1.0, || batcher.next_tokens(&ds))?;

    let prompts: Vec<String> = mathgen::dataset(2, 0xF10)
        .into_iter()
        .map(|p| format!("Q: {} A:", p.question))
        .collect();
    let teacher_out = generate_lm(
        &ctx.rt, "elastic_forward_r1", &teacher, &router, &prompts, 48,
        Caps::full(), &layer_en, 2.0)?;
    let elastic_out = generate_lm(
        &ctx.rt, "elastic_forward_r1", &teacher, &router, &prompts, 48,
        caps, &layer_en, 1.0)?;

    out.push_str("## Fig. 10 — LM generations (teacher vs elastic)\n\n");
    out.push_str("Elastic config: input selection MHA/MLP at 0.75, \
                  experts top-half, LoRA r=1, inference threshold 0.5.\n\n");
    for (i, p) in prompts.iter().enumerate() {
        out.push_str(&format!(
            "**Prompt:** `{p}`\n\n- teacher (bypass): `{}`\n- elastic: \
             `{}`\n\n",
            teacher_out[i].trim(), elastic_out[i].trim()));
    }
    Ok(())
}

fn fig11_vit(opts: &QualOpts, out: &mut String) -> Result<()> {
    let ctx = Ctx::load("vit_tiny", opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps_vit)?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let eval = fig7::eval_image_batches(&ctx, 1, 0xF11A)?;

    out.push_str("## Fig. 11 — ViT reconstruction similarity by capacity\n\n");
    out.push_str("| capacity (input/MLP tokens) | decoder cosine |\n|--|--|\n");
    for c in [0.25f64, 0.5, 0.75, 1.0] {
        let caps = Caps([1.0, c as f32, 1.0, 1.0]);
        let cos = if c >= 1.0 {
            let r = ctx.router_init("router_init", opts.seed as i32)?;
            fig7::vit_cosine(&ctx, &teacher, &r, &eval, caps, &layer_en, 2.0)?
        } else {
            let (cos, _) = fig7::distill_and_eval_vit(
                &ctx, &teacher, opts.distill_steps, caps, &layer_en, None,
                &eval, opts.seed ^ (c * 77.0) as u64)?;
            cos
        };
        out.push_str(&format!("| {c:.2} | {cos:.4} |\n"));
    }
    out.push('\n');
    Ok(())
}

fn fig12_vlm(opts: &QualOpts, out: &mut String) -> Result<()> {
    let ctx = Ctx::load("vlm_tiny", opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps_vlm)?;
    let b = ctx.rt.manifest.batch();
    let (eval_imgs, _) = vlm_dataset(&ctx.rt, b, 0xF12A)?;
    let scenes = vlm_scenes(&ctx.rt, b, 0xF12A)?;
    let flat: Vec<f32> = eval_imgs.iter().flatten().copied().collect();

    let (train_imgs, train_caps) = vlm_dataset(&ctx.rt, 400,
                                               opts.seed ^ 0xF12B)?;
    out.push_str("## Fig. 12 — VLM captions at different image-token \
                  capacities\n\n");
    for c in [0.25f32, 0.75, 1.0] {
        let router = if c >= 1.0 {
            ctx.router_init("router_init_lin", opts.seed as i32)?
        } else {
            let r0 = ctx.router_init("router_init_lin", opts.seed as i32)?;
            let mut rng = Rng::new(opts.seed ^ 10 ^ (c * 100.0) as u64);
            let mut trainer = Trainer::new(&ctx.rt);
            let (r, _) = trainer.distill_vlm(
                "distill_step_lin", &teacher, r0, opts.distill_steps, 1e-3,
                c, 1.0, || {
                    let mut fi = Vec::new();
                    let mut ft = Vec::new();
                    for _ in 0..b {
                        let i = rng.below(train_imgs.len());
                        fi.extend_from_slice(&train_imgs[i]);
                        ft.extend_from_slice(&train_caps[i]);
                    }
                    (fi, ft)
                })?;
            r
        };
        let mode = if c >= 1.0 { 2.0 } else { 1.0 };
        let caps_out = generate_vlm(&ctx.rt, "elastic_forward_lin", &teacher,
                                    &router, &flat, c, mode, 24)?;
        out.push_str(&format!("### capacity {c:.2}\n\n"));
        for (i, cap) in caps_out.iter().take(3).enumerate() {
            out.push_str(&format!(
                "- image {} (truth: {} {} {}): `{}`\n",
                i, scenes[i].density_name(), scenes[i].color_name(),
                scenes[i].class_name(), cap.trim()));
        }
        out.push('\n');
    }
    Ok(())
}

pub fn run(opts: &QualOpts) -> Result<String> {
    let mut out = String::from(
        "# Qualitative outputs (paper Figs. 10-12)\n\n");
    fig10_lm(opts, &mut out)?;
    fig11_vit(opts, &mut out)?;
    fig12_vlm(opts, &mut out)?;
    write_file(common::results_dir().join("qualitative.md"), &out)?;
    println!("{out}");
    Ok(out)
}
