//! Figure 4 — distillation-objective ablation.
//!
//! Teacher = the pretrained LM; student = the same weights perturbed with
//! gaussian noise plus trainable LoRA (the paper uses GPT-Neo-125M + noise
//! + rank-32 LoRA; we use our teacher + noise + the config's LoRA rank).
//! Each variant of the KL objective ({forward, reverse} x {full, top-k},
//! with/without temperature scaling) trains the same student; the student's
//! held-out LM loss curve decides the winner.  The paper finds forward
//! top-k KL best — that variant is the default objective everywhere else.

use anyhow::Result;

use crate::bench::{fmt_f, Table};
use crate::checkpoint::Checkpoint;
use crate::coordinator::trainer::{Caps, Trainer};
use crate::data::{Batcher, TextDataset};

use super::common::{self, Ctx};

pub struct Fig4Opts {
    pub config: String,
    pub pretrain_steps: usize,
    pub distill_steps: usize,
    pub eval_batches: usize,
    pub noise_std: f32,
    pub seed: u64,
}

impl Default for Fig4Opts {
    fn default() -> Self {
        Fig4Opts {
            config: "lm_tiny".into(),
            pretrain_steps: 300,
            distill_steps: 100,
            eval_batches: 4,
            noise_std: 0.01,
            seed: 42,
        }
    }
}

pub fn run(opts: &Fig4Opts) -> Result<Table> {
    let ctx = Ctx::load(&opts.config, opts.seed)?;
    let teacher = ctx.teacher(opts.pretrain_steps)?;
    let rank = ctx.rt.manifest.cfg_usize("lora_rank")?;
    let l = ctx.rt.manifest.n_layers();
    let layer_en = vec![1.0f32; l];
    let caps = Caps::full();

    // noised student (Fig. 4 setup) — same noise for every loss variant
    let student = Checkpoint::new(ctx.rt.manifest.name(), "teacher", 0,
                                  teacher.clone())
        .noised(opts.noise_std, opts.seed ^ 0xF1640)
        .params;

    let eval_batches = ctx.lm_eval_batches(
        &common::gsm_eval_texts(200), opts.eval_batches, 7);
    let teacher_loss = ctx.lm_teacher_loss(&teacher, &eval_batches)?;
    let noised_loss = {
        // student before any distillation (routers at init, bypass-free):
        let r0 = ctx.router_init(&format!("router_init_r{rank}"),
                                 opts.seed as i32)?;
        ctx.lm_elastic_loss(&format!("elastic_forward_r{rank}"), &student,
                            &r0, &eval_batches, caps, &layer_en, 0.0)?
    };

    // (label, distill entry, temperature)
    let variants: Vec<(&str, String, f32)> = vec![
        ("fwd KL top-k (paper choice)",
         format!("distill_step_r{rank}"), 1.0),
        ("fwd KL top-k, T=2",
         format!("distill_step_r{rank}"), 2.0),
        ("fwd KL full", "distill_fig4_fwd_full".into(), 1.0),
        ("fwd KL full, T=2", "distill_fig4_fwd_full".into(), 2.0),
        ("rev KL top-k", "distill_fig4_rev_topk".into(), 1.0),
        ("rev KL full", "distill_fig4_rev_full".into(), 1.0),
    ];

    let mut table = Table::new(&[
        "objective", "final_distill_loss", "student_lm_loss",
        "noised_lm_loss", "teacher_lm_loss",
    ]);
    for (label, entry, temp) in &variants {
        if !ctx.rt.has_entry(entry) {
            eprintln!("[fig4] skipping {label}: entry {entry} not lowered \
                       for {}", opts.config);
            continue;
        }
        let router = ctx.router_init(&format!("router_init_r{rank}"),
                                     opts.seed as i32)?;
        let b = ctx.rt.manifest.batch();
        let t = ctx.rt.manifest.seq_len();
        let ds = TextDataset::from_texts(
            &common::gsm_train_texts(600, opts.seed ^ 0x465), t);
        let mut batcher = Batcher::new(ds.len(), b, opts.seed ^ 5);
        let mut trainer = Trainer::new(&ctx.rt);
        let (router, hist) = trainer.distill_lm(
            entry, &teacher, &student, router, opts.distill_steps, 1e-3,
            caps, &layer_en, *temp, || batcher.next_tokens(&ds))?;
        let student_loss = ctx.lm_elastic_loss(
            &format!("elastic_forward_r{rank}"), &student, &router,
            &eval_batches, caps, &layer_en, 0.0)?;
        let final_distill = hist.last().map(|m| m.distill).unwrap_or(0.0);
        println!("[fig4] {label}: distill {final_distill:.4}, student LM \
                  {student_loss:.4} (noised {noised_loss:.4}, teacher \
                  {teacher_loss:.4})");
        table.row(vec![
            label.to_string(),
            fmt_f(final_distill as f64, 4),
            fmt_f(student_loss, 4),
            fmt_f(noised_loss, 4),
            fmt_f(teacher_loss, 4),
        ]);
    }
    common::save_table(
        "fig4_distill_loss_ablation", &table,
        "Paper Fig. 4: KL-objective ablation on a noised student with LoRA. \
         Expected shape: every variant recovers most of the noise-induced \
         loss gap; forward top-k KL converges best/fastest (the paper \
         adopts it, as do we).")?;
    Ok(table)
}
