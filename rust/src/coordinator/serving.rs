//! Elastic serving engine — the systems realization of "variable inference
//! time compute" (paper §1): an admission queue, a load-adaptive capacity
//! controller, a per-tier dynamic batcher, and a PJRT execution loop over
//! the static-capacity `serve_cap*` artifacts.
//!
//! Under light load every request runs at capacity 1.0 (teacher-exact, see
//! the §4.1 equivalence); as the queue deepens the controller sheds compute
//! by routing requests to lower-capacity tiers, trading the paper's
//! measured quality-vs-capacity curve for throughput.  PJRT handles are not
//! `Send`, so the engine owns the runtime on its calling thread and request
//! producers feed it through a channel — the same single-executor topology
//! vLLM uses per GPU worker.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::{summarize, Summary};
use crate::runtime::client::Arg;
use crate::runtime::Runtime;

/// One inference request: a fixed-length token row.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
}

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tier: f32,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
}

/// Load-adaptive capacity controller with hysteresis.
///
/// Maps smoothed queue depth to one of the available capacity tiers:
/// empty queue -> highest capacity; beyond `depth_per_tier` waiting
/// requests per step, shed one tier, and so on.  Hysteresis (EWMA on the
/// depth) prevents tier oscillation at load boundaries.
#[derive(Debug, Clone)]
pub struct CapacityController {
    /// available tiers, descending capacity (e.g. [1.0, 0.75, 0.5, 0.25])
    pub tiers: Vec<f32>,
    pub depth_per_tier: f64,
    ewma: f64,
    alpha: f64,
}

impl CapacityController {
    pub fn new(mut tiers: Vec<f32>, depth_per_tier: f64) -> CapacityController {
        assert!(!tiers.is_empty());
        tiers.sort_by(|a, b| b.partial_cmp(a).unwrap());
        CapacityController { tiers, depth_per_tier, ewma: 0.0, alpha: 0.4 }
    }

    /// Observe the current queue depth and pick a tier.
    pub fn choose(&mut self, queue_depth: usize) -> f32 {
        self.ewma = self.alpha * queue_depth as f64
            + (1.0 - self.alpha) * self.ewma;
        let idx = (self.ewma / self.depth_per_tier).floor() as usize;
        self.tiers[idx.min(self.tiers.len() - 1)]
    }

    /// Pure mapping (for tests / property checks): tier for a given
    /// smoothed depth without updating state.
    pub fn tier_for_depth(&self, depth: f64) -> f32 {
        let idx = (depth / self.depth_per_tier).floor() as usize;
        self.tiers[idx.min(self.tiers.len() - 1)]
    }
}

/// Engine configuration.
pub struct ServeConfig {
    /// (capacity, entry name), e.g. (0.5, "serve_cap50")
    pub tiers: Vec<(f32, String)>,
    pub depth_per_tier: f64,
    /// max time to wait filling a batch before running partial
    pub max_batch_wait: Duration,
}

impl ServeConfig {
    pub fn standard() -> ServeConfig {
        ServeConfig {
            tiers: vec![
                (1.0, "serve_cap100".into()),
                (0.75, "serve_cap75".into()),
                (0.5, "serve_cap50".into()),
                (0.25, "serve_cap25".into()),
            ],
            depth_per_tier: 8.0,
            max_batch_wait: Duration::from_millis(20),
        }
    }
}

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub wall_secs: f64,
    pub tier_counts: Vec<(f32, usize)>,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_secs.max(1e-9)
    }

    pub fn latency_summary(&self) -> Summary {
        summarize(
            &self.completions.iter().map(|c| c.total_ms).collect::<Vec<_>>())
    }

    pub fn latency_p(&self, q: f64) -> f64 {
        let mut xs: Vec<f64> =
            self.completions.iter().map(|c| c.total_ms).collect();
        if xs.is_empty() {
            return 0.0;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[((xs.len() as f64 - 1.0) * q).round() as usize]
    }

    /// Mean capacity actually served (compute proxy: fraction of teacher
    /// FLOPs spent, cf. analysis::flops for the exact mapping).
    pub fn mean_capacity(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.tier as f64).sum::<f64>()
            / self.completions.len() as f64
    }
}

/// The serving engine.  Owns the runtime reference on the calling thread;
/// consumes requests from `rx` until it has served `expected` requests or
/// the channel closes and drains.
pub struct ElasticServer<'a> {
    rt: &'a Runtime,
    /// params/router literals prepared once — the frozen multi-MB vectors
    /// are NOT re-copied per batch (EXPERIMENTS.md §Perf, L3 iteration 1).
    params_lit: xla::Literal,
    router_lit: xla::Literal,
    cfg: ServeConfig,
    controller: CapacityController,
    batch: usize,
    seq_len: usize,
}

impl<'a> ElasticServer<'a> {
    pub fn new(rt: &'a Runtime, params: &'a [f32], router: &'a [f32],
               cfg: ServeConfig) -> Result<ElasticServer<'a>> {
        let controller = CapacityController::new(
            cfg.tiers.iter().map(|(c, _)| *c).collect(), cfg.depth_per_tier);
        // pre-compile all tier executables: admission must never pay compile
        let entries: Vec<&str> =
            cfg.tiers.iter().map(|(_, e)| e.as_str()).collect();
        rt.warmup(&entries)?;
        let entry0 = &cfg.tiers[0].1;
        let params_lit = rt.prepare_arg(entry0, 0, &Arg::F32(params))?;
        let router_lit = rt.prepare_arg(entry0, 1, &Arg::F32(router))?;
        Ok(ElasticServer {
            rt,
            params_lit,
            router_lit,
            batch: rt.manifest.batch(),
            seq_len: rt.manifest.seq_len(),
            cfg,
            controller,
        })
    }

    fn entry_for(&self, tier: f32) -> &str {
        self.cfg
            .tiers
            .iter()
            .find(|(c, _)| (*c - tier).abs() < 1e-6)
            .map(|(_, e)| e.as_str())
            .expect("tier from controller is always configured")
    }

    /// Serve until `expected` completions (or channel close + drain).
    pub fn run(&mut self, rx: Receiver<Request>, expected: usize)
               -> Result<ServeReport> {
        let start = Instant::now();
        let mut queue: VecDeque<Request> = VecDeque::new();
        let mut completions = Vec::with_capacity(expected);
        let mut open = true;
        while completions.len() < expected && (open || !queue.is_empty()) {
            // admit everything currently available (bounded wait)
            let deadline = Instant::now() + self.cfg.max_batch_wait;
            while queue.len() < self.batch && open {
                let now = Instant::now();
                if now >= deadline && !queue.is_empty() {
                    break;
                }
                let timeout = if queue.is_empty() {
                    Duration::from_millis(50)
                } else {
                    deadline - now
                };
                match rx.recv_timeout(timeout) {
                    Ok(req) => queue.push_back(req),
                    Err(RecvTimeoutError::Timeout) => {
                        if !queue.is_empty() {
                            break;
                        }
                        if completions.len() >= expected {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        open = false;
                    }
                }
            }
            if queue.is_empty() {
                continue;
            }
            // controller sees post-batch backlog
            let backlog = queue.len().saturating_sub(self.batch);
            let tier = self.controller.choose(backlog);
            let entry = self.entry_for(tier).to_string();

            let take = queue.len().min(self.batch);
            let mut reqs: Vec<Request> = Vec::with_capacity(take);
            for _ in 0..take {
                reqs.push(queue.pop_front().unwrap());
            }
            let exec_start = Instant::now();
            let mut flat = Vec::with_capacity(self.batch * self.seq_len);
            for r in &reqs {
                debug_assert_eq!(r.tokens.len(), self.seq_len);
                flat.extend_from_slice(&r.tokens);
            }
            // pad partial batches by repeating the last row
            while flat.len() < self.batch * self.seq_len {
                let row_start = flat.len() - self.seq_len;
                flat.extend_from_within(row_start..row_start + self.seq_len);
            }
            let tokens_lit = self.rt.prepare_arg(&entry, 2, &Arg::I32(&flat))?;
            let out = self.rt.exec_prepared(
                &entry, &[&self.params_lit, &self.router_lit, &tokens_lit])?;
            let _logits = out.f32(0)?; // delivered to callers in a real API
            let done = Instant::now();
            for r in reqs {
                completions.push(Completion {
                    id: r.id,
                    tier,
                    queue_ms: (exec_start - r.submitted).as_secs_f64() * 1e3,
                    total_ms: (done - r.submitted).as_secs_f64() * 1e3,
                    batch_size: take,
                });
            }
        }
        let wall_secs = start.elapsed().as_secs_f64();
        let mut tier_counts: Vec<(f32, usize)> = self
            .cfg
            .tiers
            .iter()
            .map(|(c, _)| (*c, 0usize))
            .collect();
        for c in &completions {
            if let Some(tc) =
                tier_counts.iter_mut().find(|(t, _)| (*t - c.tier).abs() < 1e-6)
            {
                tc.1 += 1;
            }
        }
        Ok(ServeReport { completions, wall_secs, tier_counts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_monotone_in_depth() {
        let c = CapacityController::new(vec![1.0, 0.75, 0.5, 0.25], 4.0);
        let mut prev = f32::INFINITY;
        for d in 0..40 {
            let t = c.tier_for_depth(d as f64);
            assert!(t <= prev);
            prev = t;
        }
        assert_eq!(c.tier_for_depth(0.0), 1.0);
        assert_eq!(c.tier_for_depth(100.0), 0.25);
    }

    #[test]
    fn controller_hysteresis_smooths_spikes() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 8.0);
        // single spike shouldn't immediately drop the tier
        assert_eq!(c.choose(0), 1.0);
        let t = c.choose(20); // ewma = 0.4*20 = 8 -> boundary
        let t2 = c.choose(0); // decays back
        assert!(t >= 0.5);
        assert!(t2 >= t - 1e-6 || t2 == 1.0);
    }

    #[test]
    fn controller_sorts_tiers() {
        let c = CapacityController::new(vec![0.25, 1.0, 0.5], 1.0);
        assert_eq!(c.tiers, vec![1.0, 0.5, 0.25]);
    }

    #[test]
    fn report_percentiles() {
        let report = ServeReport {
            completions: (0..100)
                .map(|i| Completion {
                    id: i,
                    tier: 1.0,
                    queue_ms: 0.0,
                    total_ms: i as f64,
                    batch_size: 1,
                })
                .collect(),
            wall_secs: 1.0,
            tier_counts: vec![(1.0, 100)],
        };
        assert_eq!(report.latency_p(0.5), 50.0);
        assert_eq!(report.latency_p(0.99), 98.0);
        assert_eq!(report.throughput_rps(), 100.0);
        assert_eq!(report.mean_capacity(), 1.0);
    }
}
