//! Greedy autoregressive generation through the fixed-shape elastic
//! artifacts (used by the qualitative Fig. 10/12 drivers and the serving
//! example).
//!
//! The AOT forward has a static [B, T] shape, so decoding works on a padded
//! window: place the prompt, run the full forward, read the logits at the
//! last filled position, append the argmax, repeat.  O(T^2) per sequence —
//! fine at the repro's T <= 128 and identical numerics to a KV-cache
//! implementation.  Inference-mode routing (mode = 1: 0.5-threshold) is
//! used, matching Appendix B.1.

use anyhow::{bail, Result};

use crate::data::tokenizer::{Tokenizer, BOS, EOS, PAD};
use crate::eval;
use crate::runtime::client::Arg;
use crate::runtime::Runtime;

use super::trainer::Caps;

/// Greedy-decode continuations for a batch of prompts through an LM
/// `elastic_forward_r*` entry.  Returns decoded strings (prompt stripped).
#[allow(clippy::too_many_arguments)]
pub fn generate_lm(rt: &Runtime, entry: &str, params: &[f32], router: &[f32],
                   prompts: &[String], max_new: usize, caps: Caps,
                   layer_en: &[f32], mode: f32) -> Result<Vec<String>> {
    let b = rt.manifest.batch();
    let t = rt.manifest.seq_len();
    let v = rt.manifest.vocab();
    if prompts.len() > b {
        bail!("{} prompts > batch {b}", prompts.len());
    }
    let tok = Tokenizer::new();
    // token rows + current lengths
    let mut rows: Vec<Vec<i32>> = Vec::with_capacity(b);
    let mut lens: Vec<usize> = Vec::with_capacity(b);
    for i in 0..b {
        let text = prompts.get(i).map(|s| s.as_str()).unwrap_or("");
        let mut ids = vec![BOS];
        ids.extend(tok.encode(text));
        ids.truncate(t - 1);
        lens.push(ids.len());
        ids.resize(t, PAD);
        rows.push(ids);
    }
    let mut done = vec![false; b];
    for _ in 0..max_new {
        if done.iter().all(|&d| d) || lens.iter().all(|&l| l >= t) {
            break;
        }
        let flat: Vec<i32> = rows.iter().flatten().copied().collect();
        let out = rt.exec(entry, &[
            Arg::F32(params),
            Arg::F32(router),
            Arg::I32(&flat),
            Arg::F32(&caps.0),
            Arg::F32(layer_en),
            Arg::ScalarF32(mode),
        ])?;
        let logits = out.f32(0)?;
        for i in 0..prompts.len() {
            if done[i] || lens[i] >= t {
                continue;
            }
            let next = eval::greedy_token(&logits, i, lens[i] - 1, t, v);
            rows[i][lens[i]] = next;
            lens[i] += 1;
            if next == EOS {
                done[i] = true;
            }
        }
    }
    let mut outs = Vec::with_capacity(prompts.len());
    for (i, p) in prompts.iter().enumerate() {
        let full = tok.decode_until_eos(&rows[i][..lens[i]]);
        outs.push(full[p.len().min(full.len())..].to_string());
    }
    Ok(outs)
}

/// Greedy caption generation through a VLM `elastic_forward_*` entry.
/// `images` is the flat [B, H*W*C] batch; returns one caption per image.
pub fn generate_vlm(rt: &Runtime, entry: &str, params: &[f32],
                    router: &[f32], images: &[f32], capacity: f32,
                    mode: f32, max_new: usize) -> Result<Vec<String>> {
    let b = rt.manifest.batch();
    let tl = rt.manifest.cfg_usize("text_len")?;
    let v = rt.manifest.vocab();
    let tok = Tokenizer::new();
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|_| {
            let mut r = vec![PAD; tl];
            r[0] = BOS;
            r
        })
        .collect();
    let mut lens = vec![1usize; b];
    let mut done = vec![false; b];
    for _ in 0..max_new.min(tl - 1) {
        if done.iter().all(|&d| d) {
            break;
        }
        let flat: Vec<i32> = rows.iter().flatten().copied().collect();
        let out = rt.exec(entry, &[
            Arg::F32(params),
            Arg::F32(router),
            Arg::F32(images),
            Arg::I32(&flat),
            Arg::ScalarF32(capacity),
            Arg::ScalarF32(mode),
        ])?;
        let logits = out.f32(0)?; // [B, text_len, V]
        for i in 0..b {
            if done[i] || lens[i] >= tl {
                continue;
            }
            let next = eval::greedy_token(&logits, i, lens[i] - 1, tl, v);
            rows[i][lens[i]] = next;
            lens[i] += 1;
            if next == EOS {
                done[i] = true;
            }
        }
    }
    Ok(rows
        .iter()
        .zip(&lens)
        .map(|(r, &l)| tok.decode_until_eos(&r[..l]))
        .collect())
}

#[cfg(test)]
mod tests {
    // Generation requires compiled artifacts; covered by the integration
    // tests in rust/tests/ (test_generation_*) and the qualitative driver.
    // Here we only test the prompt-window bookkeeping helpers indirectly
    // through the tokenizer contract.
    use crate::data::tokenizer::{Tokenizer, BOS};

    #[test]
    fn prompt_window_layout() {
        let tok = Tokenizer::new();
        let mut ids = vec![BOS];
        ids.extend(tok.encode("Q: 2+2 A:"));
        assert_eq!(ids[0], BOS);
        assert!(ids.len() < 64);
    }
}
