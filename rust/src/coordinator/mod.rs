//! The paper's system contribution at L3: post-training self-distillation
//! orchestration (producing router checkpoints) plus an elastic serving
//! engine that realizes "variable inference time compute" as an operable
//! system (admission queue -> capacity controller -> per-tier batcher ->
//! PJRT worker).

pub mod generation;
pub mod schedule;
pub mod serving;
pub mod trainer;

pub use schedule::LrSchedule;
pub use trainer::Trainer;
