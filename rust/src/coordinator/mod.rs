//! The paper's system contribution at L3: post-training self-distillation
//! orchestration (producing router checkpoints) plus an elastic serving
//! subsystem that realizes "variable inference time compute" as an
//! operable system (sharded bounded admission queue -> heterogeneous
//! worker classes, one capacity controller per class -> `Executor`
//! backends: PJRT or the deterministic simulator; see
//! serving/README.md).

#[cfg(feature = "pjrt")]
pub mod generation;
pub mod schedule;
pub mod serving;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use schedule::LrSchedule;
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
