//! Learning-rate schedule: cosine decay with linear warmup (paper §5:
//! "AdamW ... cosine learning rate scheduler with 3% warmup").
//!
//! The schedule runs on the Rust side; the AOT train steps take `lr` as a
//! runtime scalar.

#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub base_lr: f64,
    pub total_steps: usize,
    pub warmup_steps: usize,
    pub min_lr_frac: f64,
}

impl LrSchedule {
    /// Paper defaults: 3% warmup, decay to 10% of base.
    pub fn cosine(base_lr: f64, total_steps: usize) -> LrSchedule {
        LrSchedule {
            base_lr,
            total_steps: total_steps.max(1),
            warmup_steps: ((total_steps as f64) * 0.03).ceil() as usize,
            min_lr_frac: 0.1,
        }
    }

    pub fn at(&self, step: usize) -> f64 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step as f64 + 1.0)
                / self.warmup_steps as f64;
        }
        let denom = (self.total_steps.saturating_sub(self.warmup_steps))
            .max(1) as f64;
        let progress =
            ((step - self.warmup_steps) as f64 / denom).clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        let floor = self.base_lr * self.min_lr_frac;
        floor + (self.base_lr - floor) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_to_base() {
        let s = LrSchedule::cosine(1e-3, 1000); // warmup = 30 steps
        assert!(s.at(0) < 1e-4);
        assert!(s.at(29) <= 1e-3 + 1e-12);
        assert!((s.at(30) - 1e-3).abs() / 1e-3 < 0.01);
    }

    #[test]
    fn decays_to_floor() {
        let s = LrSchedule::cosine(1e-3, 1000);
        let end = s.at(999);
        assert!((end - 1e-4).abs() < 2e-5, "end lr {end}");
        assert!(s.at(2000) >= 1e-4 - 1e-12); // clamped past the end
    }

    #[test]
    fn monotone_decreasing_after_warmup() {
        let s = LrSchedule::cosine(3e-4, 200);
        let mut prev = f64::INFINITY;
        for step in s.warmup_steps..200 {
            let lr = s.at(step);
            assert!(lr <= prev + 1e-15);
            prev = lr;
        }
    }

    #[test]
    fn tiny_run_no_division_by_zero() {
        let s = LrSchedule::cosine(1e-3, 1);
        assert!(s.at(0).is_finite());
        let s2 = LrSchedule { base_lr: 1e-3, total_steps: 5,
                              warmup_steps: 0, min_lr_frac: 0.0 };
        assert!((s2.at(0) - 1e-3).abs() < 1e-12);
    }
}
