//! Training orchestration: teacher pretraining and ElastiFormer
//! self-distillation loops over the AOT step artifacts.
//!
//! All state (params / Adam moments) lives in host `Vec<f32>` between steps
//! and round-trips through PJRT literals; the schedule, data pipeline,
//! logging and checkpointing are owned here.  The same `Trainer` drives
//! LM, ViT and VLM configs — entry names and batch payloads differ, shapes
//! come from the manifest.

use anyhow::{bail, Result};

use crate::checkpoint::Checkpoint;
use crate::metrics::JsonlLogger;
use crate::runtime::client::Arg;
use crate::runtime::Runtime;

use super::schedule::LrSchedule;

/// Runtime capacity vector for the elastic artifacts:
/// [mha_tokens, mlp_tokens, heads_frac, experts_frac].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Caps(pub [f32; 4]);

impl Caps {
    pub fn full() -> Caps {
        Caps([1.0, 1.0, 1.0, 1.0])
    }

    pub fn uniform(c: f32) -> Caps {
        Caps([c, c, c, c])
    }
}

/// Per-layer routing enable vector (all / even / none).
pub fn layer_enable(n_layers: usize, mode: &str) -> Result<Vec<f32>> {
    Ok(match mode {
        "all" => vec![1.0; n_layers],
        "even" => (0..n_layers)
            .map(|i| if i % 2 == 0 { 1.0 } else { 0.0 })
            .collect(),
        "none" => vec![0.0; n_layers],
        _ => bail!("unknown layer mode {mode:?} (all|even|none)"),
    })
}

/// Metrics of one distill step, in artifact order.
#[derive(Debug, Clone, Copy, Default)]
pub struct DistillMetrics {
    pub distill: f32,
    pub aux1: f32,
    pub aux2: f32,
    pub total: f32,
    pub student_score: f32,
    pub teacher_score: f32,
    pub gnorm: f32,
    pub frac_tokens: f32,
}

impl DistillMetrics {
    pub fn from_vec(v: &[f32]) -> DistillMetrics {
        let g = |i: usize| v.get(i).copied().unwrap_or(0.0);
        DistillMetrics {
            distill: g(0),
            aux1: g(1),
            aux2: g(2),
            total: g(3),
            student_score: g(4),
            teacher_score: g(5),
            gnorm: g(6),
            frac_tokens: g(7),
        }
    }
}

pub struct Trainer<'a> {
    pub rt: &'a Runtime,
    pub logger: Option<JsonlLogger>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime) -> Trainer<'a> {
        Trainer { rt, logger: None }
    }

    pub fn with_logger(rt: &'a Runtime, path: &str) -> Result<Trainer<'a>> {
        Ok(Trainer { rt, logger: Some(JsonlLogger::create(path)?) })
    }

    /// Initialize a flat parameter vector via the AOT `init`-family entry.
    pub fn init_params(&self, entry: &str, seed: i32) -> Result<Vec<f32>> {
        let out = self.rt.exec(entry, &[Arg::ScalarI32(seed)])?;
        out.f32(0)
    }

    /// Generic pretraining loop.  `next_batch` must yield the non-state
    /// args of the step entry in manifest order (tokens, or images [+texts]).
    ///
    /// Returns (params, per-step losses).
    pub fn pretrain<F>(&mut self, entry: &str, mut params: Vec<f32>,
                       steps: usize, base_lr: f64, mut next_batch: F)
                       -> Result<(Vec<f32>, Vec<f32>)>
    where
        F: FnMut() -> Vec<BatchArg>,
    {
        let n = params.len();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let sched = LrSchedule::cosine(base_lr, steps);
        let mut losses = Vec::with_capacity(steps);
        for step in 0..steps {
            let lr = sched.at(step) as f32;
            let batch = next_batch();
            let mut args: Vec<Arg> = vec![
                Arg::F32(&params),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::ScalarI32(step as i32),
                Arg::ScalarF32(lr),
            ];
            for b in &batch {
                args.push(b.as_arg());
            }
            let out = self.rt.exec(entry, &args)?;
            params = out.f32(0)?;
            m = out.f32(1)?;
            v = out.f32(2)?;
            let metrics = out.f32(3)?;
            let loss = metrics[0];
            if !loss.is_finite() {
                bail!("{entry}: non-finite loss at step {step}");
            }
            losses.push(loss);
            if let Some(log) = &mut self.logger {
                log.log(vec![
                    ("phase".into(), "pretrain".into()),
                    ("step".into(), step.into()),
                    ("loss".into(), (loss as f64).into()),
                    ("gnorm".into(),
                     (*metrics.get(1).unwrap_or(&0.0) as f64).into()),
                    ("lr".into(), (lr as f64).into()),
                ])?;
            }
        }
        Ok((params, losses))
    }

    /// ElastiFormer distillation loop for LM entries
    /// (`distill_step_r*` / `distill_fig4_*`):
    /// args = teacher, student, router, m, v, step, lr, tokens, caps,
    /// layer_en, temp.
    #[allow(clippy::too_many_arguments)]
    pub fn distill_lm<F>(&mut self, entry: &str, teacher: &[f32],
                         student: &[f32], mut router: Vec<f32>, steps: usize,
                         base_lr: f64, caps: Caps, layer_en: &[f32],
                         temp: f32, mut next_tokens: F)
                         -> Result<(Vec<f32>, Vec<DistillMetrics>)>
    where
        F: FnMut() -> Vec<i32>,
    {
        let n = router.len();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let sched = LrSchedule::cosine(base_lr, steps);
        let mut history = Vec::with_capacity(steps);
        for step in 0..steps {
            let lr = sched.at(step) as f32;
            let tokens = next_tokens();
            let out = self.rt.exec(entry, &[
                Arg::F32(teacher),
                Arg::F32(student),
                Arg::F32(&router),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::ScalarI32(step as i32),
                Arg::ScalarF32(lr),
                Arg::I32(&tokens),
                Arg::F32(&caps.0),
                Arg::F32(layer_en),
                Arg::ScalarF32(temp),
            ])?;
            router = out.f32(0)?;
            m = out.f32(1)?;
            v = out.f32(2)?;
            let met = DistillMetrics::from_vec(&out.f32(3)?);
            if !met.total.is_finite() {
                bail!("{entry}: non-finite loss at step {step}");
            }
            self.log_distill(entry, step, lr, &met)?;
            history.push(met);
        }
        Ok((router, history))
    }

    /// ViT distillation loop: args = params, router, m, v, step, lr,
    /// images, caps, layer_en.
    #[allow(clippy::too_many_arguments)]
    pub fn distill_vit<F>(&mut self, entry: &str, teacher: &[f32],
                          mut router: Vec<f32>, steps: usize, base_lr: f64,
                          caps: Caps, layer_en: &[f32], mut next_images: F)
                          -> Result<(Vec<f32>, Vec<DistillMetrics>)>
    where
        F: FnMut() -> Vec<f32>,
    {
        let n = router.len();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let sched = LrSchedule::cosine(base_lr, steps);
        let mut history = Vec::with_capacity(steps);
        for step in 0..steps {
            let lr = sched.at(step) as f32;
            let images = next_images();
            let out = self.rt.exec(entry, &[
                Arg::F32(teacher),
                Arg::F32(&router),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::ScalarI32(step as i32),
                Arg::ScalarF32(lr),
                Arg::F32(&images),
                Arg::F32(&caps.0),
                Arg::F32(layer_en),
            ])?;
            router = out.f32(0)?;
            m = out.f32(1)?;
            v = out.f32(2)?;
            let met = DistillMetrics::from_vec(&out.f32(3)?);
            if !met.total.is_finite() {
                bail!("{entry}: non-finite loss at step {step}");
            }
            self.log_distill(entry, step, lr, &met)?;
            history.push(met);
        }
        Ok((router, history))
    }

    /// VLM distillation loop: args = params, router, m, v, step, lr,
    /// images, texts, capacity, temp.
    #[allow(clippy::too_many_arguments)]
    pub fn distill_vlm<F>(&mut self, entry: &str, teacher: &[f32],
                          mut router: Vec<f32>, steps: usize, base_lr: f64,
                          capacity: f32, temp: f32, mut next_batch: F)
                          -> Result<(Vec<f32>, Vec<DistillMetrics>)>
    where
        F: FnMut() -> (Vec<f32>, Vec<i32>),
    {
        let n = router.len();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let sched = LrSchedule::cosine(base_lr, steps);
        let mut history = Vec::with_capacity(steps);
        for step in 0..steps {
            let lr = sched.at(step) as f32;
            let (images, texts) = next_batch();
            let out = self.rt.exec(entry, &[
                Arg::F32(teacher),
                Arg::F32(&router),
                Arg::F32(&m),
                Arg::F32(&v),
                Arg::ScalarI32(step as i32),
                Arg::ScalarF32(lr),
                Arg::F32(&images),
                Arg::I32(&texts),
                Arg::ScalarF32(capacity),
                Arg::ScalarF32(temp),
            ])?;
            router = out.f32(0)?;
            m = out.f32(1)?;
            v = out.f32(2)?;
            let met = DistillMetrics::from_vec(&out.f32(3)?);
            if !met.total.is_finite() {
                bail!("{entry}: non-finite loss at step {step}");
            }
            self.log_distill(entry, step, lr, &met)?;
            history.push(met);
        }
        Ok((router, history))
    }

    fn log_distill(&mut self, entry: &str, step: usize, lr: f32,
                   met: &DistillMetrics) -> Result<()> {
        if let Some(log) = &mut self.logger {
            log.log(vec![
                ("phase".into(), "distill".into()),
                ("entry".into(), entry.into()),
                ("step".into(), step.into()),
                ("distill".into(), (met.distill as f64).into()),
                ("total".into(), (met.total as f64).into()),
                ("student".into(), (met.student_score as f64).into()),
                ("teacher".into(), (met.teacher_score as f64).into()),
                ("frac_tokens".into(), (met.frac_tokens as f64).into()),
                ("lr".into(), (lr as f64).into()),
            ])?;
        }
        Ok(())
    }

    /// Save params as a checkpoint.
    pub fn save(&self, params: &[f32], kind: &str, step: u64, path: &str)
                -> Result<()> {
        Checkpoint::new(self.rt.manifest.name(), kind, step, params.to_vec())
            .save(path)
    }
}

/// One non-state batch argument for a pretrain entry.
pub enum BatchArg {
    Tokens(Vec<i32>),
    Floats(Vec<f32>),
}

impl BatchArg {
    fn as_arg(&self) -> Arg<'_> {
        match self {
            BatchArg::Tokens(t) => Arg::I32(t),
            BatchArg::Floats(f) => Arg::F32(f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caps_constructors() {
        assert_eq!(Caps::full().0, [1.0; 4]);
        assert_eq!(Caps::uniform(0.5).0, [0.5; 4]);
    }

    #[test]
    fn layer_enable_modes() {
        assert_eq!(layer_enable(4, "all").unwrap(), vec![1.0; 4]);
        assert_eq!(layer_enable(4, "even").unwrap(),
                   vec![1.0, 0.0, 1.0, 0.0]);
        assert_eq!(layer_enable(3, "none").unwrap(), vec![0.0; 3]);
        assert!(layer_enable(3, "odd").is_err());
    }

    #[test]
    fn metrics_from_short_vec() {
        let m = DistillMetrics::from_vec(&[1.0, 2.0]);
        assert_eq!(m.distill, 1.0);
        assert_eq!(m.aux1, 2.0);
        assert_eq!(m.gnorm, 0.0);
    }
}
