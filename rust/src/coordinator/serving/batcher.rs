//! Batch formation: turn a FIFO run of admitted requests into the exact
//! `[batch, seq_len]` i32 tensor the static-capacity artifacts expect.
//!
//! Pure host code, extracted from the old engine loop so its invariants
//! (no request dropped or duplicated, output always exactly
//! `batch * seq_len` tokens, request order preserved) are checkable by
//! the in-tree property harness without any runtime.

use super::Request;

/// One formed execution batch: the requests it carries (admission order)
/// and the flattened, padded token tensor.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub tokens: Vec<i32>,
    /// rows added beyond `requests.len()` to fill the static batch shape
    pub padded_rows: usize,
}

/// Flatten `requests` into a `batch * seq_len` token buffer.
///
/// Each request row is clamped to `seq_len` (short rows zero-pad, long
/// rows truncate — producers normally pre-pad via
/// `Tokenizer::encode_padded`, this just makes the invariant total).
/// Partial batches are filled by repeating the last real row, so the
/// executable sees realistic token statistics instead of zeros.
///
/// Panics if `requests` is empty or longer than `batch`: the worker loop
/// guarantees `1..=batch` requests per call.
pub fn form_batch(requests: Vec<Request>, batch: usize, seq_len: usize)
                  -> Batch {
    assert!(!requests.is_empty(), "form_batch on empty request set");
    assert!(requests.len() <= batch,
            "form_batch overfull: {} > {batch}", requests.len());
    let mut tokens = Vec::with_capacity(batch * seq_len);
    for r in &requests {
        let n = r.tokens.len().min(seq_len);
        tokens.extend_from_slice(&r.tokens[..n]);
        tokens.resize(tokens.len() + (seq_len - n), 0);
    }
    let padded_rows = batch - requests.len();
    for _ in 0..padded_rows {
        let row_start = tokens.len() - seq_len;
        tokens.extend_from_within(row_start..row_start + seq_len);
    }
    debug_assert_eq!(tokens.len(), batch * seq_len);
    Batch { requests, tokens, padded_rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tokens: Vec<i32>) -> Request {
        Request::new(id, tokens)
    }

    #[test]
    fn full_batch_is_verbatim_concatenation() {
        let b = form_batch(
            vec![req(0, vec![1, 2, 3]), req(1, vec![4, 5, 6])], 2, 3);
        assert_eq!(b.tokens, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(b.padded_rows, 0);
        assert_eq!(b.requests.len(), 2);
    }

    #[test]
    fn partial_batch_repeats_last_row() {
        let b = form_batch(vec![req(0, vec![7, 8])], 3, 2);
        assert_eq!(b.tokens, vec![7, 8, 7, 8, 7, 8]);
        assert_eq!(b.padded_rows, 2);
    }

    #[test]
    fn ragged_rows_clamp_to_seq_len() {
        let b = form_batch(
            vec![req(0, vec![1]), req(1, vec![2, 3, 4, 5])], 2, 3);
        assert_eq!(b.tokens, vec![1, 0, 0, 2, 3, 4]);
    }

    #[test]
    fn zero_seq_len_yields_empty_tensor() {
        let b = form_batch(vec![req(0, vec![])], 4, 0);
        assert!(b.tokens.is_empty());
        assert_eq!(b.padded_rows, 3);
    }

    #[test]
    #[should_panic(expected = "empty request set")]
    fn empty_input_panics() {
        form_batch(Vec::new(), 2, 2);
    }
}
