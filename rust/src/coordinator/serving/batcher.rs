//! Batch formation: turn a FIFO run of admitted requests into the exact
//! `[batch, seq_len]` i32 tensor the static-capacity artifacts expect,
//! plus the **class-compatibility key** that decides which requests may
//! share a batch at all.
//!
//! One executed batch runs at one capacity tier, so the strictest SLO
//! constraint in a batch binds every member: before class-aware
//! formation, a single floored request dragged its best-effort
//! neighbours up a tier, and a single tight deadline dragged relaxed
//! neighbours down one.  [`batch_key`] buckets each request by the
//! *ladder rung its floor clamps to* and a coarse *deadline band*;
//! the sharded admission queue's keyed pop only groups key-equal
//! requests, so neither cross-subsidy can happen (property-tested:
//! no batch mixes incompatible floors).
//!
//! Pure host code, extracted from the old engine loop so its invariants
//! (no request dropped or duplicated, output always exactly
//! `batch * seq_len` tokens, request order preserved) are checkable by
//! the in-tree property harness without any runtime.

use std::time::Duration;

use super::{Request, SloClass, TIER_EPS};

/// Which kind of work a queued item represents — the third batch-key
/// dimension, introduced with the streaming decode subsystem.  One
/// executed batch is one workload: a **prefill** batch processes whole
/// prompts (one-shot requests, and a decode session's first step), a
/// **decode** batch advances in-flight sessions by one token each.
/// The kinds never mix: their per-row cost profiles differ, and a
/// decode step's output is consumed by the session table, not a
/// caller's `Response`.
///
/// The speculative decode subsystem (`stream/spec.rs`) adds two more
/// kinds with the same never-mix rule: a **draft** batch runs `k`
/// cheap low-tier micro-steps per session, a **verify** batch checks
/// whole draft runs (`k+1` rows per session) in one top-tier pass.
/// Draft and verify batches still group *across* sessions — the key
/// splits by workload, not by session.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    /// full-prompt computation: one-shot requests and session step 0
    Prefill,
    /// one autoregressive step of a live decode session (step >= 1)
    Decode,
    /// speculative draft: k cheap low-tier steps for one session
    Draft,
    /// speculative verify: one top-tier pass over a session's draft run
    Verify,
}

/// Compatibility key for class-aware batch formation: two items may
/// share an execution batch iff their keys are equal.  Keys are stable
/// for the lifetime of a queued item (derived from its configured SLO
/// and its step kind, not from elapsed time), so an item's class never
/// changes while it sits in the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchKey {
    /// prefill vs decode — the streaming subsystem's workload split
    pub step_kind: StepKind,
    /// index of the ladder rung the quality floor clamps to
    /// (`tiers.len() - 1` = unconstrained best-effort)
    pub floor_rung: usize,
    /// log2 bucket of the deadline budget (`u32::MAX` = no deadline)
    pub deadline_band: u32,
}

/// Compute the compatibility key for one *prefill* item's SLO against
/// the configured capacity ladder (descending) — the one-shot request
/// path.  Decode steps use [`batch_key_for`].
pub fn batch_key(slo: &SloClass, tiers: &[f32]) -> BatchKey {
    batch_key_for(StepKind::Prefill, slo, tiers)
}

/// Compute the compatibility key for one item of the given step kind.
pub fn batch_key_for(kind: StepKind, slo: &SloClass, tiers: &[f32])
                     -> BatchKey {
    BatchKey {
        step_kind: kind,
        floor_rung: floor_rung(tiers, slo.floor_tier),
        deadline_band: deadline_band(slo.deadline),
    }
}

/// Ladder index a quality floor clamps to: the *smallest* configured
/// tier at or above `floor` (a floor above the whole ladder clamps to
/// the top tier; a floor at or below the bottom tier — including the
/// 0.0 best-effort floor — does not constrain and maps to the bottom
/// rung).  This is the single rung rule shared by the capacity
/// controller's clamp and the batch-compatibility key, so "same rung"
/// always means "same clamp outcome".
pub fn floor_rung(tiers: &[f32], floor: f32) -> usize {
    if floor <= 0.0 {
        return tiers.len().saturating_sub(1);
    }
    tiers.iter().rposition(|&t| t + TIER_EPS >= floor).unwrap_or(0)
}

/// Coarse deadline bucket: requests in the same power-of-two latency
/// band batch together (their slack-demotion pressure is comparable);
/// `None` deadlines get their own band.  Derived from the *configured*
/// budget, not the remaining slack, so the band is queue-stable.
pub fn deadline_band(deadline: Option<Duration>) -> u32 {
    match deadline {
        None => u32::MAX,
        Some(d) => {
            let ms = (d.as_millis() as u64).max(1);
            64 - ms.leading_zeros()
        }
    }
}

/// One formed execution batch: the requests it carries (admission order)
/// and the flattened, padded token tensor.
#[derive(Debug, Clone)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub tokens: Vec<i32>,
    /// rows added beyond `requests.len()` to fill the static batch shape
    pub padded_rows: usize,
}

/// Flatten `requests` into a `batch * seq_len` token buffer.
///
/// Each request row is clamped to `seq_len` (short rows zero-pad, long
/// rows truncate — producers normally pre-pad via
/// `Tokenizer::encode_padded`, this just makes the invariant total).
/// Partial batches are filled by repeating the last real row, so the
/// executable sees realistic token statistics instead of zeros.
///
/// Panics if `requests` is empty or longer than `batch`: the worker loop
/// guarantees `1..=batch` requests per call.
pub fn form_batch(requests: Vec<Request>, batch: usize, seq_len: usize)
                  -> Batch {
    assert!(!requests.is_empty(), "form_batch on empty request set");
    let rows: Vec<&[i32]> =
        requests.iter().map(|r| r.tokens.as_slice()).collect();
    let tokens = form_rows(&rows, batch, seq_len);
    let padded_rows = batch - requests.len();
    Batch { requests, tokens, padded_rows }
}

/// Row-level batch formation: flatten `rows` into a `batch * seq_len`
/// token buffer under the same clamp/pad/repeat rules as
/// [`form_batch`].  This is what the worker loop uses directly — a
/// decode step's compute row comes from the session table, not from a
/// `Request` — and what `form_batch` delegates to.
///
/// Panics if `rows` is empty or longer than `batch`.
pub fn form_rows(rows: &[&[i32]], batch: usize, seq_len: usize)
                 -> Vec<i32> {
    assert!(!rows.is_empty(), "form_rows on empty row set");
    assert!(rows.len() <= batch,
            "form_rows overfull: {} > {batch}", rows.len());
    let mut tokens = Vec::with_capacity(batch * seq_len);
    for row in rows {
        let n = row.len().min(seq_len);
        tokens.extend_from_slice(&row[..n]);
        tokens.resize(tokens.len() + (seq_len - n), 0);
    }
    for _ in 0..batch - rows.len() {
        let row_start = tokens.len() - seq_len;
        tokens.extend_from_within(row_start..row_start + seq_len);
    }
    debug_assert_eq!(tokens.len(), batch * seq_len);
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tokens: Vec<i32>) -> Request {
        Request::new(id, tokens)
    }

    #[test]
    fn full_batch_is_verbatim_concatenation() {
        let b = form_batch(
            vec![req(0, vec![1, 2, 3]), req(1, vec![4, 5, 6])], 2, 3);
        assert_eq!(b.tokens, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(b.padded_rows, 0);
        assert_eq!(b.requests.len(), 2);
    }

    #[test]
    fn partial_batch_repeats_last_row() {
        let b = form_batch(vec![req(0, vec![7, 8])], 3, 2);
        assert_eq!(b.tokens, vec![7, 8, 7, 8, 7, 8]);
        assert_eq!(b.padded_rows, 2);
    }

    #[test]
    fn ragged_rows_clamp_to_seq_len() {
        let b = form_batch(
            vec![req(0, vec![1]), req(1, vec![2, 3, 4, 5])], 2, 3);
        assert_eq!(b.tokens, vec![1, 0, 0, 2, 3, 4]);
    }

    #[test]
    fn zero_seq_len_yields_empty_tensor() {
        let b = form_batch(vec![req(0, vec![])], 4, 0);
        assert!(b.tokens.is_empty());
        assert_eq!(b.padded_rows, 3);
    }

    #[test]
    #[should_panic(expected = "empty request set")]
    fn empty_input_panics() {
        form_batch(Vec::new(), 2, 2);
    }

    const LADDER: [f32; 4] = [1.0, 0.75, 0.5, 0.25];

    #[test]
    fn floor_rung_matches_controller_clamp_semantics() {
        // best-effort and below-ladder floors are unconstrained
        assert_eq!(floor_rung(&LADDER, 0.0), 3);
        assert_eq!(floor_rung(&LADDER, 0.1), 3);
        assert_eq!(floor_rung(&LADDER, 0.25), 3);
        // between rungs rounds up to the next configured tier
        assert_eq!(floor_rung(&LADDER, 0.3), 2);
        assert_eq!(floor_rung(&LADDER, 0.6), 1);
        assert_eq!(floor_rung(&LADDER, 0.75), 1);
        assert_eq!(floor_rung(&LADDER, 1.0), 0);
        // a floor above the whole ladder clamps to the top tier
        assert_eq!(floor_rung(&LADDER, 1.5), 0);
    }

    #[test]
    fn deadline_bands_bucket_by_power_of_two() {
        assert_eq!(deadline_band(None), u32::MAX);
        // sub-millisecond budgets land in the bottom band
        assert_eq!(deadline_band(Some(Duration::from_micros(300))),
                   deadline_band(Some(Duration::from_millis(1))));
        // 2ms and 3ms share a band; 3ms and 5ms do not
        assert_eq!(deadline_band(Some(Duration::from_millis(2))),
                   deadline_band(Some(Duration::from_millis(3))));
        assert_ne!(deadline_band(Some(Duration::from_millis(3))),
                   deadline_band(Some(Duration::from_millis(5))));
        assert_ne!(deadline_band(Some(Duration::from_millis(5))), u32::MAX);
    }

    #[test]
    fn step_kinds_never_share_a_batch_key() {
        // the streaming subsystem's workload split: a decode step and a
        // prefill with the *identical* SLO must still never batch
        // together, while two decode steps from different sessions with
        // compatible SLOs do
        let caps = LADDER.to_vec();
        let slo = SloClass::named("s").with_floor_tier(0.5);
        let prefill = batch_key_for(StepKind::Prefill, &slo, &caps);
        let decode = batch_key_for(StepKind::Decode, &slo, &caps);
        let draft = batch_key_for(StepKind::Draft, &slo, &caps);
        let verify = batch_key_for(StepKind::Verify, &slo, &caps);
        let kinds = [prefill, decode, draft, verify];
        for (i, a) in kinds.iter().enumerate() {
            for b in kinds.iter().skip(i + 1) {
                assert_ne!(a, b, "step kinds must never share a batch");
            }
        }
        assert_eq!(prefill, batch_key(&slo, &caps),
                   "one-shot requests are prefill-kind");
        let decode2 =
            batch_key_for(StepKind::Decode, &SloClass::named("t")
                .with_floor_tier(0.5), &caps);
        assert_eq!(decode, decode2,
                   "compatible decode steps batch across sessions");
        // draft and verify items batch across sessions the same way
        let draft2 = batch_key_for(StepKind::Draft, &SloClass::named("t")
            .with_floor_tier(0.5), &caps);
        assert_eq!(draft, draft2,
                   "compatible draft steps batch across sessions");
    }

    #[test]
    fn form_rows_matches_form_batch_layout() {
        let reqs = vec![req(0, vec![1, 2, 3]), req(1, vec![4])];
        let via_batch = form_batch(reqs, 3, 3).tokens;
        let via_rows = form_rows(&[&[1, 2, 3], &[4]], 3, 3);
        assert_eq!(via_batch, via_rows);
        assert_eq!(via_rows, vec![1, 2, 3, 4, 0, 0, 4, 0, 0]);
    }

    #[test]
    fn batch_keys_separate_floors_but_merge_compatible_slos() {
        let caps = LADDER.to_vec();
        let best = batch_key(&SloClass::best_effort(), &caps);
        let low_floor =
            batch_key(&SloClass::named("lo").with_floor_tier(0.25), &caps);
        let premium =
            batch_key(&SloClass::named("hi").with_floor_tier(1.0), &caps);
        // a floor at the bottom rung is the same contract as best-effort
        assert_eq!(best, low_floor);
        assert_ne!(best, premium);
        // class *names* never split batches — only the contract does
        let renamed = batch_key(&SloClass::named("other"), &caps);
        assert_eq!(best, renamed);
        // deadlines split batches by band, not by exact value
        let d20 = batch_key(
            &SloClass::named("a").with_deadline(Duration::from_millis(20)),
            &caps);
        let d25 = batch_key(
            &SloClass::named("b").with_deadline(Duration::from_millis(25)),
            &caps);
        let d200 = batch_key(
            &SloClass::named("c").with_deadline(Duration::from_millis(200)),
            &caps);
        assert_eq!(d20, d25);
        assert_ne!(d20, d200);
        assert_ne!(d20, best);
    }
}
