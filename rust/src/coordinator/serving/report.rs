//! Completion records and the aggregate serving report, including the
//! per-SLO-class sections the handle API's contracts are judged by and
//! the per-worker-class sections a heterogeneous fleet is judged by
//! (which hardware class served what, at which tiers, with which
//! learned latency model).

use super::tier_matches;
use crate::metrics::{summarize, Log2Hist, Summary};

/// Completion record for one request.  The same struct rides inside the
/// caller's `Reply` (with logits alongside) and the engine's report.
///
/// Timing contract: `queue_ms` and `exec_ms` are measured on one
/// monotonic clock (admission stamp -> batch execution start -> batch
/// execution end), and `total_ms == queue_ms + exec_ms` exactly.  No
/// component is derived from a backend's *modeled* latency, so a fast
/// completion can never report a negative queue wait.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// SLO class name the request was submitted under
    pub class: String,
    pub tier: f32,
    /// index of the worker that executed the request's batch
    pub worker: usize,
    /// name of the worker class that executed the request's batch
    /// ("default" for a single-factory engine)
    pub worker_class: String,
    pub queue_ms: f64,
    pub exec_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
}

/// Why a request was shed — the dimension that lets `ServeReport` shed
/// totals reconcile exactly with client-observed verdicts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// a worker found the deadline already expired at pop time
    DeadlineExceeded,
    /// the engine refused or abandoned the request because admission
    /// was closed (client saw `Shed(ShuttingDown)` or a
    /// `ServeError::ShuttingDown` resolution)
    ShuttingDown,
    /// the quarantine ladder isolated the request as the poison of a
    /// repeatedly-failing batch (client saw `ServeError::Poisoned`);
    /// its co-batched neighbours were retried and served
    Poisoned,
}

/// One shed one-shot request: a worker-side deadline shed, or an
/// engine-side `ShuttingDown` rejection (`worker_class == "engine"`,
/// since no worker ever saw it).  `Shed(QueueFull)` admission verdicts
/// are deliberately not logged: they never enter the engine, and an
/// overload sweep would bury the report under them.
#[derive(Debug, Clone)]
pub struct ShedRecord {
    pub id: u64,
    pub class: String,
    /// worker class of the worker that shed it ("engine" for
    /// engine-side rejections)
    pub worker_class: String,
    pub cause: ShedCause,
}

/// One shed decode session (terminal `StreamEvent::Shed`): which
/// session, how far it got, and why.
#[derive(Debug, Clone)]
pub struct StreamShedRecord {
    /// caller-chosen session id
    pub id: u64,
    /// SLO class name the session ran under
    pub class: String,
    /// worker class that shed it ("engine" at teardown)
    pub worker_class: String,
    /// tokens the session had generated (and delivered) before the shed
    pub steps_done: usize,
    pub reason: super::ServeError,
}

/// Per-SLO-class section of the report.
#[derive(Debug, Clone)]
pub struct ClassStats {
    pub class: String,
    pub served: usize,
    /// requests shed for an expired deadline
    pub shed: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_capacity: f64,
}

/// Identity and learned state of one worker class, snapshotted by the
/// engine at shutdown.
#[derive(Debug, Clone)]
pub struct WorkerClassInfo {
    pub name: String,
    pub workers: usize,
    /// the class controller's per-tier exec-time EWMAs, `(tier,
    /// ms-if-observed)` in ladder order — `None` means this class never
    /// executed a batch at that tier
    pub exec_estimates_ms: Vec<(f32, Option<f64>)>,
    /// decode-step rows this class served from its session arena
    pub cache_hits: usize,
    /// decode-step rows this class recomputed from the session table
    pub cache_misses: usize,
    /// speculative proposals this class resolved (counted at verify
    /// resolution, so `drafted == accepted + rejected` always holds)
    pub drafted: usize,
    /// proposals the verifier agreed with (emitted at the draft tier)
    pub accepted: usize,
    /// proposals discarded at the first disagreement
    pub rejected: usize,
    /// verify passes this class resolved — the speculative cycle count
    pub verifies: usize,
    /// transient execute failures retried in place by this class's
    /// workers (each backoff attempt after the first try counts one)
    pub retries: usize,
    /// bisections the quarantine ladder performed (each split of a
    /// still-failing span into two independently-retried halves)
    pub splits: usize,
    /// units quarantined as poison after the ladder isolated them to a
    /// single request (or verify row group) that kept failing
    pub poisoned: usize,
    /// executors rebuilt through the class factory after a fatal fault
    /// or panic, under the class's restart budget
    pub respawns: usize,
    /// circuit-breaker trips (Closed -> Open transitions; a HalfOpen
    /// probe failing back to Open is the same incident, not a new trip)
    pub breaker_trips: usize,
}

/// Per-worker-class section of the report: how one hardware class
/// fared behind the shared queue — served/shed split, latency, tier
/// mix, and the exec-time model its own controller learned.
#[derive(Debug, Clone)]
pub struct WorkerClassStats {
    pub class: String,
    pub workers: usize,
    pub served: usize,
    /// requests this class's workers shed for an expired deadline
    pub shed: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_capacity: f64,
    /// completions per configured tier, same ladder as the aggregate
    pub tier_counts: Vec<(f32, usize)>,
    pub exec_estimates_ms: Vec<(f32, Option<f64>)>,
    /// decode-step rows served from this class's session arena vs
    /// recomputed from the session table
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// Per-SLO-class section of the *streaming* report: how one class's
/// decode sessions fared — completion/shed split, token throughput,
/// session and first-token latency, and the per-step tier histogram
/// (how often decode steps ran at each ladder rung — the engine-level
/// picture of per-step elasticity).
#[derive(Debug, Clone)]
pub struct StreamSection {
    pub class: String,
    /// sessions that generated their full budget (terminal `Done`)
    pub completed: usize,
    /// sessions terminated early (terminal `Shed`)
    pub shed: usize,
    /// tokens generated and delivered, including a shed session's
    /// pre-shed tokens
    pub tokens: usize,
    /// `tokens / wall_secs` — the streaming throughput figure
    pub tokens_per_s: f64,
    /// session wall-time percentiles over completed sessions
    pub p50_session_ms: f64,
    pub p99_session_ms: f64,
    /// mean submit → first-token latency over completed sessions
    pub mean_first_token_ms: f64,
    /// decode-step count per configured tier over completed sessions'
    /// trajectories, same ladder as the aggregate `tier_counts`
    pub tier_step_counts: Vec<(f32, usize)>,
}

/// Per-worker-class section of the *speculative* report: how one
/// class's draft/verify cycles fared — proposal volume, accept split,
/// the learned accept rate, and the tokens-per-admission estimate its
/// cycles imply.  Only classes that resolved at least one verify pass
/// get a section (a plain-decode fleet reports none).
#[derive(Debug, Clone)]
pub struct SpecSection {
    pub class: String,
    /// proposals resolved (`drafted == accepted + rejected` always)
    pub drafted: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// verify passes resolved — the cycle count
    pub verifies: usize,
    /// `accepted / drafted` (0.0 when nothing was drafted)
    pub accept_rate: f64,
    /// estimated tokens per admission item over this class's
    /// speculative cycles: each cycle enqueues two items (the draft
    /// step and its verify re-admission) and emits `accepted + 1`
    /// tokens (the agreeing prefix plus the verifier's own token), so
    /// the estimate is `(accepted + verifies) / (2 * verifies)`
    pub tokens_per_admission: f64,
}

/// Per-worker-class section of the *fault* report: what the tolerance
/// ladder did for one class — in-place retries, quarantine bisections,
/// poisoned units, supervised respawns, and circuit-breaker trips.
/// Only classes that saw at least one fault event get a section (a
/// healthy fleet reports none).
#[derive(Debug, Clone)]
pub struct FaultSection {
    pub class: String,
    /// transient failures retried in place
    pub retries: usize,
    /// quarantine-ladder bisections
    pub splits: usize,
    /// units shed as [`ShedCause::Poisoned`]
    pub poisoned: usize,
    /// executors rebuilt through the class factory
    pub respawns: usize,
    /// Closed -> Open breaker transitions
    pub breaker_trips: usize,
}

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub sheds: Vec<ShedRecord>,
    pub wall_secs: f64,
    pub tier_counts: Vec<(f32, usize)>,
    pub workers: usize,
    /// worker-class identities + learned estimates (engine shutdown
    /// attaches these via [`with_worker_classes`]; hand-built reports
    /// may leave it empty)
    ///
    /// [`with_worker_classes`]: ServeReport::with_worker_classes
    pub worker_classes: Vec<WorkerClassInfo>,
    /// decode sessions ever admitted — the reconciliation base:
    /// `sessions_started == stream_done.len() + stream_shed.len()`
    /// after a clean shutdown
    pub sessions_started: usize,
    /// completed decode sessions, with their per-step trajectories
    pub stream_done: Vec<super::StreamStats>,
    /// shed decode sessions
    pub stream_shed: Vec<StreamShedRecord>,
    /// decode-step rows served from the session arenas (all classes)
    pub cache_hits: usize,
    /// decode-step rows recomputed from the session table (arena miss,
    /// spill, or disabled arena)
    pub cache_misses: usize,
    /// speculative proposals resolved fleet-wide (at verify time):
    /// `spec_drafted == spec_accepted + spec_rejected` by construction
    pub spec_drafted: usize,
    /// proposals the top-tier verifier agreed with
    pub spec_accepted: usize,
    /// proposals discarded at the first disagreement
    pub spec_rejected: usize,
    /// streaming admission items ever enqueued — the session admit
    /// plus every continuation (decode, draft, *and* verify steps);
    /// the denominator of [`tokens_per_admission`]
    ///
    /// [`tokens_per_admission`]: ServeReport::tokens_per_admission
    pub stream_step_items: usize,
    /// every worker-side error the engine absorbed without dying:
    /// execute faults that were retried past, respawn causes, degraded
    /// startup failures.  Empty on a healthy run; populated entries
    /// mean the fleet survived something, not that the run failed.
    pub worker_errors: Vec<String>,
}

impl ServeReport {
    pub fn new(completions: Vec<Completion>, sheds: Vec<ShedRecord>,
               wall_secs: f64, tiers: &[f32], workers: usize)
               -> ServeReport {
        let mut tier_counts: Vec<(f32, usize)> =
            tiers.iter().map(|&c| (c, 0usize)).collect();
        for c in &completions {
            if let Some(tc) = tier_counts
                .iter_mut()
                .find(|(t, _)| tier_matches(*t, c.tier))
            {
                tc.1 += 1;
            }
        }
        ServeReport {
            completions,
            sheds,
            wall_secs,
            tier_counts,
            workers,
            worker_classes: Vec::new(),
            sessions_started: 0,
            stream_done: Vec::new(),
            stream_shed: Vec::new(),
            cache_hits: 0,
            cache_misses: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            spec_rejected: 0,
            stream_step_items: 0,
            worker_errors: Vec::new(),
        }
    }

    /// Attach the fleet's worker-class identities and their learned
    /// exec-time estimates (the engine does this at shutdown).
    pub fn with_worker_classes(mut self, classes: Vec<WorkerClassInfo>)
                               -> ServeReport {
        self.worker_classes = classes;
        self
    }

    /// Attach the streaming subsystem's session logs (the engine does
    /// this at shutdown).
    pub fn with_streams(mut self, started: usize,
                        done: Vec<super::StreamStats>,
                        shed: Vec<StreamShedRecord>) -> ServeReport {
        self.sessions_started = started;
        self.stream_done = done;
        self.stream_shed = shed;
        self
    }

    /// Attach the session arenas' aggregate decode-row cache counters
    /// (the engine does this at shutdown).
    pub fn with_cache(mut self, hits: usize, misses: usize)
                      -> ServeReport {
        self.cache_hits = hits;
        self.cache_misses = misses;
        self
    }

    /// Attach the speculative-decode totals and the streaming
    /// admission-item count (the engine does this at shutdown).
    pub fn with_spec(mut self, drafted: usize, accepted: usize,
                     rejected: usize, step_items: usize) -> ServeReport {
        self.spec_drafted = drafted;
        self.spec_accepted = accepted;
        self.spec_rejected = rejected;
        self.stream_step_items = step_items;
        self
    }

    /// Attach the worker errors the engine absorbed (the engine does
    /// this at shutdown).
    pub fn with_worker_errors(mut self, errors: Vec<String>)
                              -> ServeReport {
        self.worker_errors = errors;
        self
    }

    /// Per-worker-class sections of the fault report, in fleet
    /// declaration order: retries, quarantine bisections, poisoned
    /// units, respawns, breaker trips.  Classes with no fault event at
    /// all are omitted — a healthy fleet reports an empty vec.
    pub fn fault_sections(&self) -> Vec<FaultSection> {
        self.worker_classes
            .iter()
            .filter(|i| {
                i.retries + i.splits + i.poisoned + i.respawns
                    + i.breaker_trips
                    > 0
            })
            .map(|i| FaultSection {
                class: i.name.clone(),
                retries: i.retries,
                splits: i.splits,
                poisoned: i.poisoned,
                respawns: i.respawns,
                breaker_trips: i.breaker_trips,
            })
            .collect()
    }

    /// Fleet-wide speculative accept rate: `accepted / drafted`, 0.0
    /// when no proposal was ever verified (plain decode, or every
    /// speculative session shed mid-draft).
    pub fn spec_accept_rate(&self) -> f64 {
        self.spec_accept_rate_opt().unwrap_or(0.0)
    }

    /// [`spec_accept_rate`] with the zero-denominator case made
    /// explicit: `None` when no proposal was ever verified, so report
    /// printers can write "n/a" instead of a misleading 0.0 (which
    /// reads as "everything was rejected").
    ///
    /// [`spec_accept_rate`]: ServeReport::spec_accept_rate
    pub fn spec_accept_rate_opt(&self) -> Option<f64> {
        if self.spec_drafted == 0 {
            None
        } else {
            Some(self.spec_accepted as f64 / self.spec_drafted as f64)
        }
    }

    /// Delivered stream tokens per admission item (admit + every
    /// requeued continuation, draft and verify steps included).
    /// Plain decode is exactly 1.0 — every item emits one token — so
    /// any value above 1.0 is speculative acceptance paying for its
    /// verification batches.  0.0 when no stream item was ever
    /// enqueued.
    pub fn tokens_per_admission(&self) -> f64 {
        if self.stream_step_items == 0 {
            return 0.0;
        }
        let tokens: usize = self
            .stream_done
            .iter()
            .map(|s| s.steps)
            .chain(self.stream_shed.iter().map(|s| s.steps_done))
            .sum();
        tokens as f64 / self.stream_step_items as f64
    }

    /// Per-worker-class sections of the speculative report, in fleet
    /// declaration order: proposal volume, accept split and rate, and
    /// the per-class tokens-per-admission estimate.  Classes that
    /// never resolved a verify pass are omitted.
    pub fn spec_sections(&self) -> Vec<SpecSection> {
        self.worker_classes
            .iter()
            .filter(|i| i.verifies > 0)
            .map(|i| SpecSection {
                class: i.name.clone(),
                drafted: i.drafted,
                accepted: i.accepted,
                rejected: i.rejected,
                verifies: i.verifies,
                accept_rate: if i.drafted == 0 {
                    0.0
                } else {
                    i.accepted as f64 / i.drafted as f64
                },
                tokens_per_admission: (i.accepted + i.verifies) as f64
                    / (2 * i.verifies) as f64,
            })
            .collect()
    }

    /// Fraction of decode-step rows served from a session arena
    /// instead of the full-window recompute (0.0 when no decode step
    /// ever consulted an arena).
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache_hit_rate_opt().unwrap_or(0.0)
    }

    /// [`cache_hit_rate`] with the zero-denominator case made
    /// explicit: `None` when no decode step ever consulted an arena
    /// (one-shot-only runs, disabled arenas), so printers can write
    /// "n/a" instead of a 0.0 that reads as "every lookup missed".
    ///
    /// [`cache_hit_rate`]: ServeReport::cache_hit_rate
    pub fn cache_hit_rate_opt(&self) -> Option<f64> {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            None
        } else {
            Some(self.cache_hits as f64 / total as f64)
        }
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_secs.max(1e-9)
    }

    pub fn latency_summary(&self) -> Summary {
        summarize(
            &self.completions.iter().map(|c| c.total_ms).collect::<Vec<_>>())
    }

    /// Total-latency percentile from the shared log2-bucket histogram
    /// ([`Log2Hist`]): nearest-rank over the buckets, reported as the
    /// target bucket's midpoint — within half a bucket width (~12.5%
    /// relative) of the exact sample.  Using the same histogram here as
    /// in the live [`EngineSnapshot`] means a mid-run snapshot and the
    /// shutdown report can never disagree by more than bucket rounding.
    ///
    /// [`EngineSnapshot`]: super::EngineSnapshot
    pub fn latency_p(&self, q: f64) -> f64 {
        let xs: Vec<f64> =
            self.completions.iter().map(|c| c.total_ms).collect();
        Log2Hist::from_ms(&xs).quantile_ms(q)
    }

    /// Mean capacity actually served (compute proxy: fraction of teacher
    /// FLOPs spent, cf. analysis::flops for the exact mapping).
    pub fn mean_capacity(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.tier as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Completions executed by each worker, indexed by worker id.
    pub fn worker_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.workers.max(1)];
        for c in &self.completions {
            if c.worker < counts.len() {
                counts[c.worker] += 1;
            }
        }
        counts
    }

    /// Per-SLO-class sections, sorted by class name: how each contract
    /// actually fared on the shared queue (served/shed split, latency
    /// percentiles, mean capacity).
    pub fn class_sections(&self) -> Vec<ClassStats> {
        let mut names: Vec<&str> = self
            .completions
            .iter()
            .map(|c| c.class.as_str())
            .chain(self.sheds.iter().map(|s| s.class.as_str()))
            .collect();
        names.sort_unstable();
        names.dedup();
        names
            .into_iter()
            .map(|name| {
                let mut lat: Vec<f64> = Vec::new();
                let mut cap = 0.0f64;
                for c in self.completions.iter().filter(|c| c.class == name)
                {
                    lat.push(c.total_ms);
                    cap += c.tier as f64;
                }
                let served = lat.len();
                let shed =
                    self.sheds.iter().filter(|s| s.class == name).count();
                let hist = Log2Hist::from_ms(&lat);
                ClassStats {
                    class: name.to_string(),
                    served,
                    shed,
                    p50_ms: hist.quantile_ms(0.5),
                    p99_ms: hist.quantile_ms(0.99),
                    mean_capacity: if served == 0 {
                        0.0
                    } else {
                        cap / served as f64
                    },
                }
            })
            .collect()
    }

    /// Total streaming token throughput: every delivered token
    /// (completed sessions' full budgets plus shed sessions' pre-shed
    /// tokens) over the report's wall time.
    pub fn tokens_per_s(&self) -> f64 {
        let tokens: usize = self
            .stream_done
            .iter()
            .map(|s| s.steps)
            .chain(self.stream_shed.iter().map(|s| s.steps_done))
            .sum();
        tokens as f64 / self.wall_secs.max(1e-9)
    }

    /// Per-SLO-class sections of the streaming report, sorted by class
    /// name: completion/shed split, token throughput, session latency
    /// percentiles, first-token latency, and the per-step tier
    /// trajectory histogram (over completed sessions — a shed
    /// session's trajectory dies with it; its delivered tokens still
    /// count toward throughput).
    pub fn stream_sections(&self) -> Vec<StreamSection> {
        let mut names: Vec<&str> = self
            .stream_done
            .iter()
            .map(|s| s.class.as_str())
            .chain(self.stream_shed.iter().map(|s| s.class.as_str()))
            .collect();
        names.sort_unstable();
        names.dedup();
        names
            .into_iter()
            .map(|name| {
                let done: Vec<&super::StreamStats> = self
                    .stream_done
                    .iter()
                    .filter(|s| s.class == name)
                    .collect();
                let shed: Vec<&StreamShedRecord> = self
                    .stream_shed
                    .iter()
                    .filter(|s| s.class == name)
                    .collect();
                let tokens: usize = done.iter().map(|s| s.steps).sum::<usize>()
                    + shed.iter().map(|s| s.steps_done).sum::<usize>();
                let session_ms: Vec<f64> =
                    done.iter().map(|s| s.total_ms).collect();
                let session_hist = Log2Hist::from_ms(&session_ms);
                let mut tier_step_counts: Vec<(f32, usize)> = self
                    .tier_counts
                    .iter()
                    .map(|(t, _)| (*t, 0usize))
                    .collect();
                for s in &done {
                    for &tier in &s.tiers {
                        if let Some(tc) = tier_step_counts
                            .iter_mut()
                            .find(|(t, _)| tier_matches(*t, tier))
                        {
                            tc.1 += 1;
                        }
                    }
                }
                let first_token: f64 = done
                    .iter()
                    .map(|s| s.first_token_ms)
                    .sum::<f64>();
                StreamSection {
                    class: name.to_string(),
                    completed: done.len(),
                    shed: shed.len(),
                    tokens,
                    tokens_per_s: tokens as f64
                        / self.wall_secs.max(1e-9),
                    p50_session_ms: session_hist.quantile_ms(0.5),
                    p99_session_ms: session_hist.quantile_ms(0.99),
                    mean_first_token_ms: if done.is_empty() {
                        0.0
                    } else {
                        first_token / done.len() as f64
                    },
                    tier_step_counts,
                }
            })
            .collect()
    }

    /// Per-worker-class sections, in fleet declaration order: which
    /// hardware class served what.  Classes come from the attached
    /// [`WorkerClassInfo`]s plus any class names present only in the
    /// records (hand-built reports), so no executing class is hidden.
    pub fn worker_class_sections(&self) -> Vec<WorkerClassStats> {
        type ClassSeed =
            (String, usize, Vec<(f32, Option<f64>)>, usize, usize);
        let mut classes: Vec<ClassSeed> = self
            .worker_classes
            .iter()
            .map(|i| {
                (i.name.clone(), i.workers, i.exec_estimates_ms.clone(),
                 i.cache_hits, i.cache_misses)
            })
            .collect();
        let names = self
            .completions
            .iter()
            .map(|c| c.worker_class.as_str())
            .chain(self.sheds.iter().map(|s| s.worker_class.as_str()));
        for name in names {
            if !classes.iter().any(|(n, ..)| n == name) {
                classes.push((name.to_string(), 0, Vec::new(), 0, 0));
            }
        }
        classes
            .into_iter()
            .map(|(name, workers, exec_estimates_ms, cache_hits,
                   cache_misses)| {
                let mut lat: Vec<f64> = Vec::new();
                let mut cap = 0.0f64;
                let mut tier_counts: Vec<(f32, usize)> = self
                    .tier_counts
                    .iter()
                    .map(|(t, _)| (*t, 0usize))
                    .collect();
                for c in self
                    .completions
                    .iter()
                    .filter(|c| c.worker_class == name)
                {
                    lat.push(c.total_ms);
                    cap += c.tier as f64;
                    if let Some(tc) = tier_counts
                        .iter_mut()
                        .find(|(t, _)| tier_matches(*t, c.tier))
                    {
                        tc.1 += 1;
                    }
                }
                let served = lat.len();
                let shed = self
                    .sheds
                    .iter()
                    .filter(|s| s.worker_class == name)
                    .count();
                let hist = Log2Hist::from_ms(&lat);
                WorkerClassStats {
                    class: name,
                    workers,
                    served,
                    shed,
                    p50_ms: hist.quantile_ms(0.5),
                    p99_ms: hist.quantile_ms(0.99),
                    mean_capacity: if served == 0 {
                        0.0
                    } else {
                        cap / served as f64
                    },
                    tier_counts,
                    exec_estimates_ms,
                    cache_hits,
                    cache_misses,
                }
            })
            .collect()
    }
}

/// Nearest-rank percentile over a *sorted* slice.  `q <= 0` returns the
/// min, `q >= 1` the max, an empty slice 0.0.
///
/// The report itself now quotes percentiles from the log2-bucket
/// histogram ([`Log2Hist`]); this exact method stays as the reference
/// the within-one-bucket pinning tests compare against.
pub fn percentile_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as i64;
    let idx = rank.clamp(1, n as i64) as usize - 1;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(i: u64, ms: f64) -> Completion {
        Completion {
            id: i,
            class: "best-effort".into(),
            tier: 1.0,
            worker: 0,
            worker_class: "default".into(),
            queue_ms: 0.0,
            exec_ms: ms,
            total_ms: ms,
            batch_size: 1,
        }
    }

    fn report(latencies: &[f64]) -> ServeReport {
        let completions = latencies
            .iter()
            .enumerate()
            .map(|(i, &ms)| completion(i as u64, ms))
            .collect();
        ServeReport::new(completions, Vec::new(), 1.0, &[1.0], 1)
    }

    /// The histogram quotes a bucket midpoint, so "equals the exact
    /// sample" relaxes to "lands in the exact sample's bucket".
    fn assert_in_bucket(got: f64, exact: f64) {
        let (lo, hi) = Log2Hist::bucket_bounds_ms(exact);
        assert!(got >= lo && got <= hi,
                "got {got} outside [{lo}, {hi}] (exact {exact})");
    }

    #[test]
    fn percentile_empty_is_zero() {
        let r = report(&[]);
        assert_eq!(r.latency_p(0.5), 0.0);
        assert_eq!(r.latency_p(0.99), 0.0);
        assert_eq!(r.mean_capacity(), 0.0);
        assert!(r.class_sections().is_empty());
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        let r = report(&[7.5]);
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_in_bucket(r.latency_p(q), 7.5);
        }
    }

    #[test]
    fn percentile_two_elements_nearest_rank() {
        let r = report(&[10.0, 20.0]);
        // rank ceil(0.5 * 2) = 1 -> the first sample's bucket (the old
        // round() code returned the max here)
        assert_in_bucket(r.latency_p(0.5), 10.0);
        assert_in_bucket(r.latency_p(0.51), 20.0);
        assert_in_bucket(r.latency_p(0.0), 10.0);
        assert_in_bucket(r.latency_p(1.0), 20.0);
    }

    #[test]
    fn percentile_hundred_elements() {
        let r = report(&(0..100).map(|i| i as f64).collect::<Vec<_>>());
        assert_in_bucket(r.latency_p(0.5), 49.0); // ceil(50) = rank 50
        assert_in_bucket(r.latency_p(0.99), 98.0); // ceil(99) = rank 99
        assert_in_bucket(r.latency_p(1.0), 99.0);
        assert_eq!(r.throughput_rps(), 100.0);
        assert_eq!(r.mean_capacity(), 1.0);
        assert_eq!(r.tier_counts, vec![(1.0, 100)]);
    }

    /// The pinning test for the histogram swap: every quoted quantile
    /// must land in the same log2 bucket as the exact nearest-rank
    /// answer over the raw samples — i.e. within one bucket width.
    #[test]
    fn histogram_percentiles_pin_to_nearest_rank_buckets() {
        let mut lat: Vec<f64> = (1..=257)
            .map(|i| (i as f64) * 0.37 + ((i * i) % 91) as f64)
            .collect();
        let r = report(&lat);
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.01, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let exact = percentile_nearest_rank(&lat, q);
            assert_in_bucket(r.latency_p(q), exact);
        }
    }

    #[test]
    fn percentile_out_of_range_q_clamps() {
        let sorted = [1.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&sorted, -0.5), 1.0);
        assert_eq!(percentile_nearest_rank(&sorted, 2.0), 3.0);
    }

    #[test]
    fn worker_counts_partition_completions() {
        let mut completions = Vec::new();
        for i in 0..10u64 {
            let mut c = completion(i, 1.0);
            c.worker = (i % 3) as usize;
            completions.push(c);
        }
        let r = ServeReport::new(completions, Vec::new(), 1.0, &[1.0], 3);
        assert_eq!(r.worker_counts(), vec![4, 3, 3]);
    }

    #[test]
    fn class_sections_split_served_and_shed_per_class() {
        let mut completions = Vec::new();
        for i in 0..6u64 {
            let mut c = completion(i, (i + 1) as f64);
            c.class = "relaxed".into();
            completions.push(c);
        }
        let mut tight = completion(100, 2.0);
        tight.class = "tight".into();
        tight.tier = 0.25;
        completions.push(tight);
        let sheds = vec![
            ShedRecord {
                id: 101,
                class: "tight".into(),
                worker_class: "default".into(),
                cause: ShedCause::DeadlineExceeded,
            },
            ShedRecord {
                id: 102,
                class: "tight".into(),
                worker_class: "default".into(),
                cause: ShedCause::DeadlineExceeded,
            },
        ];
        let r = ServeReport::new(completions, sheds, 1.0, &[1.0, 0.25], 1);
        let sections = r.class_sections();
        assert_eq!(sections.len(), 2);
        let relaxed =
            sections.iter().find(|s| s.class == "relaxed").unwrap();
        assert_eq!((relaxed.served, relaxed.shed), (6, 0));
        assert_in_bucket(relaxed.p50_ms, 3.0);
        assert_eq!(relaxed.mean_capacity, 1.0);
        let tight = sections.iter().find(|s| s.class == "tight").unwrap();
        assert_eq!((tight.served, tight.shed), (1, 2));
        assert_in_bucket(tight.p50_ms, 2.0);
        assert!((tight.mean_capacity - 0.25).abs() < 1e-9);
    }

    #[test]
    fn class_sections_include_fully_shed_classes() {
        // a class whose every request was shed must still get a section
        // (served = 0) — otherwise the report hides the starved class
        let sheds = vec![ShedRecord {
            id: 0,
            class: "starved".into(),
            worker_class: "default".into(),
            cause: ShedCause::DeadlineExceeded,
        }];
        let r = ServeReport::new(Vec::new(), sheds, 1.0, &[1.0], 1);
        let sections = r.class_sections();
        assert_eq!(sections.len(), 1);
        assert_eq!(sections[0].class, "starved");
        assert_eq!((sections[0].served, sections[0].shed), (0, 1));
        assert_eq!(sections[0].mean_capacity, 0.0);
    }

    #[test]
    fn worker_class_sections_partition_by_executing_class() {
        // 4 completions on "fast" at tier 1.0, 2 on "slow" at tier
        // 0.25, one slow-side shed: sections must partition by the
        // executing class and surface each class's learned estimates
        let mut completions = Vec::new();
        for i in 0..6u64 {
            let mut c = completion(i, 1.0 + i as f64);
            if i < 4 {
                c.worker_class = "fast".into();
            } else {
                c.worker_class = "slow".into();
                c.tier = 0.25;
                c.worker = 1;
            }
            completions.push(c);
        }
        let sheds = vec![ShedRecord {
            id: 100,
            class: "tight".into(),
            worker_class: "slow".into(),
            cause: ShedCause::DeadlineExceeded,
        }];
        let infos = vec![
            WorkerClassInfo {
                name: "fast".into(),
                workers: 1,
                exec_estimates_ms: vec![(1.0, Some(0.5)), (0.25, None)],
                cache_hits: 12,
                cache_misses: 4,
                drafted: 0,
                accepted: 0,
                rejected: 0,
                verifies: 0,
                retries: 0,
                splits: 0,
                poisoned: 0,
                respawns: 0,
                breaker_trips: 0,
            },
            WorkerClassInfo {
                name: "slow".into(),
                workers: 1,
                exec_estimates_ms: vec![(1.0, Some(40.0)), (0.25, None)],
                cache_hits: 0,
                cache_misses: 0,
                drafted: 0,
                accepted: 0,
                rejected: 0,
                verifies: 0,
                retries: 0,
                splits: 0,
                poisoned: 0,
                respawns: 0,
                breaker_trips: 0,
            },
        ];
        let r = ServeReport::new(completions, sheds, 1.0, &[1.0, 0.25], 2)
            .with_worker_classes(infos);
        let sections = r.worker_class_sections();
        assert_eq!(sections.len(), 2);
        let fast = sections.iter().find(|s| s.class == "fast").unwrap();
        assert_eq!((fast.served, fast.shed, fast.workers), (4, 0, 1));
        assert_eq!(fast.mean_capacity, 1.0);
        assert_eq!(fast.tier_counts, vec![(1.0, 4), (0.25, 0)]);
        assert_eq!(fast.exec_estimates_ms[0], (1.0, Some(0.5)));
        assert_eq!((fast.cache_hits, fast.cache_misses), (12, 4));
        let slow = sections.iter().find(|s| s.class == "slow").unwrap();
        assert_eq!((slow.served, slow.shed), (2, 1));
        assert!((slow.mean_capacity - 0.25).abs() < 1e-9);
        assert_eq!(slow.tier_counts, vec![(1.0, 0), (0.25, 2)]);
        assert_eq!(slow.exec_estimates_ms[0], (1.0, Some(40.0)));
    }

    fn stream_stats(id: u64, class: &str, tiers: Vec<f32>, total_ms: f64)
                    -> crate::coordinator::serving::StreamStats {
        crate::coordinator::serving::StreamStats {
            id,
            class: class.into(),
            steps: tiers.len(),
            tiers,
            total_ms,
            first_token_ms: total_ms / 2.0,
            tokens_dropped: 0,
        }
    }

    #[test]
    fn stream_sections_split_classes_and_histogram_step_tiers() {
        let done = vec![
            stream_stats(0, "chat", vec![1.0, 1.0, 0.5], 30.0),
            stream_stats(1, "chat", vec![0.5, 0.5, 0.5], 10.0),
            stream_stats(2, "bulk", vec![0.25], 5.0),
        ];
        let shed = vec![StreamShedRecord {
            id: 3,
            class: "chat".into(),
            worker_class: "default".into(),
            steps_done: 2,
            reason: crate::coordinator::serving::ServeError::
                DeadlineExceeded,
        }];
        let r = ServeReport::new(Vec::new(), Vec::new(), 2.0,
                                 &[1.0, 0.5, 0.25], 1)
            .with_streams(4, done, shed);
        assert_eq!(r.sessions_started, 4);
        // 3 + 3 + 1 delivered by completed sessions, 2 by the shed one
        assert!((r.tokens_per_s() - 9.0 / 2.0).abs() < 1e-9);
        let sections = r.stream_sections();
        assert_eq!(sections.len(), 2, "one section per SLO class");
        let chat = sections.iter().find(|s| s.class == "chat").unwrap();
        assert_eq!((chat.completed, chat.shed), (2, 1));
        assert_eq!(chat.tokens, 8, "shed session's tokens still count");
        assert!((chat.tokens_per_s - 4.0).abs() < 1e-9);
        assert_in_bucket(chat.p50_session_ms, 10.0);
        assert_in_bucket(chat.p99_session_ms, 30.0);
        assert!((chat.mean_first_token_ms - 10.0).abs() < 1e-9);
        // trajectory histogram: 2 steps at 1.0, 4 at 0.5, none at 0.25
        assert_eq!(chat.tier_step_counts,
                   vec![(1.0, 2), (0.5, 4), (0.25, 0)]);
        let bulk = sections.iter().find(|s| s.class == "bulk").unwrap();
        assert_eq!((bulk.completed, bulk.shed, bulk.tokens), (1, 0, 1));
        assert_eq!(bulk.tier_step_counts,
                   vec![(1.0, 0), (0.5, 0), (0.25, 1)]);
    }

    #[test]
    fn reports_without_streams_have_empty_stream_sections() {
        let r = report(&[1.0, 2.0]);
        assert_eq!(r.sessions_started, 0);
        assert!(r.stream_sections().is_empty());
        assert_eq!(r.tokens_per_s(), 0.0);
    }

    #[test]
    fn cache_hit_rate_is_hits_over_consulted_lookups() {
        let r = report(&[1.0]);
        assert_eq!(r.cache_hit_rate(), 0.0,
                   "no lookups must read 0.0, not NaN");
        assert_eq!(r.cache_hit_rate_opt(), None,
                   "the Option variant distinguishes \"no lookups\"");
        let r = report(&[1.0]).with_cache(3, 1);
        assert!((r.cache_hit_rate() - 0.75).abs() < 1e-9);
        assert!((r.cache_hit_rate_opt().unwrap() - 0.75).abs() < 1e-9);
        let r = report(&[1.0]).with_cache(0, 5);
        assert_eq!(r.cache_hit_rate(), 0.0);
        assert_eq!(r.cache_hit_rate_opt(), Some(0.0),
                   "all-miss is a real 0.0, not n/a");
    }

    #[test]
    fn spec_sections_cover_only_classes_that_verified() {
        let infos = vec![
            WorkerClassInfo {
                name: "spec".into(),
                workers: 1,
                exec_estimates_ms: vec![(1.0, Some(1.0))],
                cache_hits: 0,
                cache_misses: 0,
                drafted: 8,
                accepted: 6,
                rejected: 2,
                verifies: 2,
                retries: 0,
                splits: 0,
                poisoned: 0,
                respawns: 0,
                breaker_trips: 0,
            },
            WorkerClassInfo {
                name: "plain".into(),
                workers: 1,
                exec_estimates_ms: vec![(1.0, Some(1.0))],
                cache_hits: 0,
                cache_misses: 0,
                drafted: 0,
                accepted: 0,
                rejected: 0,
                verifies: 0,
                retries: 0,
                splits: 0,
                poisoned: 0,
                respawns: 0,
                breaker_trips: 0,
            },
        ];
        let r = ServeReport::new(Vec::new(), Vec::new(), 1.0, &[1.0], 2)
            .with_worker_classes(infos)
            .with_spec(8, 6, 2, 0);
        let sections = r.spec_sections();
        assert_eq!(sections.len(), 1, "plain class gets no section");
        let s = &sections[0];
        assert_eq!(s.class, "spec");
        assert_eq!(s.drafted, s.accepted + s.rejected);
        assert!((s.accept_rate - 0.75).abs() < 1e-9);
        // 2 cycles = 4 admission items, 6 + 2 tokens -> 2.0 per item
        assert!((s.tokens_per_admission - 2.0).abs() < 1e-9);
        assert!((r.spec_accept_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn tokens_per_admission_is_unity_for_plain_decode() {
        // 3 delivered tokens over 3 admission items (admit + 2
        // requeues) — the plain-decode identity the CI gate leans on
        let done = vec![stream_stats(0, "chat", vec![1.0, 1.0, 1.0], 3.0)];
        let r = ServeReport::new(Vec::new(), Vec::new(), 1.0, &[1.0], 1)
            .with_streams(1, done, Vec::new())
            .with_spec(0, 0, 0, 3);
        assert!((r.tokens_per_admission() - 1.0).abs() < 1e-9);
        assert_eq!(r.spec_accept_rate(), 0.0);
        assert_eq!(r.spec_accept_rate_opt(), None,
                   "nothing drafted is n/a, not an all-rejected 0.0");
        // no items ever enqueued reads 0.0, not NaN
        let empty = report(&[1.0]);
        assert_eq!(empty.tokens_per_admission(), 0.0);
    }

    #[test]
    fn fault_sections_cover_only_classes_with_fault_events() {
        let healthy = WorkerClassInfo {
            name: "healthy".into(),
            workers: 2,
            exec_estimates_ms: vec![(1.0, Some(1.0))],
            cache_hits: 0,
            cache_misses: 0,
            drafted: 0,
            accepted: 0,
            rejected: 0,
            verifies: 0,
            retries: 0,
            splits: 0,
            poisoned: 0,
            respawns: 0,
            breaker_trips: 0,
        };
        let mut flaky = healthy.clone();
        flaky.name = "flaky".into();
        flaky.retries = 7;
        flaky.splits = 2;
        flaky.poisoned = 1;
        flaky.respawns = 1;
        flaky.breaker_trips = 1;
        let r = ServeReport::new(Vec::new(), Vec::new(), 1.0, &[1.0], 4)
            .with_worker_classes(vec![healthy, flaky])
            .with_worker_errors(vec!["worker 3: execution: boom".into()]);
        let sections = r.fault_sections();
        assert_eq!(sections.len(), 1, "healthy class gets no section");
        let s = &sections[0];
        assert_eq!(s.class, "flaky");
        assert_eq!((s.retries, s.splits, s.poisoned), (7, 2, 1));
        assert_eq!((s.respawns, s.breaker_trips), (1, 1));
        assert_eq!(r.worker_errors.len(), 1);
        // a report with no fault events at all reads clean
        assert!(report(&[1.0]).fault_sections().is_empty());
    }

    #[test]
    fn worker_class_sections_include_classes_absent_from_infos() {
        // a hand-built report with no attached infos must still derive
        // a section for every executing class it has records for
        let mut c = completion(0, 2.0);
        c.worker_class = "mystery".into();
        let r = ServeReport::new(vec![c], Vec::new(), 1.0, &[1.0], 1);
        let sections = r.worker_class_sections();
        assert_eq!(sections.len(), 1);
        assert_eq!(sections[0].class, "mystery");
        assert_eq!(sections[0].served, 1);
        assert_eq!(sections[0].workers, 0, "unknown fleet size reads 0");
        assert!(sections[0].exec_estimates_ms.is_empty());
    }
}
