//! Completion records and the aggregate serving report.

use super::tier_matches;
use crate::metrics::{summarize, Summary};

/// Completion record for one request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub tier: f32,
    /// index of the worker that executed the request's batch
    pub worker: usize,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub batch_size: usize,
}

/// Aggregate serving report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub completions: Vec<Completion>,
    pub wall_secs: f64,
    pub tier_counts: Vec<(f32, usize)>,
    pub workers: usize,
}

impl ServeReport {
    pub fn new(completions: Vec<Completion>, wall_secs: f64, tiers: &[f32],
               workers: usize) -> ServeReport {
        let mut tier_counts: Vec<(f32, usize)> =
            tiers.iter().map(|&c| (c, 0usize)).collect();
        for c in &completions {
            if let Some(tc) = tier_counts
                .iter_mut()
                .find(|(t, _)| tier_matches(*t, c.tier))
            {
                tc.1 += 1;
            }
        }
        ServeReport { completions, wall_secs, tier_counts, workers }
    }

    pub fn throughput_rps(&self) -> f64 {
        self.completions.len() as f64 / self.wall_secs.max(1e-9)
    }

    pub fn latency_summary(&self) -> Summary {
        summarize(
            &self.completions.iter().map(|c| c.total_ms).collect::<Vec<_>>())
    }

    /// Total-latency percentile by the nearest-rank method: the smallest
    /// sample with at least `ceil(q * n)` samples at or below it.  (The
    /// old `round()`-based indexing mixed ranks at small n: with n = 2,
    /// q = 0.5 it returned the max.)
    pub fn latency_p(&self, q: f64) -> f64 {
        let mut xs: Vec<f64> =
            self.completions.iter().map(|c| c.total_ms).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_nearest_rank(&xs, q)
    }

    /// Mean capacity actually served (compute proxy: fraction of teacher
    /// FLOPs spent, cf. analysis::flops for the exact mapping).
    pub fn mean_capacity(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        self.completions.iter().map(|c| c.tier as f64).sum::<f64>()
            / self.completions.len() as f64
    }

    /// Completions executed by each worker, indexed by worker id.
    pub fn worker_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.workers.max(1)];
        for c in &self.completions {
            if c.worker < counts.len() {
                counts[c.worker] += 1;
            }
        }
        counts
    }
}

/// Nearest-rank percentile over a *sorted* slice.  `q <= 0` returns the
/// min, `q >= 1` the max, an empty slice 0.0.
pub fn percentile_nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = (q * n as f64).ceil() as i64;
    let idx = rank.clamp(1, n as i64) as usize - 1;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completion(i: u64, ms: f64) -> Completion {
        Completion {
            id: i,
            tier: 1.0,
            worker: 0,
            queue_ms: 0.0,
            total_ms: ms,
            batch_size: 1,
        }
    }

    fn report(latencies: &[f64]) -> ServeReport {
        let completions = latencies
            .iter()
            .enumerate()
            .map(|(i, &ms)| completion(i as u64, ms))
            .collect();
        ServeReport::new(completions, 1.0, &[1.0], 1)
    }

    #[test]
    fn percentile_empty_is_zero() {
        let r = report(&[]);
        assert_eq!(r.latency_p(0.5), 0.0);
        assert_eq!(r.latency_p(0.99), 0.0);
        assert_eq!(r.mean_capacity(), 0.0);
    }

    #[test]
    fn percentile_single_element_is_that_element() {
        let r = report(&[7.5]);
        for q in [0.0, 0.01, 0.5, 0.99, 1.0] {
            assert_eq!(r.latency_p(q), 7.5, "q = {q}");
        }
    }

    #[test]
    fn percentile_two_elements_nearest_rank() {
        let r = report(&[10.0, 20.0]);
        // rank ceil(0.5 * 2) = 1 -> first element (the old round() code
        // returned 20.0 here)
        assert_eq!(r.latency_p(0.5), 10.0);
        assert_eq!(r.latency_p(0.51), 20.0);
        assert_eq!(r.latency_p(0.0), 10.0);
        assert_eq!(r.latency_p(1.0), 20.0);
    }

    #[test]
    fn percentile_hundred_elements() {
        let r = report(&(0..100).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(r.latency_p(0.5), 49.0); // ceil(50) = rank 50
        assert_eq!(r.latency_p(0.99), 98.0); // ceil(99) = rank 99
        assert_eq!(r.latency_p(1.0), 99.0);
        assert_eq!(r.throughput_rps(), 100.0);
        assert_eq!(r.mean_capacity(), 1.0);
        assert_eq!(r.tier_counts, vec![(1.0, 100)]);
    }

    #[test]
    fn percentile_out_of_range_q_clamps() {
        let sorted = [1.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&sorted, -0.5), 1.0);
        assert_eq!(percentile_nearest_rank(&sorted, 2.0), 3.0);
    }

    #[test]
    fn worker_counts_partition_completions() {
        let mut completions = Vec::new();
        for i in 0..10u64 {
            let mut c = completion(i, 1.0);
            c.worker = (i % 3) as usize;
            completions.push(c);
        }
        let r = ServeReport::new(completions, 1.0, &[1.0], 3);
        assert_eq!(r.worker_counts(), vec![4, 3, 3]);
    }
}
