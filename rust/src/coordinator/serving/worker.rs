//! The `Executor` trait — "warm up and execute one batch at a capacity
//! tier, returning its logits" — plus the PJRT implementor and the
//! worker loop that drives any implementor from the shared admission
//! queue and routes every completion back to its requester.
//!
//! PJRT handles are not `Send`, so executors never cross threads: the
//! engine calls its factory *on* each worker thread and the boxed
//! executor lives and dies there.  The worker loop itself is
//! backend-agnostic, which is what lets `tests/serving_sim.rs` exercise
//! the full submit → admit → batch → tier-select → execute → resolve
//! path through [`super::SimExecutor`] with no artifacts on disk.

use std::time::Instant;

use anyhow::Result;

use super::batcher::{batch_key, form_batch};
use super::report::{Completion, ShedRecord};
use super::{EngineShared, Pending, Reply, ServeError};

#[cfg(feature = "pjrt")]
use super::tier_matches;
#[cfg(feature = "pjrt")]
use crate::runtime::client::Arg;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

/// One executed batch's output: the flattened logits for every row of
/// the batch (real and padded rows alike).  `logits.len()` must be a
/// multiple of the executor's `batch()` so the worker can slice out
/// each request's row for its [`super::Reply`].
#[derive(Debug, Clone)]
pub struct ExecOutput {
    pub logits: Vec<f32>,
}

/// A serving backend: owns whatever compiled/warmed state one worker
/// needs and executes one fixed-shape batch at a given capacity tier.
pub trait Executor {
    /// static batch dimension of the compiled executables
    fn batch(&self) -> usize;
    /// static sequence length of the compiled executables
    fn seq_len(&self) -> usize;
    /// Run one `batch() * seq_len()` token tensor at `tier` (one of the
    /// configured capacities) and return the batch logits.  Blocking;
    /// called from the worker thread that constructed the executor.
    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput>;
    /// Can this executor run the given capacity tier?  The engine
    /// probes every configured tier at worker startup, so a ladder
    /// mismatch between `ServeConfig` and the factory aborts at init
    /// with a clear error instead of failing per-batch mid-run.
    fn supports(&self, _tier: f32) -> bool {
        true
    }
    /// backend name for reports/logs
    fn name(&self) -> &'static str {
        "executor"
    }
}

/// PJRT-backed executor over the static-capacity `serve_cap*` artifacts.
/// Owns its own [`Runtime`] (and therefore its own PJRT client and
/// non-`Send` handles), so each worker thread loads one via
/// [`XlaExecutor::load`] inside the engine's executor factory.
#[cfg(feature = "pjrt")]
pub struct XlaExecutor {
    rt: Runtime,
    /// (capacity, entry name) ladder, mirrors `ServeConfig::tiers`
    tiers: Vec<(f32, String)>,
    /// params/router literals prepared once — the frozen multi-MB vectors
    /// are NOT re-copied per batch (EXPERIMENTS.md §Perf, L3 iteration 1).
    params_lit: xla::Literal,
    router_lit: xla::Literal,
    batch: usize,
    seq_len: usize,
}

#[cfg(feature = "pjrt")]
impl XlaExecutor {
    /// Load the artifact set for `config` and pre-compile every tier
    /// entry: admission must never pay compile latency.
    pub fn load(artifacts_dir: &str, config: &str, params: &[f32],
                router: &[f32], tiers: &[(f32, String)])
                -> Result<XlaExecutor> {
        let rt = Runtime::load(artifacts_dir, config)?;
        XlaExecutor::from_runtime(rt, params, router, tiers)
    }

    /// Wrap an already-loaded runtime (takes ownership: the runtime's
    /// handles must stay on the constructing thread).
    pub fn from_runtime(rt: Runtime, params: &[f32], router: &[f32],
                        tiers: &[(f32, String)]) -> Result<XlaExecutor> {
        anyhow::ensure!(!tiers.is_empty(), "no serving tiers configured");
        let entries: Vec<&str> =
            tiers.iter().map(|(_, e)| e.as_str()).collect();
        rt.warmup(&entries)?;
        let entry0 = &tiers[0].1;
        let params_lit = rt.prepare_arg(entry0, 0, &Arg::F32(params))?;
        let router_lit = rt.prepare_arg(entry0, 1, &Arg::F32(router))?;
        Ok(XlaExecutor {
            batch: rt.manifest.batch(),
            seq_len: rt.manifest.seq_len(),
            rt,
            tiers: tiers.to_vec(),
            params_lit,
            router_lit,
        })
    }

    /// Executor factory for [`super::ElasticEngine::start`]: each worker
    /// thread loads its own runtime (and PJRT client) over the same
    /// artifact set and parameter vectors.
    pub fn factory(artifacts_dir: String, config: String, params: Vec<f32>,
                   router: Vec<f32>, tiers: Vec<(f32, String)>)
                   -> impl Fn(usize) -> Result<Box<dyn Executor>>
                       + Send + Sync + 'static {
        move |_worker| {
            Ok(Box::new(XlaExecutor::load(&artifacts_dir, &config, &params,
                                          &router, &tiers)?)
                as Box<dyn Executor>)
        }
    }

    fn entry_for(&self, tier: f32) -> Result<&str> {
        self.tiers
            .iter()
            .find(|(c, _)| tier_matches(*c, tier))
            .map(|(_, e)| e.as_str())
            .ok_or_else(|| anyhow::anyhow!(
                "tier {tier} not in configured ladder {:?}",
                self.tiers.iter().map(|(c, _)| *c).collect::<Vec<_>>()))
    }
}

#[cfg(feature = "pjrt")]
impl Executor for XlaExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput> {
        let entry = self.entry_for(tier)?;
        let tokens_lit = self.rt.prepare_arg(entry, 2, &Arg::I32(tokens))?;
        let out = self.rt.exec_prepared(
            entry, &[&self.params_lit, &self.router_lit, &tokens_lit])?;
        Ok(ExecOutput { logits: out.f32(0)? })
    }

    fn supports(&self, tier: f32) -> bool {
        self.entry_for(tier).is_ok()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// The worker loop: pop a run of *class-compatible* admitted requests
/// (the tightest-slack available head seeds the run — deadline-aware
/// stealing — own shard winning ties, siblings drained when it runs
/// dry), shed the ones whose deadline already expired, pick a tier from
/// the global backlog plus the batch's SLO constraints via **this
/// worker class's own** capacity controller, form the padded batch,
/// execute, and resolve each request's [`super::Response`] with its
/// logits row and timings.  Returns the number of batches executed;
/// exits when the queue is closed and drained.
///
/// Batch compatibility is [`batch_key`]: every popped run shares one
/// floor rung and one deadline band, so a quality floor never drags
/// best-effort neighbours up a tier and a tight deadline never drags
/// relaxed neighbours down one (the strictest constraint in a batch
/// binds all of it — so batches are formed to agree on constraints).
///
/// All timings are measured on one monotonic clock: `submitted` (the
/// admission stamp) -> `exec_start` (stamped immediately before the
/// backend call, so host-side batch formation bills as queue time, not
/// exec time) -> `done`.  `queue_ms + exec_ms == total_ms` exactly, and
/// neither can go negative on fast completions.
pub(crate) fn run_worker(shared: &EngineShared, worker: usize,
                         class_idx: usize, exec: &mut dyn Executor)
                         -> Result<usize> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let class_name = shared.classes[class_idx].0.clone();
    let controller = &shared.controllers[class_idx];
    let mut batches = 0usize;
    loop {
        let popped = shared.queue.pop_batch_keyed(
            worker, batch, shared.max_batch_wait,
            |p: &Pending| batch_key(&p.req.slo, &shared.caps),
            // steal priority: remaining deadline budget in ms (may have
            // gone negative — an expired request is the most urgent of
            // all: it is shed below, freeing its queue slot and
            // resolving its Response promptly)
            |p: &Pending| match p.req.slo.deadline {
                None => f64::INFINITY,
                Some(d) => {
                    d.as_secs_f64() * 1e3
                        - p.submitted.elapsed().as_secs_f64() * 1e3
                }
            });
        if popped.is_empty() {
            return Ok(batches); // closed and drained
        }
        // shed expired deadlines before spending any compute on them,
        // and collect the survivors' SLO constraints for the controller
        let now = Instant::now();
        let mut live: Vec<Pending> = Vec::with_capacity(popped.len());
        let mut expired: Vec<ShedRecord> = Vec::new();
        let mut floor = 0.0f32;
        let mut slack_ms: Option<f64> = None;
        for p in popped {
            let waited = now.saturating_duration_since(p.submitted);
            if let Some(deadline) = p.req.slo.deadline {
                if waited >= deadline {
                    expired.push(ShedRecord {
                        id: p.req.id,
                        class: p.req.slo.name.clone(),
                        worker_class: class_name.clone(),
                    });
                    p.responder.fulfil(Err(ServeError::DeadlineExceeded));
                    continue;
                }
                let s = (deadline - waited).as_secs_f64() * 1e3;
                slack_ms = Some(match slack_ms {
                    Some(prev) => prev.min(s),
                    None => s,
                });
            }
            floor = floor.max(p.req.slo.floor_tier);
            live.push(p);
        }
        if !expired.is_empty() {
            // one lock for the whole run's sheds, mirroring the
            // one-lock-per-batch completions path below
            shared.sheds.lock().unwrap().append(&mut expired);
        }
        if live.is_empty() {
            continue; // the whole run was past-deadline
        }
        // this class's controller sees the global post-pop backlog (one
        // atomic load off the sharded queue's depth gauge — no queue
        // lock) plus this batch's tightest deadline slack and strictest
        // quality floor; the floor is the max over a run that already
        // shares one floor rung, so the clamp binds every member alike
        let tier = controller.lock().unwrap().choose_for_batch(
            shared.queue.len(), floor, slack_ms);
        // split each Pending into its request (consumed by form_batch)
        // and its response half; form_batch preserves order, so the two
        // vectors stay aligned
        let mut meta = Vec::with_capacity(live.len());
        let mut reqs = Vec::with_capacity(live.len());
        for p in live {
            meta.push((p.submitted, p.responder));
            reqs.push(p.req);
        }
        let formed = form_batch(reqs, batch, seq_len);
        // stamped after batch formation, immediately before the backend
        // call: the documented clock is admission -> exec start -> done,
        // and host-side formation is queue time, not exec time
        let exec_start = Instant::now();
        let out = match exec.execute(tier, &formed.tokens) {
            Ok(out) => out,
            Err(e) => {
                let msg = format!(
                    "{} worker {worker}: tier {tier} batch of {}: {e:#}",
                    exec.name(), formed.requests.len());
                for (_, responder) in meta {
                    responder
                        .fulfil(Err(ServeError::ExecFailed(msg.clone())));
                }
                return Err(e.context(format!(
                    "{} worker {worker}: tier {tier} batch of {}",
                    exec.name(), formed.requests.len())));
            }
        };
        let done = Instant::now();
        let exec_ms = done
            .saturating_duration_since(exec_start)
            .as_secs_f64() * 1e3;
        // feed the latency model of THIS class only: a slow backend's
        // timings never pollute a fast class's deadline decisions
        controller.lock().unwrap().observe_exec(tier, exec_ms);
        // the executor contract is one equal-size logits row per batch
        // slot (padded rows included); a violating backend must surface
        // as an error, not as silently truncated rows handed to callers
        if out.logits.len() % batch != 0 {
            let msg = format!(
                "{} worker {worker}: executor returned {} logits, not a \
                 multiple of batch {batch}",
                exec.name(), out.logits.len());
            for (_, responder) in meta {
                responder.fulfil(Err(ServeError::ExecFailed(msg.clone())));
            }
            return Err(anyhow::anyhow!(msg));
        }
        let n = formed.requests.len();
        let row_len = out.logits.len() / batch;
        let mut batch_completions = Vec::with_capacity(n);
        for (i, (req, (submitted, responder))) in
            formed.requests.into_iter().zip(meta).enumerate()
        {
            let queue_ms = exec_start
                .saturating_duration_since(submitted)
                .as_secs_f64() * 1e3;
            let completion = Completion {
                id: req.id,
                class: req.slo.name.clone(),
                tier,
                worker,
                worker_class: class_name.clone(),
                queue_ms,
                exec_ms,
                total_ms: queue_ms + exec_ms,
                batch_size: n,
            };
            batch_completions.push(completion.clone());
            let logits =
                out.logits[i * row_len..(i + 1) * row_len].to_vec();
            responder.fulfil(Ok(Reply { completion, logits }));
        }
        // one lock for the whole batch, not one per request
        shared.completions.lock().unwrap().extend(batch_completions);
        batches += 1;
    }
}
