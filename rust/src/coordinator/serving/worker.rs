//! The `Executor` trait — "warm up and execute one batch at a capacity
//! tier" — plus the PJRT implementor and the worker loop that drives any
//! implementor from the shared admission queue.
//!
//! PJRT handles are not `Send`, so executors never cross threads: the
//! engine calls its factory *on* each worker thread and the boxed
//! executor lives and dies there.  The worker loop itself is
//! backend-agnostic, which is what lets `tests/serving_sim.rs` exercise
//! the full admission → batch → tier-select → execute → complete path
//! through [`super::SimExecutor`] with no artifacts on disk.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::form_batch;
use super::controller::CapacityController;
use super::queue::AdmissionQueue;
use super::report::Completion;
use super::tier_matches;
use crate::runtime::client::Arg;
use crate::runtime::Runtime;

/// A serving backend: owns whatever compiled/warmed state one worker
/// needs and executes one fixed-shape batch at a given capacity tier.
pub trait Executor {
    /// static batch dimension of the compiled executables
    fn batch(&self) -> usize;
    /// static sequence length of the compiled executables
    fn seq_len(&self) -> usize;
    /// Run one `batch() * seq_len()` token tensor at `tier` (one of the
    /// configured capacities).  Blocking; called from the worker thread
    /// that constructed the executor.
    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<()>;
    /// Can this executor run the given capacity tier?  The engine
    /// probes every configured tier at worker startup, so a ladder
    /// mismatch between `ServeConfig` and the factory aborts at init
    /// with a clear error instead of failing per-batch mid-run.
    fn supports(&self, _tier: f32) -> bool {
        true
    }
    /// backend name for reports/logs
    fn name(&self) -> &'static str {
        "executor"
    }
}

/// PJRT-backed executor over the static-capacity `serve_cap*` artifacts.
/// Owns its own [`Runtime`] (and therefore its own PJRT client and
/// non-`Send` handles), so each worker thread loads one via
/// [`XlaExecutor::load`] inside the engine's executor factory.
pub struct XlaExecutor {
    rt: Runtime,
    /// (capacity, entry name) ladder, mirrors `ServeConfig::tiers`
    tiers: Vec<(f32, String)>,
    /// params/router literals prepared once — the frozen multi-MB vectors
    /// are NOT re-copied per batch (EXPERIMENTS.md §Perf, L3 iteration 1).
    params_lit: xla::Literal,
    router_lit: xla::Literal,
    batch: usize,
    seq_len: usize,
}

impl XlaExecutor {
    /// Load the artifact set for `config` and pre-compile every tier
    /// entry: admission must never pay compile latency.
    pub fn load(artifacts_dir: &str, config: &str, params: &[f32],
                router: &[f32], tiers: &[(f32, String)])
                -> Result<XlaExecutor> {
        let rt = Runtime::load(artifacts_dir, config)?;
        XlaExecutor::from_runtime(rt, params, router, tiers)
    }

    /// Wrap an already-loaded runtime (takes ownership: the runtime's
    /// handles must stay on the constructing thread).
    pub fn from_runtime(rt: Runtime, params: &[f32], router: &[f32],
                        tiers: &[(f32, String)]) -> Result<XlaExecutor> {
        anyhow::ensure!(!tiers.is_empty(), "no serving tiers configured");
        let entries: Vec<&str> =
            tiers.iter().map(|(_, e)| e.as_str()).collect();
        rt.warmup(&entries)?;
        let entry0 = &tiers[0].1;
        let params_lit = rt.prepare_arg(entry0, 0, &Arg::F32(params))?;
        let router_lit = rt.prepare_arg(entry0, 1, &Arg::F32(router))?;
        Ok(XlaExecutor {
            batch: rt.manifest.batch(),
            seq_len: rt.manifest.seq_len(),
            rt,
            tiers: tiers.to_vec(),
            params_lit,
            router_lit,
        })
    }

    /// Executor factory for [`super::ElasticServer::run`]: each worker
    /// thread loads its own runtime (and PJRT client) over the same
    /// artifact set and parameter vectors.
    pub fn factory(artifacts_dir: String, config: String, params: Vec<f32>,
                   router: Vec<f32>, tiers: Vec<(f32, String)>)
                   -> impl Fn(usize) -> Result<Box<dyn Executor>> + Sync {
        move |_worker| {
            Ok(Box::new(XlaExecutor::load(&artifacts_dir, &config, &params,
                                          &router, &tiers)?)
                as Box<dyn Executor>)
        }
    }

    fn entry_for(&self, tier: f32) -> Result<&str> {
        self.tiers
            .iter()
            .find(|(c, _)| tier_matches(*c, tier))
            .map(|(_, e)| e.as_str())
            .ok_or_else(|| anyhow::anyhow!(
                "tier {tier} not in configured ladder {:?}",
                self.tiers.iter().map(|(c, _)| *c).collect::<Vec<_>>()))
    }
}

impl Executor for XlaExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<()> {
        let entry = self.entry_for(tier)?;
        let tokens_lit = self.rt.prepare_arg(entry, 2, &Arg::I32(tokens))?;
        let out = self.rt.exec_prepared(
            entry, &[&self.params_lit, &self.router_lit, &tokens_lit])?;
        let _logits = out.f32(0)?; // delivered to callers in a real API
        Ok(())
    }

    fn supports(&self, tier: f32) -> bool {
        self.entry_for(tier).is_ok()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Shared engine state one worker borrows for its lifetime.
pub(crate) struct WorkerShared<'a> {
    pub queue: &'a AdmissionQueue,
    pub controller: &'a Mutex<CapacityController>,
    pub completions: &'a Mutex<Vec<Completion>>,
    pub max_batch_wait: Duration,
}

/// The worker loop: pop a FIFO run of requests, pick a tier from the
/// global backlog, form the padded batch, execute, record completions.
/// Returns the number of batches executed; exits when the queue is
/// closed and drained.
pub(crate) fn run_worker(shared: &WorkerShared<'_>, worker: usize,
                         exec: &mut dyn Executor) -> Result<usize> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let mut batches = 0usize;
    loop {
        let reqs = shared.queue.pop_batch(batch, shared.max_batch_wait);
        if reqs.is_empty() {
            return Ok(batches); // closed and drained
        }
        // the controller sees the global post-pop backlog, so all
        // workers shed capacity together under sustained load
        let tier =
            shared.controller.lock().unwrap().choose(shared.queue.len());
        let exec_start = Instant::now();
        let formed = form_batch(reqs, batch, seq_len);
        exec.execute(tier, &formed.tokens).with_context(|| {
            format!("{} worker {worker}: tier {tier} batch of {}",
                    exec.name(), formed.requests.len())
        })?;
        let done = Instant::now();
        let n = formed.requests.len();
        let mut out = shared.completions.lock().unwrap();
        for r in formed.requests {
            out.push(Completion {
                id: r.id,
                tier,
                worker,
                queue_ms: (exec_start - r.submitted).as_secs_f64() * 1e3,
                total_ms: (done - r.submitted).as_secs_f64() * 1e3,
                batch_size: n,
            });
        }
        drop(out);
        batches += 1;
    }
}
