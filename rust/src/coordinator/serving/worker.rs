//! The `Executor` trait — "warm up and execute one batch at a capacity
//! tier, returning its logits" — plus the PJRT implementor and the
//! worker loop that drives any implementor from the shared admission
//! queue and routes every completion back to its requester.
//!
//! PJRT handles are not `Send`, so executors never cross threads: the
//! engine calls its factory *on* each worker thread and the boxed
//! executor lives and dies there.  The worker loop itself is
//! backend-agnostic, which is what lets `tests/serving_sim.rs` exercise
//! the full submit → admit → batch → tier-select → execute → resolve
//! path through [`super::SimExecutor`] with no artifacts on disk.

use std::time::Instant;

use anyhow::Result;

use super::batcher::{batch_key_for, form_rows, StepKind};
use super::report::{Completion, ShedCause, ShedRecord, StreamShedRecord};
use super::stream::{spec, Advance};
use super::{EngineShared, Outcome, Pending, Reply, ServeError};

#[cfg(feature = "pjrt")]
use super::tier_matches;
#[cfg(feature = "pjrt")]
use crate::runtime::client::Arg;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

/// One executed batch's output: the flattened logits for every row of
/// the batch (real and padded rows alike).  `logits.len()` must be a
/// multiple of the executor's `batch()` so the worker can slice out
/// each request's row for its [`super::Reply`].
#[derive(Debug, Clone)]
pub struct ExecOutput {
    pub logits: Vec<f32>,
}

/// A serving backend: owns whatever compiled/warmed state one worker
/// needs and executes one fixed-shape batch at a given capacity tier.
pub trait Executor {
    /// static batch dimension of the compiled executables
    fn batch(&self) -> usize;
    /// static sequence length of the compiled executables
    fn seq_len(&self) -> usize;
    /// Run one `batch() * seq_len()` token tensor at `tier` (one of the
    /// configured capacities) and return the batch logits.  Blocking;
    /// called from the worker thread that constructed the executor.
    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput>;
    /// Can this executor run the given capacity tier?  The engine
    /// probes every configured tier at worker startup, so a ladder
    /// mismatch between `ServeConfig` and the factory aborts at init
    /// with a clear error instead of failing per-batch mid-run.
    fn supports(&self, _tier: f32) -> bool {
        true
    }
    /// Cost hint for the *next* `execute` call: how many of its rows
    /// carry a full-window recompute (one-shot prefills, decode cache
    /// misses) vs a cached incremental window from the session arena.
    /// Real backends ignore it (the work is whatever the tensors
    /// hold); the sim backend uses it to model the KV-cache saving —
    /// a cached row costs O(1) in window length, a recompute row
    /// O(seq_len) — so the bench record shows the hit path beating
    /// the recompute path on modeled cost.
    fn note_batch_mix(&mut self, _recompute_rows: usize,
                      _cached_rows: usize) {
    }
    /// backend name for reports/logs
    fn name(&self) -> &'static str {
        "executor"
    }
}

/// PJRT-backed executor over the static-capacity `serve_cap*` artifacts.
/// Owns its own [`Runtime`] (and therefore its own PJRT client and
/// non-`Send` handles), so each worker thread loads one via
/// [`XlaExecutor::load`] inside the engine's executor factory.
#[cfg(feature = "pjrt")]
pub struct XlaExecutor {
    rt: Runtime,
    /// (capacity, entry name) ladder, mirrors `ServeConfig::tiers`
    tiers: Vec<(f32, String)>,
    /// params/router literals prepared once — the frozen multi-MB vectors
    /// are NOT re-copied per batch (EXPERIMENTS.md §Perf, L3 iteration 1).
    params_lit: xla::Literal,
    router_lit: xla::Literal,
    batch: usize,
    seq_len: usize,
}

#[cfg(feature = "pjrt")]
impl XlaExecutor {
    /// Load the artifact set for `config` and pre-compile every tier
    /// entry: admission must never pay compile latency.
    pub fn load(artifacts_dir: &str, config: &str, params: &[f32],
                router: &[f32], tiers: &[(f32, String)])
                -> Result<XlaExecutor> {
        let rt = Runtime::load(artifacts_dir, config)?;
        XlaExecutor::from_runtime(rt, params, router, tiers)
    }

    /// Wrap an already-loaded runtime (takes ownership: the runtime's
    /// handles must stay on the constructing thread).
    pub fn from_runtime(rt: Runtime, params: &[f32], router: &[f32],
                        tiers: &[(f32, String)]) -> Result<XlaExecutor> {
        anyhow::ensure!(!tiers.is_empty(), "no serving tiers configured");
        let entries: Vec<&str> =
            tiers.iter().map(|(_, e)| e.as_str()).collect();
        rt.warmup(&entries)?;
        let entry0 = &tiers[0].1;
        let params_lit = rt.prepare_arg(entry0, 0, &Arg::F32(params))?;
        let router_lit = rt.prepare_arg(entry0, 1, &Arg::F32(router))?;
        Ok(XlaExecutor {
            batch: rt.manifest.batch(),
            seq_len: rt.manifest.seq_len(),
            rt,
            tiers: tiers.to_vec(),
            params_lit,
            router_lit,
        })
    }

    /// Executor factory for [`super::ElasticEngine::start`]: each worker
    /// thread loads its own runtime (and PJRT client) over the same
    /// artifact set and parameter vectors.
    pub fn factory(artifacts_dir: String, config: String, params: Vec<f32>,
                   router: Vec<f32>, tiers: Vec<(f32, String)>)
                   -> impl Fn(usize) -> Result<Box<dyn Executor>>
                       + Send + Sync + 'static {
        move |_worker| {
            Ok(Box::new(XlaExecutor::load(&artifacts_dir, &config, &params,
                                          &router, &tiers)?)
                as Box<dyn Executor>)
        }
    }

    fn entry_for(&self, tier: f32) -> Result<&str> {
        self.tiers
            .iter()
            .find(|(c, _)| tier_matches(*c, tier))
            .map(|(_, e)| e.as_str())
            .ok_or_else(|| anyhow::anyhow!(
                "tier {tier} not in configured ladder {:?}",
                self.tiers.iter().map(|(c, _)| *c).collect::<Vec<_>>()))
    }
}

#[cfg(feature = "pjrt")]
impl Executor for XlaExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput> {
        let entry = self.entry_for(tier)?;
        let tokens_lit = self.rt.prepare_arg(entry, 2, &Arg::I32(tokens))?;
        let out = self.rt.exec_prepared(
            entry, &[&self.params_lit, &self.router_lit, &tokens_lit])?;
        Ok(ExecOutput { logits: out.f32(0)? })
    }

    fn supports(&self, tier: f32) -> bool {
        self.entry_for(tier).is_ok()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Greedy sampling: the argmax index of one logits row.  Real vocab
/// heads yield a token id; the sim backend's single-logit rows yield 0.
/// Shared with the speculative runners in `stream::spec`, so draft,
/// verify and plain decode all sample identically — the acceptance
/// test is exact token equality.
pub(crate) fn sample_token(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Terminate every item of a failing batch: one-shots resolve to
/// `ExecFailed`, decode sessions are shed through the session table
/// (their stream's terminal event) and logged to the engine's
/// stream-shed record under one lock.
pub(crate) fn fail_batch(shared: &EngineShared, items: Vec<Pending>,
                         msg: &str, class_name: &str) {
    let mut recs: Vec<StreamShedRecord> = Vec::new();
    for p in items {
        match p.outcome {
            Outcome::OneShot(responder) => responder
                .fulfil(Err(ServeError::ExecFailed(msg.to_string()))),
            Outcome::Stream(st) => {
                if let Some(rec) = shared.sessions.shed(
                    st.session,
                    ServeError::ExecFailed(msg.to_string()),
                    class_name)
                {
                    recs.push(rec);
                }
                shared.recycle_session(st.session);
            }
        }
    }
    if !recs.is_empty() {
        shared.stream_shed.lock().unwrap().append(&mut recs);
    }
}

/// The worker loop: pop a run of *class-compatible* admitted work items
/// (the tightest-slack available head seeds the run — deadline-aware
/// stealing — own shard winning ties, siblings drained when it runs
/// dry), shed the ones whose deadline already expired, pick a tier from
/// the global backlog plus the run's SLO constraints via **this worker
/// class's own** capacity controller, form the padded batch, execute,
/// and route each item's result: a one-shot request's [`super::Response`]
/// resolves with its logits row and timings; a decode step streams its
/// sampled token to the session's client and is turned by the
/// [`super::stream::SessionTable`] into either a **re-admission** of
/// the session's next step (continuous batching) or the session's
/// terminal `Done`.  Returns the number of batches executed; exits when
/// the queue is closed and drained.
///
/// Batch compatibility is [`batch_key_for`]: every popped run shares
/// one step kind (prefill vs decode — the two workloads never mix in a
/// batch), one floor rung and one deadline band, so a quality floor
/// never drags best-effort neighbours up a tier and a tight deadline
/// never drags relaxed neighbours down one (the strictest constraint
/// in a batch binds all of it — so batches are formed to agree on
/// constraints).
///
/// Deadline clocks differ per workload: a one-shot's budget runs from
/// its admission stamp; a decode session's budget runs from *session*
/// admission, and the slack fed to the controller is the remaining
/// budget **divided by the steps left** — the session's per-step
/// allowance — so a session degrades tiers gradually as budget burns
/// instead of riding the top tier into a cliff-edge shed.
///
/// All timings are measured on one monotonic clock: `submitted` (the
/// admission stamp) -> `exec_start` (stamped immediately before the
/// backend call, so host-side batch formation bills as queue time, not
/// exec time) -> `done`.  `queue_ms + exec_ms == total_ms` exactly, and
/// neither can go negative on fast completions.
pub(crate) fn run_worker(shared: &EngineShared, worker: usize,
                         class_idx: usize, exec: &mut dyn Executor)
                         -> Result<usize> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let class_name = shared.classes[class_idx].0.clone();
    let controller = &shared.controllers[class_idx];
    let arena = &shared.arenas[class_idx];
    let mut batches = 0usize;
    loop {
        let popped = shared.queue.pop_batch_keyed_affine(
            worker, batch, shared.max_batch_wait,
            |p: &Pending| {
                batch_key_for(p.kind(), &p.req.slo, &shared.caps)
            },
            // steal priority: remaining deadline budget in ms, per
            // step for decode sessions (may have gone negative — an
            // expired item is the most urgent of all: it is shed
            // below, freeing its queue slot and resolving its client
            // promptly)
            |p: &Pending| p.slack_ms_at(Instant::now())
                .unwrap_or(f64::INFINITY),
            // affinity: a decode continuation is pinned to its
            // session's shard, where the arena pages live.  Prefills
            // (step 0) have no cached state yet, and one-shots never
            // do — no affinity, exactly the old steal cost.
            |p: &Pending| match &p.outcome {
                Outcome::Stream(st) if st.step > 0 => Some(st.shard),
                _ => None,
            });
        if popped.is_empty() {
            return Ok(batches); // closed and drained
        }
        // shed expired deadlines before spending any compute on them,
        // and collect the survivors' SLO constraints for the controller
        let now = Instant::now();
        let mut live: Vec<Pending> = Vec::with_capacity(popped.len());
        let mut expired: Vec<ShedRecord> = Vec::new();
        let mut stream_sheds: Vec<StreamShedRecord> = Vec::new();
        let mut floor = 0.0f32;
        let mut slack_ms: Option<f64> = None;
        for p in popped {
            if p.deadline_expired_at(now) {
                match p.outcome {
                    Outcome::OneShot(responder) => {
                        expired.push(ShedRecord {
                            id: p.req.id,
                            class: p.req.slo.name.clone(),
                            worker_class: class_name.clone(),
                            cause: ShedCause::DeadlineExceeded,
                        });
                        responder
                            .fulfil(Err(ServeError::DeadlineExceeded));
                    }
                    Outcome::Stream(st) => {
                        if let Some(rec) = shared.sessions.shed(
                            st.session, ServeError::DeadlineExceeded,
                            &class_name)
                        {
                            stream_sheds.push(rec);
                        }
                        shared.recycle_session(st.session);
                    }
                }
                continue;
            }
            if let Some(s) = p.slack_ms_at(now) {
                slack_ms = Some(match slack_ms {
                    Some(prev) => prev.min(s),
                    None => s,
                });
            }
            floor = floor.max(p.req.slo.floor_tier);
            live.push(p);
        }
        if !expired.is_empty() {
            // one lock for the whole run's sheds, mirroring the
            // one-lock-per-batch completions path below
            shared.sheds.lock().unwrap().append(&mut expired);
        }
        if !stream_sheds.is_empty() {
            shared.stream_shed.lock().unwrap().append(&mut stream_sheds);
        }
        if live.is_empty() {
            continue; // the whole run was past-deadline
        }
        // speculative step shapes run through their own runners: a
        // draft batch is k cheap micro-steps over the same rows, a
        // verify batch packs k+1 rows per session — neither fits the
        // one-row-per-item path below.  The batch key guarantees the
        // popped run is homogeneous in kind, so the head decides.
        match live[0].kind() {
            StepKind::Draft => {
                batches += spec::run_draft_batch(
                    shared, worker, class_idx, &class_name, exec,
                    floor, live)?;
                continue;
            }
            StepKind::Verify => {
                batches += spec::run_verify_batch(
                    shared, worker, class_idx, &class_name, exec,
                    live)?;
                continue;
            }
            StepKind::Prefill | StepKind::Decode => {}
        }
        // this class's controller sees the global post-pop backlog (one
        // atomic load off the sharded queue's depth gauge — no queue
        // lock) plus this batch's tightest deadline slack (per-step for
        // decode) and strictest quality floor; the floor is the max
        // over a run that already shares one floor rung, so the clamp
        // binds every member alike.  Decode steps get this decision
        // FRESH every step — per-step elastic compute.
        let tier = controller.lock().unwrap().choose_for_batch(
            shared.queue.len(), floor, slack_ms);
        // build each item's compute row: a one-shot's row is its
        // request tokens, a decode step's is served from this class's
        // arena when a live page matches the step (the incremental hit
        // path — no table locks, no window rebuild) and recomputed
        // from the session table otherwise (cold start, spilled page,
        // or a step stolen across classes); `items` and `rows` stay
        // aligned
        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(live.len());
        let mut items: Vec<Pending> = Vec::with_capacity(live.len());
        let mut cached_rows = 0usize;
        for mut p in live {
            match &p.outcome {
                Outcome::OneShot(_) => {
                    rows.push(std::mem::take(&mut p.req.tokens));
                }
                Outcome::Stream(st) => {
                    let hit = if st.step > 0 {
                        arena.lookup(st.session, st.step)
                    } else {
                        None // prefill: nothing cached yet
                    };
                    match hit {
                        Some(row) => {
                            cached_rows += 1;
                            rows.push(row);
                        }
                        None => match shared.sessions
                            .compute_row(st.session, seq_len)
                        {
                            Some(row) => rows.push(row),
                            // session already terminated: drop the
                            // stale step (its stream got its terminal
                            // elsewhere)
                            None => continue,
                        },
                    }
                }
            }
            items.push(p);
        }
        if items.is_empty() {
            continue;
        }
        let row_refs: Vec<&[i32]> =
            rows.iter().map(|r| r.as_slice()).collect();
        let tokens = form_rows(&row_refs, batch, seq_len);
        drop(row_refs);
        exec.note_batch_mix(items.len() - cached_rows, cached_rows);
        // stamped after batch formation, immediately before the backend
        // call: the documented clock is admission -> exec start -> done,
        // and host-side formation is queue time, not exec time
        let exec_start = Instant::now();
        let out = match exec.execute(tier, &tokens) {
            Ok(out) => out,
            Err(e) => {
                let msg = format!(
                    "{} worker {worker}: tier {tier} batch of {}: {e:#}",
                    exec.name(), items.len());
                let n = items.len();
                fail_batch(shared, items, &msg, &class_name);
                return Err(e.context(format!(
                    "{} worker {worker}: tier {tier} batch of {n}",
                    exec.name())));
            }
        };
        let done = Instant::now();
        let exec_ms = done
            .saturating_duration_since(exec_start)
            .as_secs_f64() * 1e3;
        // feed the latency model of THIS class only: a slow backend's
        // timings never pollute a fast class's deadline decisions
        controller.lock().unwrap().observe_exec(tier, exec_ms);
        // the executor contract is one equal-size logits row per batch
        // slot (padded rows included); a violating backend must surface
        // as an error, not as silently truncated rows handed to callers
        if out.logits.len() % batch != 0 {
            let msg = format!(
                "{} worker {worker}: executor returned {} logits, not a \
                 multiple of batch {batch}",
                exec.name(), out.logits.len());
            fail_batch(shared, items, &msg, &class_name);
            return Err(anyhow::anyhow!(msg));
        }
        let n = items.len();
        let row_len = out.logits.len() / batch;
        let mut batch_completions = Vec::with_capacity(n);
        let mut stream_done = Vec::new();
        let mut stream_sheds: Vec<StreamShedRecord> = Vec::new();
        for (i, p) in items.into_iter().enumerate() {
            let row = &out.logits[i * row_len..(i + 1) * row_len];
            match p.outcome {
                Outcome::OneShot(responder) => {
                    let queue_ms = exec_start
                        .saturating_duration_since(p.submitted)
                        .as_secs_f64() * 1e3;
                    let completion = Completion {
                        id: p.req.id,
                        class: p.req.slo.name.clone(),
                        tier,
                        worker,
                        worker_class: class_name.clone(),
                        queue_ms,
                        exec_ms,
                        total_ms: queue_ms + exec_ms,
                        batch_size: n,
                    };
                    batch_completions.push(completion.clone());
                    responder.fulfil(Ok(Reply {
                        completion,
                        logits: row.to_vec(),
                    }));
                }
                Outcome::Stream(st) => {
                    // sample the step's token, stream it, and let the
                    // session table turn the completed step into a
                    // re-admission or the session's terminal
                    let token = sample_token(row);
                    match shared.sessions.advance(&st, token, tier, done)
                    {
                        Advance::Requeue(next) => {
                            // deposit the session's *next* window into
                            // this class's arena before the step
                            // becomes visible to any worker: append
                            // the sampled token to the window we just
                            // executed and slide it — the incremental
                            // update the recompute path exists to
                            // avoid
                            let mut win = std::mem::take(&mut rows[i]);
                            win.push(token);
                            if win.len() > seq_len {
                                let cut = win.len() - seq_len;
                                win.drain(..cut);
                            }
                            arena.store(st.session, st.step + 1, win);
                            let urgent =
                                next.req.slo.deadline.is_some();
                            if let Err(stale) =
                                shared.queue.requeue_to(
                                    st.shard, next, urgent)
                            {
                                // queue closed mid-decode: terminate
                                // the session now, not at a step that
                                // will never run
                                if let Outcome::Stream(st) =
                                    stale.outcome
                                {
                                    if let Some(rec) =
                                        shared.sessions.shed(
                                            st.session,
                                            ServeError::ShuttingDown,
                                            &class_name)
                                    {
                                        stream_sheds.push(rec);
                                    }
                                    shared.recycle_session(st.session);
                                }
                            }
                        }
                        Advance::Done(stats) => {
                            shared.recycle_session(st.session);
                            stream_done.push(stats);
                        }
                        // terminated concurrently: whoever shed it
                        // already recycled; a second recycle is a
                        // guaranteed no-op either way
                        Advance::Gone => {
                            shared.recycle_session(st.session);
                        }
                    }
                }
            }
        }
        // one lock per log for the whole batch, not one per item
        if !batch_completions.is_empty() {
            shared.completions.lock().unwrap().extend(batch_completions);
        }
        if !stream_done.is_empty() {
            shared.stream_done.lock().unwrap().append(&mut stream_done);
        }
        if !stream_sheds.is_empty() {
            shared.stream_shed.lock().unwrap().append(&mut stream_sheds);
        }
        batches += 1;
    }
}
