//! The `Executor` trait — "warm up and execute one batch at a capacity
//! tier, returning its logits" — plus the PJRT implementor and the
//! worker loop that drives any implementor from the shared admission
//! queue and routes every completion back to its requester.
//!
//! PJRT handles are not `Send`, so executors never cross threads: the
//! engine calls its factory *on* each worker thread and the boxed
//! executor lives and dies there.  The worker loop itself is
//! backend-agnostic, which is what lets `tests/serving_sim.rs` exercise
//! the full submit → admit → batch → tier-select → execute → resolve
//! path through [`super::SimExecutor`] with no artifacts on disk.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{batch_key_for, floor_rung, form_rows, StepKind};
use super::controller::BreakerState;
use super::report::{Completion, ShedCause, ShedRecord, StreamShedRecord};
use super::stream::{spec, Advance};
use super::{EngineShared, FatalExecError, Outcome, Pending, Reply,
            ServeError};

#[cfg(feature = "pjrt")]
use super::tier_matches;
#[cfg(feature = "pjrt")]
use crate::runtime::client::Arg;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;

/// One executed batch's output: the flattened logits for every row of
/// the batch (real and padded rows alike).  `logits.len()` must be a
/// multiple of the executor's `batch()` so the worker can slice out
/// each request's row for its [`super::Reply`].
#[derive(Debug, Clone)]
pub struct ExecOutput {
    pub logits: Vec<f32>,
}

/// A serving backend: owns whatever compiled/warmed state one worker
/// needs and executes one fixed-shape batch at a given capacity tier.
pub trait Executor {
    /// static batch dimension of the compiled executables
    fn batch(&self) -> usize;
    /// static sequence length of the compiled executables
    fn seq_len(&self) -> usize;
    /// Run one `batch() * seq_len()` token tensor at `tier` (one of the
    /// configured capacities) and return the batch logits.  Blocking;
    /// called from the worker thread that constructed the executor.
    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput>;
    /// Can this executor run the given capacity tier?  The engine
    /// probes every configured tier at worker startup, so a ladder
    /// mismatch between `ServeConfig` and the factory aborts at init
    /// with a clear error instead of failing per-batch mid-run.
    fn supports(&self, _tier: f32) -> bool {
        true
    }
    /// Cost hint for the *next* `execute` call: how many of its rows
    /// carry a full-window recompute (one-shot prefills, decode cache
    /// misses) vs a cached incremental window from the session arena.
    /// Real backends ignore it (the work is whatever the tensors
    /// hold); the sim backend uses it to model the KV-cache saving —
    /// a cached row costs O(1) in window length, a recompute row
    /// O(seq_len) — so the bench record shows the hit path beating
    /// the recompute path on modeled cost.
    fn note_batch_mix(&mut self, _recompute_rows: usize,
                      _cached_rows: usize) {
    }
    /// backend name for reports/logs
    fn name(&self) -> &'static str {
        "executor"
    }
}

/// PJRT-backed executor over the static-capacity `serve_cap*` artifacts.
/// Owns its own [`Runtime`] (and therefore its own PJRT client and
/// non-`Send` handles), so each worker thread loads one via
/// [`XlaExecutor::load`] inside the engine's executor factory.
#[cfg(feature = "pjrt")]
pub struct XlaExecutor {
    rt: Runtime,
    /// (capacity, entry name) ladder, mirrors `ServeConfig::tiers`
    tiers: Vec<(f32, String)>,
    /// params/router literals prepared once — the frozen multi-MB vectors
    /// are NOT re-copied per batch (EXPERIMENTS.md §Perf, L3 iteration 1).
    params_lit: xla::Literal,
    router_lit: xla::Literal,
    batch: usize,
    seq_len: usize,
}

#[cfg(feature = "pjrt")]
impl XlaExecutor {
    /// Load the artifact set for `config` and pre-compile every tier
    /// entry: admission must never pay compile latency.
    pub fn load(artifacts_dir: &str, config: &str, params: &[f32],
                router: &[f32], tiers: &[(f32, String)])
                -> Result<XlaExecutor> {
        let rt = Runtime::load(artifacts_dir, config)?;
        XlaExecutor::from_runtime(rt, params, router, tiers)
    }

    /// Wrap an already-loaded runtime (takes ownership: the runtime's
    /// handles must stay on the constructing thread).
    pub fn from_runtime(rt: Runtime, params: &[f32], router: &[f32],
                        tiers: &[(f32, String)]) -> Result<XlaExecutor> {
        anyhow::ensure!(!tiers.is_empty(), "no serving tiers configured");
        let entries: Vec<&str> =
            tiers.iter().map(|(_, e)| e.as_str()).collect();
        rt.warmup(&entries)?;
        let entry0 = &tiers[0].1;
        let params_lit = rt.prepare_arg(entry0, 0, &Arg::F32(params))?;
        let router_lit = rt.prepare_arg(entry0, 1, &Arg::F32(router))?;
        Ok(XlaExecutor {
            batch: rt.manifest.batch(),
            seq_len: rt.manifest.seq_len(),
            rt,
            tiers: tiers.to_vec(),
            params_lit,
            router_lit,
        })
    }

    /// Executor factory for [`super::ElasticEngine::start`]: each worker
    /// thread loads its own runtime (and PJRT client) over the same
    /// artifact set and parameter vectors.
    pub fn factory(artifacts_dir: String, config: String, params: Vec<f32>,
                   router: Vec<f32>, tiers: Vec<(f32, String)>)
                   -> impl Fn(usize) -> Result<Box<dyn Executor>>
                       + Send + Sync + 'static {
        move |_worker| {
            Ok(Box::new(XlaExecutor::load(&artifacts_dir, &config, &params,
                                          &router, &tiers)?)
                as Box<dyn Executor>)
        }
    }

    fn entry_for(&self, tier: f32) -> Result<&str> {
        self.tiers
            .iter()
            .find(|(c, _)| tier_matches(*c, tier))
            .map(|(_, e)| e.as_str())
            .ok_or_else(|| anyhow::anyhow!(
                "tier {tier} not in configured ladder {:?}",
                self.tiers.iter().map(|(c, _)| *c).collect::<Vec<_>>()))
    }
}

#[cfg(feature = "pjrt")]
impl Executor for XlaExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput> {
        let entry = self.entry_for(tier)?;
        let tokens_lit = self.rt.prepare_arg(entry, 2, &Arg::I32(tokens))?;
        let out = self.rt.exec_prepared(
            entry, &[&self.params_lit, &self.router_lit, &tokens_lit])?;
        Ok(ExecOutput { logits: out.f32(0)? })
    }

    fn supports(&self, tier: f32) -> bool {
        self.entry_for(tier).is_ok()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Greedy sampling: the argmax index of one logits row.  Real vocab
/// heads yield a token id; the sim backend's single-logit rows yield 0.
/// Shared with the speculative runners in `stream::spec`, so draft,
/// verify and plain decode all sample identically — the acceptance
/// test is exact token equality.
pub(crate) fn sample_token(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Terminate every item of a failing batch: one-shots resolve to
/// `ExecFailed`, decode sessions are shed through the session table
/// (their stream's terminal event) and logged to the engine's
/// stream-shed record under one lock.  `lane` is the dying worker's
/// trace lane: every item it takes down gets its balancing `Terminal`
/// here, so the admit/terminal ledger reconciles even through a fleet
/// that exhausted its restart budget.
pub(crate) fn fail_batch(shared: &EngineShared, items: Vec<Pending>,
                         msg: &str, class_name: &str, lane: usize) {
    let trace = shared.trace.as_deref();
    let mut recs: Vec<StreamShedRecord> = Vec::new();
    for p in items {
        match p.outcome {
            Outcome::OneShot(responder) => {
                responder.fulfil(
                    Err(ServeError::ExecFailed(msg.to_string())));
                if let Some(t) = trace {
                    t.terminal(lane, p.trace_id, "exec-failed");
                }
            }
            Outcome::Stream(st) => {
                if let Some(rec) = shared.sessions.shed(
                    st.session,
                    ServeError::ExecFailed(msg.to_string()),
                    class_name)
                {
                    recs.push(rec);
                    // only the shed that won the race owns the
                    // session's terminal event
                    if let Some(t) = trace {
                        t.terminal(lane, p.trace_id, "exec-failed");
                    }
                }
                shared.recycle_session(st.session);
            }
        }
    }
    if !recs.is_empty() {
        shared.stream_shed.lock().append(&mut recs);
    }
}

/// A FATAL worker fault: the executor (or the backend under it) is in
/// an unknown state — a panic escaped `execute`, or the error chain
/// carried a [`FatalExecError`] marker.  The worker loop hands its
/// in-flight items back to the engine's supervision loop, which
/// rebuilds the executor through the class factory (restart budget
/// permitting) and requeues the batch.
pub(crate) struct WorkerFault {
    pub msg: String,
    pub inflight: Vec<Pending>,
}

/// One classified `Executor::execute` attempt.
enum ExecTry {
    Ok(ExecOutput),
    /// retryable: the executor survives (I/O hiccup, transient backend
    /// error) — the batch can be retried on the same executor
    Transient(String),
    /// NOT retryable: a panic crossed the call, or the backend tagged
    /// the error fatal — the executor must be torn down and rebuilt
    Fatal(String),
}

/// Call the executor once and classify the outcome.  Panics are caught
/// here (the executor is behind `&mut`, hence `AssertUnwindSafe`: a
/// Fatal verdict means the executor is discarded, never reused, so
/// broken invariants cannot leak into a later call).
fn call_exec(exec: &mut dyn Executor, tier: f32, tokens: &[i32])
             -> ExecTry {
    match std::panic::catch_unwind(
        AssertUnwindSafe(|| exec.execute(tier, tokens)))
    {
        Ok(Ok(out)) => ExecTry::Ok(out),
        Ok(Err(e)) => {
            let fatal = e.chain().any(
                |c| c.downcast_ref::<FatalExecError>().is_some());
            let msg = format!("{e:#}");
            if fatal {
                ExecTry::Fatal(msg)
            } else {
                ExecTry::Transient(msg)
            }
        }
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".into());
            ExecTry::Fatal(format!("executor panicked: {msg}"))
        }
    }
}

/// What the retry → bisect → quarantine ladder decided for one *unit*
/// (a one-shot request, one decode/draft step row, or one session's
/// packed verify rows).
pub(crate) enum UnitFate {
    /// executed: one logits row per input row of the unit
    Served(Vec<Vec<f32>>),
    /// still failing alone after every retry — the poison; shed it
    /// with the final failure message, everyone else survives
    Poisoned(String),
}

/// Execute `units` (each unit = the rows that must live or die
/// together) through the fault ladder at `tier`:
///
/// 1. **retry** — transient failures retry in place with bounded
///    exponential backoff (`FaultPolicy::{max_retries, backoff_ms}`);
/// 2. **bisect** — a span still failing after retries splits in half
///    and each half retries independently, so one bad unit cannot
///    take innocent co-batched neighbours down with it;
/// 3. **quarantine** — a *singleton* span that still fails is the
///    poison: its fate is `Poisoned` and the ladder moves on.
///
/// Returns the per-unit fates (aligned with `units`) plus whether any
/// transient failure was observed (feeds the class breaker), or
/// `Err(msg)` on a FATAL fault — executor state is unknown, the caller
/// must escalate to supervision with the batch intact.
pub(crate) fn execute_quarantine(shared: &EngineShared, class_idx: usize,
                                 worker: usize, exec: &mut dyn Executor,
                                 tier: f32, units: &[Vec<Vec<i32>>])
                                 -> Result<(Vec<UnitFate>, bool), String> {
    let mut fates: Vec<Option<UnitFate>> =
        (0..units.len()).map(|_| None).collect();
    let failed = exec_span(shared, class_idx, worker, exec, tier, units,
                           0, units.len(), &mut fates)?;
    Ok((fates
            .into_iter()
            .map(|f| f.expect("ladder assigns every unit a fate"))
            .collect(),
        failed))
}

/// One rung of the ladder: retry `units[lo..hi]` as a single batch,
/// then bisect or quarantine.  Recursion depth is log2(batch) — a
/// handful of frames for any real batch dimension.
#[allow(clippy::too_many_arguments)]
fn exec_span(shared: &EngineShared, class_idx: usize, worker: usize,
             exec: &mut dyn Executor, tier: f32,
             units: &[Vec<Vec<i32>>], lo: usize, hi: usize,
             fates: &mut [Option<UnitFate>]) -> Result<bool, String> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let policy = shared.policy;
    let faults = &shared.faults[class_idx];
    let trace = shared.trace.as_deref();
    let rows: Vec<&[i32]> = units[lo..hi]
        .iter()
        .flat_map(|u| u.iter().map(|r| r.as_slice()))
        .collect();
    let tokens = form_rows(&rows, batch, seq_len);
    drop(rows);
    let mut failed = false;
    let mut last_msg = String::new();
    for attempt in 0..=policy.max_retries {
        if attempt > 0 {
            // Relaxed fault counters throughout this ladder: pure
            // statistics, read by report assembly after the joins
            faults.retries.fetch_add(1, Ordering::Relaxed);
            if let Some(t) = trace {
                t.retry(worker, attempt);
            }
            // bounded exponential backoff: the shift saturates at 64x
            // so a large max_retries cannot overflow into a sleep of
            // centuries
            let backoff =
                policy.backoff_ms * (1u64 << (attempt - 1).min(6));
            if backoff > 0 {
                std::thread::sleep(Duration::from_millis(backoff));
            }
        }
        let exec_start = Instant::now();
        // paired around the backend call itself — success, transient
        // failure and fatal fault all close their span, so the Chrome
        // exec track shows retries as distinct back-to-back slices
        if let Some(t) = trace {
            t.exec_start(worker, tier, class_idx);
        }
        let verdict = call_exec(exec, tier, &tokens);
        if let Some(t) = trace {
            t.exec_end(worker, tier, class_idx);
        }
        match verdict {
            ExecTry::Ok(out) => {
                // the executor contract is one equal-size logits row
                // per batch slot; a violating backend is retried like
                // any transient fault (and quarantined if persistent)
                if out.logits.is_empty() || out.logits.len() % batch != 0
                {
                    failed = true;
                    last_msg = format!(
                        "{} returned {} logits, not a multiple of \
                         batch {batch}",
                        exec.name(), out.logits.len());
                    continue;
                }
                // only successful attempts feed the latency model:
                // fault spikes are breaker business, not tier business
                let exec_ms = exec_start.elapsed().as_secs_f64() * 1e3;
                shared.controllers[class_idx]
                    .lock()
                    .observe_exec(tier, exec_ms);
                let row_len = out.logits.len() / batch;
                let mut r = 0usize;
                for (ui, unit) in units[lo..hi].iter().enumerate() {
                    let mut unit_rows = Vec::with_capacity(unit.len());
                    for _ in 0..unit.len() {
                        unit_rows.push(
                            out.logits[r * row_len..(r + 1) * row_len]
                                .to_vec());
                        r += 1;
                    }
                    fates[lo + ui] = Some(UnitFate::Served(unit_rows));
                }
                return Ok(failed);
            }
            ExecTry::Transient(msg) => {
                failed = true;
                last_msg = msg;
            }
            ExecTry::Fatal(msg) => return Err(msg),
        }
    }
    // retries exhausted on this span: bisect if it can still be split,
    // quarantine the singleton otherwise
    if hi - lo >= 2 {
        faults.splits.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = trace {
            t.bisect(worker);
        }
        let mid = lo + (hi - lo) / 2;
        exec_span(shared, class_idx, worker, exec, tier, units, lo, mid,
                  fates)?;
        exec_span(shared, class_idx, worker, exec, tier, units, mid, hi,
                  fates)?;
    } else {
        faults.poisoned.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = trace {
            t.poisoned(worker);
        }
        fates[lo] = Some(UnitFate::Poisoned(last_msg));
    }
    Ok(true)
}

/// The worker loop: pop a run of *class-compatible* admitted work items
/// (the tightest-slack available head seeds the run — deadline-aware
/// stealing — own shard winning ties, siblings drained when it runs
/// dry), shed the ones whose deadline already expired, pick a tier from
/// the global backlog plus the run's SLO constraints via **this worker
/// class's own** capacity controller, form the padded batch, execute,
/// and route each item's result: a one-shot request's [`super::Response`]
/// resolves with its logits row and timings; a decode step streams its
/// sampled token to the session's client and is turned by the
/// [`super::stream::SessionTable`] into either a **re-admission** of
/// the session's next step (continuous batching) or the session's
/// terminal `Done`.  Returns the number of batches executed; exits when
/// the queue is closed and drained.
///
/// Batch compatibility is [`batch_key_for`]: every popped run shares
/// one step kind (prefill vs decode — the two workloads never mix in a
/// batch), one floor rung and one deadline band, so a quality floor
/// never drags best-effort neighbours up a tier and a tight deadline
/// never drags relaxed neighbours down one (the strictest constraint
/// in a batch binds all of it — so batches are formed to agree on
/// constraints).
///
/// Deadline clocks differ per workload: a one-shot's budget runs from
/// its admission stamp; a decode session's budget runs from *session*
/// admission, and the slack fed to the controller is the remaining
/// budget **divided by the steps left** — the session's per-step
/// allowance — so a session degrades tiers gradually as budget burns
/// instead of riding the top tier into a cliff-edge shed.
///
/// All timings are measured on one monotonic clock: `submitted` (the
/// admission stamp) -> `exec_start` (stamped immediately before the
/// backend call, so host-side batch formation bills as queue time, not
/// exec time) -> `done`.  `queue_ms + exec_ms == total_ms` exactly, and
/// neither can go negative on fast completions.
///
/// Faults never exit this loop quietly: transient execute failures run
/// the retry → bisect → quarantine ladder in place
/// ([`execute_quarantine`]), and only a FATAL fault (panic or
/// [`FatalExecError`]) returns — as `Err(WorkerFault)` carrying the
/// in-flight batch, so the engine's supervision loop can rebuild the
/// executor and requeue the work.  `Ok` means the queue closed and
/// drained.
pub(crate) fn run_worker(shared: &EngineShared, worker: usize,
                         class_idx: usize, exec: &mut dyn Executor)
                         -> Result<usize, WorkerFault> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let class_name = shared.classes[class_idx].0.clone();
    let controller = &shared.controllers[class_idx];
    let arena = &shared.arenas[class_idx];
    let trace = shared.trace.as_deref();
    let live_stats = &shared.live[class_idx];
    let mut batches = 0usize;
    loop {
        // one breaker tick per pop cycle: an Open class backs off the
        // shared queue briefly (healthy classes win the steal race for
        // its would-be batches) and serves whatever it does pop in
        // brownout — at the cheapest floored tier — instead of
        // shedding; Half-open probes at the normally-chosen tier so
        // recovery is actually tested at real quality
        let (breaker, flip) = controller.lock().breaker_tick_noting();
        if let (Some(t), Some((from, to))) = (trace, flip) {
            t.breaker_transition(worker, class_idx, from, to);
        }
        if breaker == BreakerState::Open {
            std::thread::sleep(Duration::from_millis(1));
        }
        let (popped, stolen) = shared.queue.pop_batch_keyed_affine_counting(
            worker, batch, shared.max_batch_wait,
            |p: &Pending| {
                batch_key_for(p.kind(), &p.req.slo, &shared.caps)
            },
            // steal priority: remaining deadline budget in ms, per
            // step for decode sessions (may have gone negative — an
            // expired item is the most urgent of all: it is shed
            // below, freeing its queue slot and resolving its client
            // promptly)
            |p: &Pending| p.slack_ms_at(Instant::now())
                .unwrap_or(f64::INFINITY),
            // affinity: a decode continuation is pinned to its
            // session's shard, where the arena pages live.  Prefills
            // (step 0) have no cached state yet, and one-shots never
            // do — no affinity, exactly the old steal cost.
            |p: &Pending| match &p.outcome {
                Outcome::Stream(st) if st.step > 0 => Some(st.shard),
                _ => None,
            });
        if popped.is_empty() {
            return Ok(batches); // closed and drained
        }
        if let Some(t) = trace {
            if stolen > 0 {
                t.steal(worker, stolen);
            }
            // the popped run is homogeneous by construction, so the
            // head's key names the whole batch; the format! only runs
            // with tracing on
            let key = batch_key_for(popped[0].kind(),
                                    &popped[0].req.slo, &shared.caps);
            t.batch_formed(worker, format!("{key:?}"), popped.len());
        }
        // shed expired deadlines before spending any compute on them,
        // and collect the survivors' SLO constraints for the controller
        let now = Instant::now();
        let mut live: Vec<Pending> = Vec::with_capacity(popped.len());
        let mut expired: Vec<ShedRecord> = Vec::new();
        let mut stream_sheds: Vec<StreamShedRecord> = Vec::new();
        let mut floor = 0.0f32;
        let mut slack_ms: Option<f64> = None;
        for p in popped {
            if p.deadline_expired_at(now) {
                match p.outcome {
                    Outcome::OneShot(responder) => {
                        expired.push(ShedRecord {
                            id: p.req.id,
                            class: p.req.slo.name.clone(),
                            worker_class: class_name.clone(),
                            cause: ShedCause::DeadlineExceeded,
                        });
                        live_stats.record_shed();
                        if let Some(t) = trace {
                            t.terminal(worker, p.trace_id,
                                       "shed-deadline");
                        }
                        responder
                            .fulfil(Err(ServeError::DeadlineExceeded));
                    }
                    Outcome::Stream(st) => {
                        if let Some(rec) = shared.sessions.shed(
                            st.session, ServeError::DeadlineExceeded,
                            &class_name)
                        {
                            stream_sheds.push(rec);
                            if let Some(t) = trace {
                                t.terminal(worker, p.trace_id,
                                           "shed-deadline");
                            }
                        }
                        shared.recycle_session(st.session);
                    }
                }
                continue;
            }
            if let Some(s) = p.slack_ms_at(now) {
                slack_ms = Some(match slack_ms {
                    Some(prev) => prev.min(s),
                    None => s,
                });
            }
            floor = floor.max(p.req.slo.floor_tier);
            live.push(p);
        }
        if !expired.is_empty() {
            // one lock for the whole run's sheds, mirroring the
            // one-lock-per-batch completions path below
            shared.sheds.lock().append(&mut expired);
        }
        if !stream_sheds.is_empty() {
            shared.stream_shed.lock().append(&mut stream_sheds);
        }
        if live.is_empty() {
            continue; // the whole run was past-deadline
        }
        // speculative step shapes run through their own runners: a
        // draft batch is k cheap micro-steps over the same rows, a
        // verify batch packs k+1 rows per session — neither fits the
        // one-row-per-item path below.  The batch key guarantees the
        // popped run is homogeneous in kind, so the head decides.
        match live[0].kind() {
            StepKind::Draft => {
                batches += spec::run_draft_batch(
                    shared, worker, class_idx, &class_name, exec,
                    floor, live)?;
                continue;
            }
            StepKind::Verify => {
                batches += spec::run_verify_batch(
                    shared, worker, class_idx, &class_name, exec,
                    live)?;
                continue;
            }
            StepKind::Prefill | StepKind::Decode => {}
        }
        // this class's controller sees the global post-pop backlog (one
        // atomic load off the sharded queue's depth gauge — no queue
        // lock) plus this batch's tightest deadline slack (per-step for
        // decode) and strictest quality floor; the floor is the max
        // over a run that already shares one floor rung, so the clamp
        // binds every member alike.  Decode steps get this decision
        // FRESH every step — per-step elastic compute.  An Open
        // breaker overrides the choice with brownout: the cheapest
        // rung the batch's quality floor allows.
        let tier = if breaker == BreakerState::Open {
            shared.caps[floor_rung(&shared.caps, floor)]
        } else {
            controller.lock().choose_for_batch(
                shared.queue.len(), floor, slack_ms)
        };
        // build each item's compute row: a one-shot's row is its
        // request tokens, a decode step's is served from this class's
        // arena when a live page matches the step (the incremental hit
        // path — no table locks, no window rebuild) and recomputed
        // from the session table otherwise (cold start, spilled page,
        // or a step stolen across classes); `items` and `rows` stay
        // aligned
        let mut rows: Vec<Vec<i32>> = Vec::with_capacity(live.len());
        let mut items: Vec<Pending> = Vec::with_capacity(live.len());
        let mut cached_rows = 0usize;
        for mut p in live {
            match &p.outcome {
                Outcome::OneShot(_) => {
                    rows.push(std::mem::take(&mut p.req.tokens));
                }
                Outcome::Stream(st) => {
                    let hit = if st.step > 0 {
                        arena.lookup(st.session, st.step)
                    } else {
                        None // prefill: nothing cached yet
                    };
                    match hit {
                        Some(row) => {
                            cached_rows += 1;
                            if let Some(t) = trace {
                                t.arena_hit(worker, p.trace_id);
                            }
                            rows.push(row);
                        }
                        None => {
                            // a prefill expects nothing cached, so
                            // only step >= 1 counts as a miss
                            if st.step > 0 {
                                if let Some(t) = trace {
                                    t.arena_miss(worker, p.trace_id);
                                }
                            }
                            match shared.sessions
                                .compute_row(st.session, seq_len)
                            {
                                Some(row) => rows.push(row),
                                // session already terminated: drop the
                                // stale step (its stream got its
                                // terminal elsewhere)
                                None => continue,
                            }
                        }
                    }
                }
            }
            items.push(p);
        }
        if items.is_empty() {
            continue;
        }
        // quarantine granularity on this path is one ROW: each unit is
        // a single one-shot request or a single decode step, so the
        // bisect ladder can isolate exactly one poison item
        let mut units: Vec<Vec<Vec<i32>>> =
            rows.into_iter().map(|r| vec![r]).collect();
        exec.note_batch_mix(items.len() - cached_rows, cached_rows);
        // stamped after batch formation, immediately before the backend
        // call: the documented clock is admission -> exec start -> done,
        // and host-side formation is queue time, not exec time (the
        // ladder's retries and backoff DO bill as exec time — the
        // client waited on them)
        let exec_start = Instant::now();
        let (fates, any_fail) = match execute_quarantine(
            shared, class_idx, worker, exec, tier, &units)
        {
            Ok(ok) => ok,
            Err(fatal) => {
                // FATAL: executor state unknown.  Hand the batch back
                // intact — one-shot tokens restored (they were moved
                // into the unit rows above) — so supervision can
                // rebuild the executor and requeue the work; nothing
                // here has been resolved yet, so the requeue cannot
                // double-deliver.
                controller.lock().observe_batch_outcome(false);
                let mut inflight = items;
                for (i, p) in inflight.iter_mut().enumerate() {
                    if matches!(p.outcome, Outcome::OneShot(_)) {
                        p.req.tokens = std::mem::take(&mut units[i][0]);
                    }
                }
                let n = inflight.len();
                return Err(WorkerFault {
                    msg: format!(
                        "{} worker {worker}: tier {tier} batch of {n}: \
                         {fatal}",
                        exec.name()),
                    inflight,
                });
            }
        };
        // the breaker judges whole-batch health: any transient fault in
        // the ladder counts one failed observation for this class
        controller.lock().observe_batch_outcome(!any_fail);
        let done = Instant::now();
        let exec_ms = done
            .saturating_duration_since(exec_start)
            .as_secs_f64() * 1e3;
        let n = items.len();
        let mut batch_completions = Vec::with_capacity(n);
        let mut poison_sheds: Vec<ShedRecord> = Vec::new();
        let mut stream_done = Vec::new();
        let mut stream_sheds: Vec<StreamShedRecord> = Vec::new();
        for (i, (p, fate)) in items.into_iter().zip(fates).enumerate() {
            let unit_rows = match fate {
                UnitFate::Served(unit_rows) => unit_rows,
                UnitFate::Poisoned(msg) => {
                    // the quarantined unit resolves with an explicit
                    // Poisoned verdict — its co-batched neighbours
                    // resolve normally below
                    match p.outcome {
                        Outcome::OneShot(responder) => {
                            poison_sheds.push(ShedRecord {
                                id: p.req.id,
                                class: p.req.slo.name.clone(),
                                worker_class: class_name.clone(),
                                cause: ShedCause::Poisoned,
                            });
                            live_stats.record_shed();
                            if let Some(t) = trace {
                                t.terminal(worker, p.trace_id,
                                           "shed-poisoned");
                            }
                            responder
                                .fulfil(Err(ServeError::Poisoned(msg)));
                        }
                        Outcome::Stream(st) => {
                            if let Some(rec) = shared.sessions.shed(
                                st.session, ServeError::Poisoned(msg),
                                &class_name)
                            {
                                stream_sheds.push(rec);
                                if let Some(t) = trace {
                                    t.terminal(worker, p.trace_id,
                                               "shed-poisoned");
                                }
                            }
                            shared.recycle_session(st.session);
                        }
                    }
                    continue;
                }
            };
            let row = &unit_rows[0];
            match p.outcome {
                Outcome::OneShot(responder) => {
                    let queue_ms = exec_start
                        .saturating_duration_since(p.submitted)
                        .as_secs_f64() * 1e3;
                    let completion = Completion {
                        id: p.req.id,
                        class: p.req.slo.name.clone(),
                        tier,
                        worker,
                        worker_class: class_name.clone(),
                        queue_ms,
                        exec_ms,
                        total_ms: queue_ms + exec_ms,
                        batch_size: n,
                    };
                    batch_completions.push(completion.clone());
                    // live stats and the terminal event land BEFORE
                    // the client's future resolves: a snapshot taken
                    // after `wait()` returns is guaranteed to count
                    // this request
                    live_stats.record_served(completion.total_ms);
                    if let Some(t) = trace {
                        t.terminal(worker, p.trace_id, "served");
                    }
                    responder.fulfil(Ok(Reply {
                        completion,
                        logits: row.to_vec(),
                    }));
                }
                Outcome::Stream(st) => {
                    // sample the step's token, stream it, and let the
                    // session table turn the completed step into a
                    // re-admission or the session's terminal
                    let token = sample_token(row);
                    match shared.sessions.advance(&st, token, tier, done)
                    {
                        Advance::Requeue(next) => {
                            // deposit the session's *next* window into
                            // this class's arena before the step
                            // becomes visible to any worker: append
                            // the sampled token to the window we just
                            // executed and slide it — the incremental
                            // update the recompute path exists to
                            // avoid
                            let mut win =
                                std::mem::take(&mut units[i][0]);
                            win.push(token);
                            if win.len() > seq_len {
                                let cut = win.len() - seq_len;
                                win.drain(..cut);
                            }
                            let evicted =
                                arena.store(st.session, st.step + 1, win);
                            if let (Some(t), Some(victim)) =
                                (trace, evicted)
                            {
                                t.arena_evict(worker, victim);
                            }
                            let urgent =
                                next.req.slo.deadline.is_some();
                            match shared.queue.requeue_to(
                                st.shard, next, urgent)
                            {
                                Ok(_) => {
                                    if let Some(t) = trace {
                                        t.requeue(worker, p.trace_id);
                                    }
                                }
                                Err(stale) => {
                                    // queue closed mid-decode:
                                    // terminate the session now, not
                                    // at a step that will never run
                                    if let Outcome::Stream(st) =
                                        stale.outcome
                                    {
                                        if let Some(rec) =
                                            shared.sessions.shed(
                                                st.session,
                                                ServeError::ShuttingDown,
                                                &class_name)
                                        {
                                            stream_sheds.push(rec);
                                            if let Some(t) = trace {
                                                t.terminal(
                                                    worker, p.trace_id,
                                                    "shed-shutdown");
                                            }
                                        }
                                        shared
                                            .recycle_session(st.session);
                                    }
                                }
                            }
                        }
                        Advance::Done(stats) => {
                            shared.recycle_session(st.session);
                            if let Some(t) = trace {
                                t.terminal(worker, p.trace_id,
                                           "stream-done");
                            }
                            stream_done.push(stats);
                        }
                        // terminated concurrently: whoever shed it
                        // already recycled; a second recycle is a
                        // guaranteed no-op either way
                        Advance::Gone => {
                            shared.recycle_session(st.session);
                        }
                    }
                }
            }
        }
        // one lock per log for the whole batch, not one per item
        if !batch_completions.is_empty() {
            shared.completions.lock().extend(batch_completions);
        }
        if !poison_sheds.is_empty() {
            shared.sheds.lock().append(&mut poison_sheds);
        }
        if !stream_done.is_empty() {
            shared.stream_done.lock().append(&mut stream_done);
        }
        if !stream_sheds.is_empty() {
            shared.stream_shed.lock().append(&mut stream_sheds);
        }
        batches += 1;
    }
}
