//! Flight recorder: per-worker ring buffers of typed request-lifecycle
//! events, live engine snapshots, and Chrome-trace export.
//!
//! The engine's only lens used to be the end-of-run `ServeReport`;
//! this module adds the *during*-the-run view.  Three pieces:
//!
//!  * [`TraceRecorder`] — one fixed-capacity event lane per worker
//!    plus one engine lane for client-thread events (submit/admission/
//!    shutdown-drain).  Every event is a [`Stamped`] [`TraceEvent`]
//!    carrying a µs tick from the engine's exec clock and the request/
//!    session `trace_id` (0 for batch-scoped events).  A full lane
//!    drops its **oldest** event and counts the drop exactly, so
//!    `dropped + exported == emitted` always reconciles (property-
//!    tested under panicking fleets and mid-run shutdown).
//!  * [`EngineSnapshot`] / [`ClassSnapshot`] — the live mid-run
//!    counters/gauges/log2-bucket latency histograms that
//!    `EngineHandle::snapshot()` returns; before this module *all*
//!    numbers were shutdown-only.
//!  * [`trace_export::chrome_json`] — Chrome `trace_event` JSON:
//!    workers as tids with complete ("X") spans from ExecStart/End
//!    pairs, one complete span per request from its Admit/Terminal
//!    pair, and instant ("i") events for sheds/retries/breaker flips.
//!    Open the file at `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! Concurrency discipline: lanes are `RankedMutex<VecDeque<…>>` at
//! [`Rank::TraceRing`], the strictly-last rank in `sync.rs`'s table —
//! emission is legal while holding *any* other serving lock, and
//! nothing is ever acquired under a lane lock.  The exact-count
//! ledgers are `Relaxed` atomics (independent monotone event counts;
//! see the per-file allowlist in `lint.rs`).  The disabled recorder is
//! simply `None` in the engine's `Option<Arc<TraceRecorder>>` — every
//! emission site is one branch, no allocation, no lock, and no
//! trace-id counter is consumed, so a `trace_capacity == 0` run
//! replays a seeded sim bit-identically to the untraced build.
//!
//! Event construction is confined to this module's emission API
//! (`invariant-lint` rule `trace-confined`): call sites can never
//! build a `TraceEvent` themselves and bypass the drop-counting path.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::controller::BreakerState;
use crate::json::Value;
use crate::metrics::Log2Hist;
use crate::sync::{Rank, RankedMutex};

/// One typed point in a request's (or batch's, or worker's) lifecycle.
///
/// Constructed ONLY by [`TraceRecorder`]'s emission methods — the
/// `trace-confined` lint rule fails CI on any `TraceEvent::` token
/// outside this file.  Consumers match via [`Stamped::kind`] and the
/// public fields of the drained events.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// a request/session entered the engine (trace_id allocated)
    Admit,
    /// admission placed the request on a queue shard
    Place { shard: usize },
    /// a popped batch took `rows` items from shards other than the
    /// popping worker's own
    Steal { rows: usize },
    /// a worker formed a batch of `rows` compatible items
    BatchFormed { key: String, rows: usize },
    /// one executor call begins (per attempt, so retries re-emit)
    ExecStart { tier: f32, class: usize },
    /// the matching executor call returned
    ExecEnd { tier: f32, class: usize },
    /// the fault ladder retried a transient span failure
    Retry { attempt: usize },
    /// the fault ladder bisected a still-failing span
    Bisect,
    /// a singleton unit failed last-resort and was quarantined
    Poisoned,
    /// the supervisor rebuilt a worker's executor
    Respawn { class: usize },
    /// a class circuit breaker changed state
    BreakerTransition {
        class: usize,
        from: &'static str,
        to: &'static str,
    },
    /// a speculative draft batch ran `rows` session rows
    DraftRound { rows: usize },
    /// one session's verify pass resolved
    VerifyResolve { accepted: usize, rejected: usize },
    /// decode-step window served from the session arena
    ArenaHit,
    /// decode-step window recomputed (arena miss or disabled)
    ArenaMiss,
    /// storing a window evicted the LRU victim session
    ArenaEvict { victim: u64 },
    /// a continuation/in-flight item went back into the queue
    Requeue,
    /// the request/session resolved — exactly one per Admit
    Terminal { cause: &'static str },
}

/// A [`TraceEvent`] stamped with its lane, µs tick and trace id.
#[derive(Debug, Clone)]
pub struct Stamped {
    /// µs since engine start, from the same monotonic clock that
    /// stamps the report's queue/exec timings
    pub tick_us: u64,
    /// request/session id threaded through `Pending`/`DecodeSession`;
    /// 0 for batch- or worker-scoped events
    pub trace_id: u64,
    /// worker index, or [`TraceRecorder::engine_lane`] for
    /// client-thread events
    pub lane: usize,
    pub event: TraceEvent,
}

impl Stamped {
    /// Stable kebab-case label of the event type — what consumers
    /// outside this module match on (building `TraceEvent::` patterns
    /// elsewhere is a lint violation by design).
    pub fn kind(&self) -> &'static str {
        match self.event {
            TraceEvent::Admit => "admit",
            TraceEvent::Place { .. } => "place",
            TraceEvent::Steal { .. } => "steal",
            TraceEvent::BatchFormed { .. } => "batch-formed",
            TraceEvent::ExecStart { .. } => "exec-start",
            TraceEvent::ExecEnd { .. } => "exec-end",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::Bisect => "bisect",
            TraceEvent::Poisoned => "poisoned",
            TraceEvent::Respawn { .. } => "respawn",
            TraceEvent::BreakerTransition { .. } => "breaker-transition",
            TraceEvent::DraftRound { .. } => "draft-round",
            TraceEvent::VerifyResolve { .. } => "verify-resolve",
            TraceEvent::ArenaHit => "arena-hit",
            TraceEvent::ArenaMiss => "arena-miss",
            TraceEvent::ArenaEvict { .. } => "arena-evict",
            TraceEvent::Requeue => "requeue",
            TraceEvent::Terminal { .. } => "terminal",
        }
    }

    /// The `cause` of a terminal event, if this is one.
    pub fn terminal_cause(&self) -> Option<&'static str> {
        match self.event {
            TraceEvent::Terminal { cause } => Some(cause),
            _ => None,
        }
    }

    /// `(accepted, rejected)` of a verify resolution, if this is one.
    pub fn verify_counts(&self) -> Option<(usize, usize)> {
        match self.event {
            TraceEvent::VerifyResolve { accepted, rejected } => {
                Some((accepted, rejected))
            }
            _ => None,
        }
    }
}

/// Exact event ledger: `dropped + exported == emitted` once every
/// lane has been drained, no matter how the run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCounts {
    pub emitted: u64,
    pub dropped: u64,
    pub exported: u64,
}

/// The flight recorder.  See the module docs for the discipline; the
/// short version: emission methods only, one per event type, each a
/// single lane-lock push with exact overflow accounting.
pub struct TraceRecorder {
    start: Instant,
    capacity: usize,
    /// worker-class names, indexed by the `class` field of events
    classes: Vec<String>,
    /// lanes `0..workers` belong to workers; the last is the engine's
    lanes: Vec<RankedMutex<VecDeque<Stamped>>>,
    // Relaxed throughout: independent monotone counters — the ledger
    // invariant is evaluated only after threads are joined/drained
    emitted: AtomicU64,
    dropped: AtomicU64,
    exported: AtomicU64,
    next_trace_id: AtomicU64,
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRecorder")
            .field("capacity", &self.capacity)
            .field("lanes", &self.lanes.len())
            .field("counts", &self.counts())
            .finish()
    }
}

impl TraceRecorder {
    /// `capacity` events per lane (> 0 — a zero capacity means "no
    /// recorder at all": the engine keeps `None` instead), one lane
    /// per worker plus the trailing engine lane.
    pub fn new(capacity: usize, workers: usize, classes: Vec<String>,
               start: Instant) -> TraceRecorder {
        assert!(capacity > 0,
                "trace_capacity 0 disables tracing; build no recorder");
        TraceRecorder {
            start,
            capacity,
            classes,
            lanes: (0..workers + 1)
                .map(|_| {
                    RankedMutex::new(Rank::TraceRing,
                                     VecDeque::with_capacity(capacity))
                })
                .collect(),
            emitted: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            exported: AtomicU64::new(0),
            next_trace_id: AtomicU64::new(0),
        }
    }

    /// The lane client threads (submit/try_submit/shutdown) stamp.
    pub fn engine_lane(&self) -> usize {
        self.lanes.len() - 1
    }

    /// Worker-class names, indexed by event `class` fields.
    pub fn classes(&self) -> &[String] {
        &self.classes
    }

    /// Allocate the next request/session trace id (starts at 1; 0 is
    /// the "untraced" stamp a disabled engine writes).
    pub fn alloc_trace_id(&self) -> u64 {
        self.next_trace_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// µs since engine start on the exec clock.
    pub fn tick_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    fn push(&self, lane: usize, trace_id: u64, event: TraceEvent) {
        let stamped = Stamped {
            tick_us: self.tick_us(),
            trace_id,
            lane,
            event,
        };
        let mut ring = self.lanes[lane].lock();
        if ring.len() == self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(stamped);
        drop(ring);
        self.emitted.fetch_add(1, Ordering::Relaxed);
    }

    // --- emission API: one method per event type ----------------------

    pub fn admit(&self, lane: usize, trace_id: u64) {
        self.push(lane, trace_id, TraceEvent::Admit);
    }

    pub fn place(&self, lane: usize, trace_id: u64, shard: usize) {
        self.push(lane, trace_id, TraceEvent::Place { shard });
    }

    pub fn steal(&self, lane: usize, rows: usize) {
        self.push(lane, 0, TraceEvent::Steal { rows });
    }

    pub fn batch_formed(&self, lane: usize, key: String, rows: usize) {
        self.push(lane, 0, TraceEvent::BatchFormed { key, rows });
    }

    pub fn exec_start(&self, lane: usize, tier: f32, class: usize) {
        self.push(lane, 0, TraceEvent::ExecStart { tier, class });
    }

    pub fn exec_end(&self, lane: usize, tier: f32, class: usize) {
        self.push(lane, 0, TraceEvent::ExecEnd { tier, class });
    }

    pub fn retry(&self, lane: usize, attempt: usize) {
        self.push(lane, 0, TraceEvent::Retry { attempt });
    }

    pub fn bisect(&self, lane: usize) {
        self.push(lane, 0, TraceEvent::Bisect);
    }

    pub fn poisoned(&self, lane: usize) {
        self.push(lane, 0, TraceEvent::Poisoned);
    }

    pub fn respawn(&self, lane: usize, class: usize) {
        self.push(lane, 0, TraceEvent::Respawn { class });
    }

    pub fn breaker_transition(&self, lane: usize, class: usize,
                              from: BreakerState, to: BreakerState) {
        self.push(lane, 0, TraceEvent::BreakerTransition {
            class,
            from: from.name(),
            to: to.name(),
        });
    }

    pub fn draft_round(&self, lane: usize, rows: usize) {
        self.push(lane, 0, TraceEvent::DraftRound { rows });
    }

    pub fn verify_resolve(&self, lane: usize, trace_id: u64,
                          accepted: usize, rejected: usize) {
        self.push(lane, trace_id,
                  TraceEvent::VerifyResolve { accepted, rejected });
    }

    pub fn arena_hit(&self, lane: usize, trace_id: u64) {
        self.push(lane, trace_id, TraceEvent::ArenaHit);
    }

    pub fn arena_miss(&self, lane: usize, trace_id: u64) {
        self.push(lane, trace_id, TraceEvent::ArenaMiss);
    }

    pub fn arena_evict(&self, lane: usize, victim: u64) {
        self.push(lane, 0, TraceEvent::ArenaEvict { victim });
    }

    pub fn requeue(&self, lane: usize, trace_id: u64) {
        self.push(lane, trace_id, TraceEvent::Requeue);
    }

    pub fn terminal(&self, lane: usize, trace_id: u64,
                    cause: &'static str) {
        self.push(lane, trace_id, TraceEvent::Terminal { cause });
    }

    // --- drain / ledger ------------------------------------------------

    /// Take every buffered event (oldest first per lane, then merged
    /// into global tick order) and count them as exported.  After this
    /// returns — with emitters quiesced — the ledger reconciles:
    /// `counts().dropped + counts().exported == counts().emitted`.
    pub fn drain(&self) -> Vec<Stamped> {
        let mut out: Vec<Stamped> = Vec::new();
        for lane in &self.lanes {
            out.extend(lane.lock().drain(..));
        }
        self.exported
            .fetch_add(out.len() as u64, Ordering::Relaxed);
        out.sort_by_key(|e| e.tick_us);
        out
    }

    /// The exact event ledger so far.
    pub fn counts(&self) -> TraceCounts {
        TraceCounts {
            emitted: self.emitted.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            exported: self.exported.load(Ordering::Relaxed),
        }
    }
}

// ---------------------------------------------------------------------------
// live snapshot types
// ---------------------------------------------------------------------------

/// Live per-worker-class counters the engine keeps regardless of
/// whether tracing is enabled: one-shot served/shed tallies plus a
/// bounded-memory latency histogram, all observable mid-run with no
/// lock.  These feed [`ClassSnapshot`] and the shutdown report's
/// percentile lines.
#[derive(Debug, Default)]
pub struct LiveClassStats {
    // Relaxed: independent monotone tallies read by snapshots; a
    // torn cross-counter read can only lag, never corrupt
    pub served: AtomicU64,
    pub shed: AtomicU64,
    pub latency: Log2Hist,
}

impl LiveClassStats {
    pub fn record_served(&self, latency_ms: f64) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.latency.observe_ms(latency_ms);
    }

    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }
}

/// One worker class's slice of a live [`EngineSnapshot`].
#[derive(Debug, Clone)]
pub struct ClassSnapshot {
    pub class: String,
    /// one-shot completions served by this class so far
    pub served: u64,
    /// one-shot sheds attributed to this class so far
    pub shed: u64,
    /// log2-bucket latency percentiles over the served completions
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub latency_samples: u64,
    pub breaker: &'static str,
    pub breaker_trips: usize,
    pub retries: usize,
    pub splits: usize,
    pub poisoned: usize,
    pub respawns: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// What `EngineHandle::snapshot()` returns: the engine's live gauges
/// and counters at one instant mid-run — the multi-node heartbeat
/// building block (ROADMAP).  Everything here is read from atomics
/// (or one brief controller lock per class for the breaker state);
/// nothing blocks the serving hot path.
#[derive(Debug, Clone)]
pub struct EngineSnapshot {
    /// ms since engine start on the exec clock
    pub uptime_ms: f64,
    /// aggregate admission-queue depth (one atomic load)
    pub queue_depth: usize,
    /// deadline-carrying items currently enqueued
    pub urgent_depth: usize,
    pub live_workers: usize,
    /// one-shot completions so far, summed over classes
    pub served: u64,
    /// one-shot sheds so far (worker- and engine-side)
    pub shed: u64,
    pub sessions_started: usize,
    pub sessions_done: usize,
    pub sessions_shed: usize,
    pub spec_drafted: usize,
    pub spec_accepted: usize,
    pub spec_rejected: usize,
    pub classes: Vec<ClassSnapshot>,
    /// event ledger, when tracing is enabled
    pub trace: Option<TraceCounts>,
}

// ---------------------------------------------------------------------------
// Chrome trace_event export
// ---------------------------------------------------------------------------

/// Chrome `trace_event` JSON export (load at `chrome://tracing` or
/// <https://ui.perfetto.dev>).  Pure functions over drained events —
/// no recorder state, so tests and the CLI share one code path.
pub mod trace_export {
    use super::*;

    /// pid for worker-lane rows (one tid per worker + engine lane)
    const PID_WORKERS: u64 = 1;
    /// pid for per-request lifecycle spans (one tid per trace id)
    const PID_REQUESTS: u64 = 2;

    fn f(x: f64) -> Value {
        Value::Num(if x.is_finite() { x } else { 0.0 })
    }

    fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Obj(fields.into_iter()
                       .map(|(k, v)| (k.to_string(), v))
                       .collect())
    }

    fn event(name: &str, ph: &str, pid: u64, tid: u64, ts: u64,
             extra: Vec<(&str, Value)>, args: Vec<(&str, Value)>)
             -> Value {
        let mut fields = vec![
            ("name", Value::Str(name.to_string())),
            ("ph", Value::Str(ph.to_string())),
            ("pid", f(pid as f64)),
            ("tid", f(tid as f64)),
            ("ts", f(ts as f64)),
        ];
        fields.extend(extra);
        fields.push(("args", obj(args)));
        obj(fields)
    }

    fn class_name(classes: &[String], idx: usize) -> Value {
        Value::Str(classes.get(idx).cloned()
                       .unwrap_or_else(|| format!("class{idx}")))
    }

    /// Render drained events as a Chrome trace: complete ("X") spans
    /// for ExecStart/End pairs (per worker tid) and Admit→Terminal
    /// pairs (per request tid under pid 2), instant ("i") events for
    /// everything else.  Unpaired starts/admits (ring overflow, or a
    /// fleet that died mid-exec) degrade to instants, never panic.
    pub fn chrome_json(events: &[Stamped], classes: &[String])
                       -> String {
        let mut out: Vec<Value> = Vec::new();
        // ExecStart/End pair per lane: workers are serial, so the
        // first unmatched start on a lane pairs with the next end
        let max_lane =
            events.iter().map(|e| e.lane).max().unwrap_or(0);
        let mut open_exec: Vec<Option<&Stamped>> =
            vec![None; max_lane + 1];
        // Admit/Terminal pair per trace id
        let mut admits: std::collections::HashMap<u64, u64> =
            std::collections::HashMap::new();
        for e in events {
            match &e.event {
                TraceEvent::ExecStart { .. } => {
                    if let Some(orphan) =
                        open_exec[e.lane].replace(e)
                    {
                        // a start with no end (overflow/fault): keep
                        // it visible as an instant
                        out.push(instant(orphan, classes));
                    }
                }
                TraceEvent::ExecEnd { tier, class } => {
                    match open_exec[e.lane].take() {
                        Some(start) => out.push(event(
                            "exec", "X", PID_WORKERS,
                            e.lane as u64, start.tick_us,
                            vec![(
                                "dur",
                                f(e.tick_us
                                      .saturating_sub(start.tick_us)
                                      as f64),
                            )],
                            vec![
                                ("tier", f(*tier as f64)),
                                ("class",
                                 class_name(classes, *class)),
                            ],
                        )),
                        None => out.push(instant(e, classes)),
                    }
                }
                TraceEvent::Admit => {
                    admits.insert(e.trace_id, e.tick_us);
                }
                TraceEvent::Terminal { cause } => {
                    match admits.remove(&e.trace_id) {
                        Some(start) => out.push(event(
                            "request", "X", PID_REQUESTS, e.trace_id,
                            start,
                            vec![(
                                "dur",
                                f(e.tick_us.saturating_sub(start)
                                      as f64),
                            )],
                            vec![
                                ("cause",
                                 Value::Str(cause.to_string())),
                                ("trace_id",
                                 f(e.trace_id as f64)),
                            ],
                        )),
                        None => out.push(instant(e, classes)),
                    }
                }
                _ => out.push(instant(e, classes)),
            }
        }
        // orphans left open at the end of the capture
        for orphan in open_exec.into_iter().flatten() {
            out.push(instant(orphan, classes));
        }
        for (trace_id, ts) in admits {
            out.push(event("admit", "i", PID_REQUESTS, trace_id, ts,
                           vec![("s", Value::Str("t".into()))],
                           vec![("trace_id", f(trace_id as f64))]));
        }
        crate::json::to_string(&obj(vec![
            ("traceEvents", Value::Arr(out)),
            ("displayTimeUnit", Value::Str("ms".into())),
        ]))
    }

    fn instant(e: &Stamped, classes: &[String]) -> Value {
        let mut args: Vec<(&str, Value)> =
            vec![("trace_id", f(e.trace_id as f64))];
        match &e.event {
            TraceEvent::Place { shard } => {
                args.push(("shard", f(*shard as f64)));
            }
            TraceEvent::Steal { rows }
            | TraceEvent::DraftRound { rows } => {
                args.push(("rows", f(*rows as f64)));
            }
            TraceEvent::BatchFormed { key, rows } => {
                args.push(("key", Value::Str(key.clone())));
                args.push(("rows", f(*rows as f64)));
            }
            TraceEvent::ExecStart { tier, class }
            | TraceEvent::ExecEnd { tier, class } => {
                args.push(("tier", f(*tier as f64)));
                args.push(("class", class_name(classes, *class)));
            }
            TraceEvent::Retry { attempt } => {
                args.push(("attempt", f(*attempt as f64)));
            }
            TraceEvent::Respawn { class } => {
                args.push(("class", class_name(classes, *class)));
            }
            TraceEvent::BreakerTransition { class, from, to } => {
                args.push(("class", class_name(classes, *class)));
                args.push(("from", Value::Str((*from).into())));
                args.push(("to", Value::Str((*to).into())));
            }
            TraceEvent::VerifyResolve { accepted, rejected } => {
                args.push(("accepted", f(*accepted as f64)));
                args.push(("rejected", f(*rejected as f64)));
            }
            TraceEvent::ArenaEvict { victim } => {
                args.push(("victim", f(*victim as f64)));
            }
            TraceEvent::Terminal { cause } => {
                args.push(("cause", Value::Str((*cause).into())));
            }
            _ => {}
        }
        event(e.kind(), "i", PID_WORKERS, e.lane as u64, e.tick_us,
              vec![("s", Value::Str("t".into()))], args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recorder(cap: usize) -> TraceRecorder {
        TraceRecorder::new(cap, 2, vec!["default".into()],
                           Instant::now())
    }

    #[test]
    fn overflow_drops_oldest_and_counts_exactly() {
        let t = recorder(3);
        for i in 0..10u64 {
            t.admit(0, i + 1);
        }
        let c = t.counts();
        assert_eq!((c.emitted, c.dropped, c.exported), (10, 7, 0));
        let drained = t.drain();
        assert_eq!(drained.len(), 3, "ring capacity bounds the lane");
        // the survivors are the NEWEST three, in order
        let ids: Vec<u64> =
            drained.iter().map(|e| e.trace_id).collect();
        assert_eq!(ids, vec![8, 9, 10]);
        let c = t.counts();
        assert_eq!(c.dropped + c.exported, c.emitted);
    }

    #[test]
    fn lanes_are_independent_and_merge_in_tick_order() {
        let t = recorder(8);
        t.admit(0, 1);
        t.admit(1, 2);
        t.terminal(2, 1, "done"); // engine lane
        assert_eq!(t.engine_lane(), 2);
        let drained = t.drain();
        assert_eq!(drained.len(), 3);
        assert!(drained.windows(2)
                    .all(|w| w[0].tick_us <= w[1].tick_us));
        let c = t.counts();
        assert_eq!(c.dropped + c.exported, c.emitted);
        assert_eq!(c.exported, 3);
        // a second drain exports nothing new
        assert!(t.drain().is_empty());
        assert_eq!(t.counts().exported, 3);
    }

    #[test]
    fn trace_ids_start_at_one_and_are_unique() {
        let t = recorder(4);
        assert_eq!(t.alloc_trace_id(), 1);
        assert_eq!(t.alloc_trace_id(), 2);
        assert_eq!(t.alloc_trace_id(), 3);
    }

    #[test]
    fn kinds_cover_every_variant() {
        let t = recorder(64);
        t.admit(0, 1);
        t.place(0, 1, 3);
        t.steal(0, 2);
        t.batch_formed(0, "k".into(), 4);
        t.exec_start(0, 1.0, 0);
        t.exec_end(0, 1.0, 0);
        t.retry(0, 1);
        t.bisect(0);
        t.poisoned(0);
        t.respawn(0, 0);
        t.breaker_transition(0, 0, BreakerState::Closed,
                             BreakerState::Open);
        t.draft_round(0, 3);
        t.verify_resolve(0, 1, 2, 1);
        t.arena_hit(0, 1);
        t.arena_miss(0, 1);
        t.arena_evict(0, 9);
        t.requeue(0, 1);
        t.terminal(0, 1, "done");
        let kinds: Vec<&str> =
            t.drain().iter().map(|e| e.kind()).collect();
        assert_eq!(kinds, vec![
            "admit", "place", "steal", "batch-formed", "exec-start",
            "exec-end", "retry", "bisect", "poisoned", "respawn",
            "breaker-transition", "draft-round", "verify-resolve",
            "arena-hit", "arena-miss", "arena-evict", "requeue",
            "terminal",
        ]);
    }

    #[test]
    fn chrome_export_pairs_spans_and_parses() {
        let t = recorder(64);
        t.admit(2, 7);
        t.exec_start(0, 0.5, 0);
        t.exec_end(0, 0.5, 0);
        t.retry(1, 1);
        t.terminal(0, 7, "done");
        // an unpaired start must degrade to an instant, not panic
        t.exec_start(1, 1.0, 0);
        let events = t.drain();
        let text = trace_export::chrome_json(&events,
                                             &["default".into()]);
        let doc = crate::json::parse(&text).expect("valid JSON");
        let arr = doc.req("traceEvents").unwrap().as_arr().unwrap();
        let phase = |v: &crate::json::Value| {
            v.req("ph").unwrap().as_str().unwrap().to_string()
        };
        let name = |v: &crate::json::Value| {
            v.req("name").unwrap().as_str().unwrap().to_string()
        };
        let execs: Vec<_> = arr.iter()
            .filter(|v| name(v) == "exec" && phase(v) == "X")
            .collect();
        assert_eq!(execs.len(), 1, "one complete exec span");
        assert!(execs[0].req("dur").unwrap().as_f64().unwrap()
                    >= 0.0);
        let requests: Vec<_> = arr.iter()
            .filter(|v| name(v) == "request" && phase(v) == "X")
            .collect();
        assert_eq!(requests.len(), 1,
                   "one complete request lifecycle span");
        assert_eq!(requests[0].req("tid").unwrap().as_f64().unwrap(),
                   7.0);
        // retry shows up as an instant, the orphan start too
        assert!(arr.iter().any(|v| name(v) == "retry"
                                   && phase(v) == "i"));
        assert!(arr.iter().any(|v| name(v) == "exec-start"
                                   && phase(v) == "i"));
    }

    #[test]
    fn live_class_stats_tally_and_histogram() {
        let live = LiveClassStats::default();
        live.record_served(5.0);
        live.record_served(7.0);
        live.record_shed();
        assert_eq!(live.served.load(Ordering::Relaxed), 2);
        assert_eq!(live.shed.load(Ordering::Relaxed), 1);
        assert_eq!(live.latency.count(), 2);
        let p50 = live.latency.quantile_ms(0.5);
        let (lo, hi) = Log2Hist::bucket_bounds_ms(5.0);
        assert!(p50 >= lo && p50 <= hi, "p50 {p50} vs [{lo}, {hi}]");
    }
}
