//! SLO-aware capacity controller with hysteresis.
//!
//! Two signals go into every tier decision:
//!
//!  1. **Global backlog** (the original signal): smoothed queue depth is
//!     mapped to one of the available capacity tiers — empty queue ->
//!     highest capacity; beyond `depth_per_tier` waiting requests per
//!     step, shed one tier, and so on.  Hysteresis (EWMA on the depth)
//!     prevents tier oscillation at load boundaries.
//!  2. **Per-batch SLO constraints** (the handle-API extension): the
//!     tightest deadline slack in the batch can push the choice *down*
//!     the ladder (lower tiers are faster, so a request about to miss
//!     its deadline is served cheap rather than late), and the largest
//!     `floor_tier` in the batch clamps the choice *up* (a quality
//!     floor beats both the backlog and the deadline signal).
//!
//! Deadline pressure needs a latency estimate per tier; the controller
//! learns one online as an EWMA over the per-batch execution times the
//! workers report via [`observe_exec`](CapacityController::observe_exec).
//! Until a tier has been observed its estimate is unknown and treated
//! optimistically (no demotion), so cold starts behave exactly like the
//! old backlog-only controller.
//!
//! In the multi-worker engine there is one controller instance **per
//! worker class** (see `WorkerClass` in the engine module), each behind
//! its own mutex: per-tier exec-time EWMAs learned on one backend class
//! (a fast GPU) never demote — or mask demotion for — batches served by
//! another (a slow CPU).  Every controller observes the same *global*
//! backlog, read off the sharded admission queue's atomic depth gauge,
//! so observing it never takes a queue lock and all classes shed
//! backlog together while their latency models stay isolated.  The
//! floor clamp uses the same [`floor_rung`](super::batcher::floor_rung)
//! rule as the batch-compatibility key, so a batch grouped as "rung r"
//! is always clamped to exactly rung r, never split by rounding
//! disagreements.

use super::batcher::floor_rung;
use super::tier_matches;

/// EWMA weight on each batch-health observation feeding the breaker.
const FAIL_ALPHA: f64 = 0.3;
/// failure-rate EWMA above which a Closed breaker trips Open
const OPEN_AT: f64 = 0.5;
/// minimum observations before the breaker is allowed to trip — a
/// single failed first batch must not brown out a cold class
const MIN_OBS: usize = 4;
/// worker pop-cycles an Open breaker waits before probing Half-open
const COOLDOWN_TICKS: usize = 16;
/// accept-rate EWMA below which draft tier escalates one rung
const DRAFT_ESCALATE_BELOW: f64 = 0.5;

/// Per-class circuit-breaker state, driven by the failure-rate EWMA
/// over batch outcomes ([`CapacityController::observe_batch_outcome`]).
///
///  * **Closed** — healthy: batches run at the controller's chosen
///    tier.
///  * **Open** — tripped: the class backs off the queue and serves
///    whatever it still pops in *brownout* (cheapest floored tier);
///    after a cooldown of [`COOLDOWN_TICKS`] pop-cycles it probes.
///  * **HalfOpen** — probing: batches run at the NORMAL tier (recovery
///    must be tested at real quality, not at brownout quality); one
///    healthy batch closes the breaker, one failure re-opens it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    /// Stable lowercase label (trace events, report lines).
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// See module docs.  Invariants (property-tested in
/// `tests/properties.rs`):
///  * `tier_for_depth` is monotone non-increasing in depth
///  * every returned tier is one of the configured tiers
///  * after the queue empties, repeated `choose(0)` decays the EWMA and
///    converges back to the top tier
///  * `choose_for_batch` never returns a tier below the requested floor
///    (when the floor is within the ladder)
#[derive(Debug, Clone)]
pub struct CapacityController {
    /// available tiers, descending capacity (e.g. [1.0, 0.75, 0.5, 0.25])
    pub tiers: Vec<f32>,
    pub depth_per_tier: f64,
    ewma: f64,
    alpha: f64,
    /// learned per-tier batch execution time (ms), EWMA over worker
    /// observations; `None` until the tier has been executed once
    exec_ms: Vec<Option<f64>>,
    exec_alpha: f64,
    /// learned speculative-decode accept rate (fraction of drafted
    /// tokens the top-tier verify pass agreed with), EWMA over verify
    /// resolutions on this class; `None` until the first verify
    accept_ewma: Option<f64>,
    accept_alpha: f64,
    /// failure-rate EWMA over batch outcomes (1.0 = every batch saw a
    /// transient fault), the breaker's trip signal
    fail_ewma: f64,
    /// batch outcomes observed since the last Closed reset (the
    /// breaker needs [`MIN_OBS`] before it may trip)
    fail_obs: usize,
    breaker: BreakerState,
    /// pop-cycles left before an Open breaker probes Half-open
    cooldown: usize,
    /// Closed → Open transitions over this controller's lifetime
    trips: usize,
}

impl CapacityController {
    pub fn new(mut tiers: Vec<f32>, depth_per_tier: f64)
               -> CapacityController {
        assert!(!tiers.is_empty());
        // a non-positive ladder step makes tier_for_depth divide into
        // NaN/inf and silently pin the tier; fail loudly instead
        assert!(depth_per_tier.is_finite() && depth_per_tier > 0.0,
                "depth_per_tier must be finite and > 0, got {depth_per_tier}");
        tiers.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let exec_ms = vec![None; tiers.len()];
        CapacityController {
            tiers,
            depth_per_tier,
            ewma: 0.0,
            alpha: 0.4,
            exec_ms,
            exec_alpha: 0.3,
            accept_ewma: None,
            accept_alpha: 0.4,
            fail_ewma: 0.0,
            fail_obs: 0,
            breaker: BreakerState::Closed,
            cooldown: 0,
            trips: 0,
        }
    }

    /// Observe the current queue depth and pick a tier from the backlog
    /// signal alone (no SLO constraints — kept as the primitive the
    /// batch-level decision builds on).
    pub fn choose(&mut self, queue_depth: usize) -> f32 {
        self.ewma = self.alpha * queue_depth as f64
            + (1.0 - self.alpha) * self.ewma;
        self.tier_for_depth(self.ewma)
    }

    /// Full per-batch decision: backlog signal, then deadline pressure
    /// (demote to a tier whose learned exec time fits the tightest
    /// remaining slack), then the quality floor (clamp back up to the
    /// smallest configured tier at or above `floor_tier`).
    ///
    /// `tightest_slack_ms` is the smallest `deadline - waited` over the
    /// batch's deadline-carrying requests (`None` when the batch is all
    /// best-effort); already-expired requests are shed by the worker
    /// before this is called, so the slack is non-negative.
    pub fn choose_for_batch(&mut self, queue_depth: usize, floor_tier: f32,
                            tightest_slack_ms: Option<f64>) -> f32 {
        let backlog = self.choose(queue_depth);
        let mut idx = self
            .tiers
            .iter()
            .position(|&t| tier_matches(t, backlog))
            .unwrap_or(0);
        if let Some(slack) = tightest_slack_ms {
            // walk down the ladder while the learned estimate says the
            // current tier would blow the slack; unknown estimates are
            // optimistic (stop — no evidence the tier is too slow)
            while idx + 1 < self.tiers.len() {
                match self.exec_ms[idx] {
                    Some(est) if est > slack => idx += 1,
                    _ => break,
                }
            }
        }
        if floor_tier > 0.0 {
            // smallest configured tier still at/above the floor; a floor
            // above the whole ladder clamps to the top tier (shared rung
            // rule — see batcher::floor_rung)
            idx = idx.min(floor_rung(&self.tiers, floor_tier));
        }
        self.tiers[idx]
    }

    /// Feed back one executed batch so the per-tier latency estimate
    /// tracks the real backend (called by workers after each batch).
    pub fn observe_exec(&mut self, tier: f32, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        if let Some(i) =
            self.tiers.iter().position(|&t| tier_matches(t, tier))
        {
            self.exec_ms[i] = Some(match self.exec_ms[i] {
                Some(prev) => {
                    self.exec_alpha * ms + (1.0 - self.exec_alpha) * prev
                }
                None => ms,
            });
        }
    }

    /// Learned per-batch execution estimate for `tier` (ms), if any
    /// batch has run there yet.
    pub fn exec_estimate(&self, tier: f32) -> Option<f64> {
        self.tiers
            .iter()
            .position(|&t| tier_matches(t, tier))
            .and_then(|i| self.exec_ms[i])
    }

    /// Snapshot of every learned estimate, `(tier, ms-if-observed)` in
    /// ladder order — what the engine folds into the report's
    /// per-worker-class sections at shutdown.
    pub fn exec_estimates(&self) -> Vec<(f32, Option<f64>)> {
        self.tiers
            .iter()
            .copied()
            .zip(self.exec_ms.iter().copied())
            .collect()
    }

    /// Feed back one resolved speculative verify pass: `accepted` of
    /// `drafted` proposed tokens agreed with the top-tier verifier.
    /// Drives [`draft_k`](Self::draft_k) — the accept rate is a
    /// *per-class* learned signal, like the exec-time EWMAs, because
    /// the draft tier's agreement with the top tier depends on the
    /// backend serving the class.
    pub fn observe_accept(&mut self, accepted: usize, drafted: usize) {
        if drafted == 0 {
            return;
        }
        let rate = (accepted.min(drafted) as f64) / drafted as f64;
        self.accept_ewma = Some(match self.accept_ewma {
            Some(prev) => {
                self.accept_alpha * rate
                    + (1.0 - self.accept_alpha) * prev
            }
            None => rate,
        });
    }

    /// Learned speculative accept rate on this class, if any verify
    /// pass has resolved yet.
    pub fn accept_rate(&self) -> Option<f64> {
        self.accept_ewma
    }

    /// How many tokens a session should draft per admission, given the
    /// configured ceiling `max_k`.  Unobserved classes draft the full
    /// `max_k` (optimistic, like the cold-start exec estimates);
    /// otherwise `k` scales linearly with the learned accept rate and
    /// never drops below 1.  The floor is the no-regret guarantee:
    /// with `k == 1` a rejected draft costs exactly one wasted
    /// verification pass, so speculative mode can never trail plain
    /// decode by more than that even against an adversarial verifier.
    pub fn draft_k(&self, max_k: usize) -> usize {
        let max_k = max_k.max(1);
        match self.accept_ewma {
            None => max_k,
            Some(rate) => {
                let extra = (max_k - 1) as f64 * rate.clamp(0.0, 1.0);
                1 + extra.round() as usize
            }
        }
    }

    /// Which tier a draft batch should run at, given the batch's
    /// strictest quality floor.  Normally the cheapest floored rung —
    /// speculation exists to make drafting cheap — but when the
    /// learned accept rate is persistently low
    /// (< [`DRAFT_ESCALATE_BELOW`]), the cheap proposals are mostly
    /// being thrown away at verification, so drafting one rung higher
    /// buys agreement instead of burning verify passes.  Unobserved
    /// classes stay optimistic (cheapest rung), like cold-start exec
    /// estimates.
    pub fn draft_tier(&self, floor: f32) -> f32 {
        let base = floor_rung(&self.tiers, floor);
        match self.accept_ewma {
            Some(rate) if rate < DRAFT_ESCALATE_BELOW && base > 0 => {
                self.tiers[base - 1]
            }
            _ => self.tiers[base],
        }
    }

    /// Feed back one executed batch's *health* (did the fault ladder
    /// see any transient failure?) and drive the breaker state
    /// machine.  Called by workers once per batch — including the
    /// batches a Half-open probe serves, whose outcome decides between
    /// closing and re-opening.
    pub fn observe_batch_outcome(&mut self, ok: bool) {
        let sample = if ok { 0.0 } else { 1.0 };
        self.fail_ewma =
            FAIL_ALPHA * sample + (1.0 - FAIL_ALPHA) * self.fail_ewma;
        self.fail_obs += 1;
        match self.breaker {
            BreakerState::Closed => {
                if self.fail_obs >= MIN_OBS && self.fail_ewma > OPEN_AT {
                    self.breaker = BreakerState::Open;
                    self.trips += 1;
                    self.cooldown = COOLDOWN_TICKS;
                }
            }
            BreakerState::HalfOpen => {
                if ok {
                    // recovery confirmed at real quality: reset the
                    // trip signal so old faults never count twice
                    self.breaker = BreakerState::Closed;
                    self.fail_ewma = 0.0;
                    self.fail_obs = 0;
                } else {
                    self.breaker = BreakerState::Open;
                    self.cooldown = COOLDOWN_TICKS;
                }
            }
            // Open transitions only via breaker_tick's cooldown
            BreakerState::Open => {}
        }
    }

    /// One worker pop-cycle: burn cooldown while Open (reaching zero
    /// moves to Half-open — time to probe) and return the state the
    /// cycle should serve under.
    pub fn breaker_tick(&mut self) -> BreakerState {
        if self.breaker == BreakerState::Open {
            self.cooldown = self.cooldown.saturating_sub(1);
            if self.cooldown == 0 {
                self.breaker = BreakerState::HalfOpen;
            }
        }
        self.breaker
    }

    /// [`observe_batch_outcome`](Self::observe_batch_outcome), but
    /// reporting the breaker transition it caused, if any — the flight
    /// recorder's hook, so emission sites never have to re-derive
    /// state by comparing `breaker_state()` around the call.
    pub fn observe_batch_outcome_noting(&mut self, ok: bool)
        -> Option<(BreakerState, BreakerState)> {
        let before = self.breaker;
        self.observe_batch_outcome(ok);
        (self.breaker != before).then_some((before, self.breaker))
    }

    /// [`breaker_tick`](Self::breaker_tick), but also reporting the
    /// Open → Half-open transition when the cooldown expires.
    pub fn breaker_tick_noting(&mut self)
        -> (BreakerState, Option<(BreakerState, BreakerState)>) {
        let before = self.breaker;
        let state = self.breaker_tick();
        (state, (state != before).then_some((before, state)))
    }

    /// Current breaker state, without ticking.
    pub fn breaker_state(&self) -> BreakerState {
        self.breaker
    }

    /// Closed → Open transitions so far (report material).
    pub fn breaker_trips(&self) -> usize {
        self.trips
    }

    /// Pure mapping (for tests / property checks): tier for a given
    /// smoothed depth without updating state.
    pub fn tier_for_depth(&self, depth: f64) -> f32 {
        let idx = (depth / self.depth_per_tier).floor() as usize;
        self.tiers[idx.min(self.tiers.len() - 1)]
    }

    /// Highest-capacity tier (what an idle system serves).
    pub fn top_tier(&self) -> f32 {
        self.tiers[0]
    }

    /// Current smoothed depth (EWMA state).
    pub fn smoothed_depth(&self) -> f64 {
        self.ewma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_monotone_in_depth() {
        let c = CapacityController::new(vec![1.0, 0.75, 0.5, 0.25], 4.0);
        let mut prev = f32::INFINITY;
        for d in 0..40 {
            let t = c.tier_for_depth(d as f64);
            assert!(t <= prev);
            prev = t;
        }
        assert_eq!(c.tier_for_depth(0.0), 1.0);
        assert_eq!(c.tier_for_depth(100.0), 0.25);
    }

    #[test]
    fn controller_hysteresis_smooths_spikes() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 8.0);
        // single spike shouldn't immediately drop the tier
        assert_eq!(c.choose(0), 1.0);
        let t = c.choose(20); // ewma = 0.4*20 = 8 -> boundary
        let t2 = c.choose(0); // decays back
        assert!(t >= 0.5);
        assert!(t2 >= t - 1e-6 || t2 == 1.0);
    }

    #[test]
    fn controller_sorts_tiers() {
        let c = CapacityController::new(vec![0.25, 1.0, 0.5], 1.0);
        assert_eq!(c.tiers, vec![1.0, 0.5, 0.25]);
        assert_eq!(c.top_tier(), 1.0);
    }

    #[test]
    fn ewma_decays_back_to_top_tier() {
        let mut c = CapacityController::new(vec![1.0, 0.5, 0.25], 2.0);
        for _ in 0..10 {
            c.choose(50); // sustained overload
        }
        assert_eq!(c.choose(50), 0.25);
        for _ in 0..64 {
            c.choose(0); // queue empties
        }
        assert_eq!(c.choose(0), 1.0, "ewma {}", c.smoothed_depth());
    }

    #[test]
    fn deadline_pressure_demotes_to_a_tier_that_fits() {
        let mut c = CapacityController::new(vec![1.0, 0.5, 0.25], 1e9);
        // teach it: full capacity takes 40ms/batch, 0.5 takes 12ms,
        // 0.25 takes 4ms
        for _ in 0..4 {
            c.observe_exec(1.0, 40.0);
            c.observe_exec(0.5, 12.0);
            c.observe_exec(0.25, 4.0);
        }
        // no deadline -> backlog choice (top tier on an empty queue)
        assert_eq!(c.choose_for_batch(0, 0.0, None), 1.0);
        // 20ms of slack: 1.0 (40ms) blows it, 0.5 (12ms) fits
        assert_eq!(c.choose_for_batch(0, 0.0, Some(20.0)), 0.5);
        // 2ms of slack: even 0.25 (4ms) is too slow, but it is the
        // fastest option available — never walks off the ladder
        assert_eq!(c.choose_for_batch(0, 0.0, Some(2.0)), 0.25);
        // generous slack keeps the top tier
        assert_eq!(c.choose_for_batch(0, 0.0, Some(500.0)), 1.0);
    }

    #[test]
    fn unknown_estimates_do_not_demote() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 1e9);
        // cold start: nothing observed yet -> optimistic, serve the top
        assert_eq!(c.choose_for_batch(0, 0.0, Some(0.001)), 1.0);
    }

    #[test]
    fn floor_tier_clamps_back_up() {
        let mut c = CapacityController::new(vec![1.0, 0.75, 0.5, 0.25], 1.0);
        for _ in 0..20 {
            c.choose(50); // drive the backlog signal to the bottom tier
        }
        // best-effort batch sheds to the bottom...
        assert_eq!(c.choose_for_batch(50, 0.0, None), 0.25);
        // ...but a 0.75 floor holds the line at exactly 0.75
        assert_eq!(c.choose_for_batch(50, 0.75, None), 0.75);
        // a floor between rungs rounds up to the next configured tier
        assert_eq!(c.choose_for_batch(50, 0.6, None), 0.75);
        // a floor above the whole ladder clamps to the top tier
        assert_eq!(c.choose_for_batch(50, 1.5, None), 1.0);
    }

    #[test]
    fn floor_beats_deadline_pressure() {
        let mut c = CapacityController::new(vec![1.0, 0.5, 0.25], 1e9);
        for _ in 0..4 {
            c.observe_exec(1.0, 40.0);
            c.observe_exec(0.5, 12.0);
            c.observe_exec(0.25, 4.0);
        }
        // 5ms slack wants 0.25, but the 0.5 floor wins: quality floors
        // are a contract, lateness is only a preference
        assert_eq!(c.choose_for_batch(0, 0.5, Some(5.0)), 0.5);
    }

    #[test]
    fn draft_k_is_optimistic_until_observed_then_tracks_accepts() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 4.0);
        assert_eq!(c.accept_rate(), None);
        // cold start: draft the configured ceiling
        assert_eq!(c.draft_k(4), 4);
        assert_eq!(c.draft_k(1), 1);
        assert_eq!(c.draft_k(0), 1, "ceiling clamps to >= 1");
        // perfect agreement keeps k at the ceiling
        c.observe_accept(4, 4);
        assert_eq!(c.accept_rate(), Some(1.0));
        assert_eq!(c.draft_k(4), 4);
        // total rejection collapses k toward the floor of 1
        for _ in 0..16 {
            c.observe_accept(0, 4);
        }
        let rate = c.accept_rate().unwrap();
        assert!(rate < 0.05, "ewma must decay under rejection: {rate}");
        assert_eq!(c.draft_k(4), 1,
                   "rejected drafts must shrink k to the floor");
        // a first observation of zero pins the floor immediately
        let mut cold = CapacityController::new(vec![1.0], 1.0);
        cold.observe_accept(0, 3);
        assert_eq!(cold.draft_k(8), 1);
        // zero-draft observations are ignored (no division blowup)
        cold.observe_accept(5, 0);
        assert_eq!(cold.accept_rate(), Some(0.0));
    }

    #[test]
    fn breaker_trips_after_min_obs_and_cools_to_half_open() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 4.0);
        assert_eq!(c.breaker_state(), BreakerState::Closed);
        // three straight failures: EWMA is high but MIN_OBS unmet
        for _ in 0..MIN_OBS - 1 {
            c.observe_batch_outcome(false);
        }
        assert_eq!(c.breaker_state(), BreakerState::Closed,
                   "must not trip before MIN_OBS observations");
        c.observe_batch_outcome(false);
        assert_eq!(c.breaker_state(), BreakerState::Open);
        assert_eq!(c.breaker_trips(), 1);
        // Open holds through the cooldown, then probes
        for _ in 0..COOLDOWN_TICKS - 1 {
            assert_eq!(c.breaker_tick(), BreakerState::Open);
        }
        assert_eq!(c.breaker_tick(), BreakerState::HalfOpen);
    }

    #[test]
    fn half_open_probe_closes_on_success_reopens_on_failure() {
        let mut c = CapacityController::new(vec![1.0], 1.0);
        for _ in 0..MIN_OBS {
            c.observe_batch_outcome(false);
        }
        for _ in 0..COOLDOWN_TICKS {
            c.breaker_tick();
        }
        assert_eq!(c.breaker_state(), BreakerState::HalfOpen);
        // failed probe: straight back to Open, full cooldown again
        c.observe_batch_outcome(false);
        assert_eq!(c.breaker_state(), BreakerState::Open);
        assert_eq!(c.breaker_trips(), 1,
                   "a re-open from Half-open is not a new trip");
        for _ in 0..COOLDOWN_TICKS {
            c.breaker_tick();
        }
        // healthy probe: Closed with the trip signal reset, so the
        // next trip needs MIN_OBS fresh failures
        c.observe_batch_outcome(true);
        assert_eq!(c.breaker_state(), BreakerState::Closed);
        c.observe_batch_outcome(false);
        assert_eq!(c.breaker_state(), BreakerState::Closed,
                   "old faults must not count after recovery");
    }

    #[test]
    fn noting_wrappers_report_exactly_the_real_transitions() {
        let mut c = CapacityController::new(vec![1.0], 1.0);
        // healthy observations: no transition reported
        for _ in 0..MIN_OBS {
            assert_eq!(c.observe_batch_outcome_noting(true), None);
        }
        // drive to the trip: the LAST failing observation reports
        // Closed -> Open, the earlier ones report nothing
        let mut transitions = Vec::new();
        while c.breaker_state() == BreakerState::Closed {
            if let Some(t) = c.observe_batch_outcome_noting(false) {
                transitions.push(t);
            }
        }
        assert_eq!(transitions,
                   vec![(BreakerState::Closed, BreakerState::Open)]);
        // ticking through the cooldown reports one Open -> HalfOpen
        let mut tick_transitions = Vec::new();
        for _ in 0..COOLDOWN_TICKS {
            let (state, t) = c.breaker_tick_noting();
            assert_eq!(state, c.breaker_state());
            if let Some(t) = t {
                tick_transitions.push(t);
            }
        }
        assert_eq!(tick_transitions,
                   vec![(BreakerState::Open, BreakerState::HalfOpen)]);
        // the healthy probe reports HalfOpen -> Closed
        assert_eq!(c.observe_batch_outcome_noting(true),
                   Some((BreakerState::HalfOpen, BreakerState::Closed)));
        assert_eq!(BreakerState::HalfOpen.name(), "half-open");
    }

    #[test]
    fn healthy_stream_never_trips_the_breaker() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 4.0);
        for _ in 0..100 {
            c.observe_batch_outcome(true);
        }
        // a lone fault in a long healthy run stays Closed
        c.observe_batch_outcome(false);
        assert_eq!(c.breaker_state(), BreakerState::Closed);
        assert_eq!(c.breaker_trips(), 0);
    }

    #[test]
    fn draft_tier_escalates_one_rung_under_rejection() {
        let mut c =
            CapacityController::new(vec![1.0, 0.75, 0.5, 0.25], 4.0);
        // cold start: optimistic, cheapest floored rung
        assert_eq!(c.draft_tier(0.0), 0.25);
        assert_eq!(c.draft_tier(0.5), 0.5);
        // high accept rate keeps the cheap rung
        c.observe_accept(4, 4);
        assert_eq!(c.draft_tier(0.0), 0.25);
        // persistent rejection escalates exactly one rung
        for _ in 0..16 {
            c.observe_accept(0, 4);
        }
        assert_eq!(c.draft_tier(0.0), 0.5);
        assert_eq!(c.draft_tier(0.5), 0.75);
        // the top rung has nowhere to escalate to
        assert_eq!(c.draft_tier(1.0), 1.0);
    }

    #[test]
    fn exec_estimate_tracks_observations() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 1.0);
        assert_eq!(c.exec_estimate(1.0), None);
        c.observe_exec(1.0, 10.0);
        assert_eq!(c.exec_estimate(1.0), Some(10.0));
        c.observe_exec(1.0, 20.0); // ewma moves toward the new sample
        let est = c.exec_estimate(1.0).unwrap();
        assert!(est > 10.0 && est < 20.0, "ewma {est}");
        // junk observations are ignored
        c.observe_exec(1.0, f64::NAN);
        c.observe_exec(1.0, -5.0);
        assert_eq!(c.exec_estimate(1.0), Some(est));
        assert_eq!(c.exec_estimate(0.5), None);
    }
}
