//! Load-adaptive capacity controller with hysteresis.
//!
//! Maps smoothed queue depth to one of the available capacity tiers:
//! empty queue -> highest capacity; beyond `depth_per_tier` waiting
//! requests per step, shed one tier, and so on.  Hysteresis (EWMA on the
//! depth) prevents tier oscillation at load boundaries.  In the
//! multi-worker engine one controller instance is shared behind a mutex
//! and observes the *global* backlog, so all workers shed together.

/// See module docs.  Invariants (property-tested in
/// `tests/properties.rs`):
///  * `tier_for_depth` is monotone non-increasing in depth
///  * every returned tier is one of the configured tiers
///  * after the queue empties, repeated `choose(0)` decays the EWMA and
///    converges back to the top tier
#[derive(Debug, Clone)]
pub struct CapacityController {
    /// available tiers, descending capacity (e.g. [1.0, 0.75, 0.5, 0.25])
    pub tiers: Vec<f32>,
    pub depth_per_tier: f64,
    ewma: f64,
    alpha: f64,
}

impl CapacityController {
    pub fn new(mut tiers: Vec<f32>, depth_per_tier: f64)
               -> CapacityController {
        assert!(!tiers.is_empty());
        // a non-positive ladder step makes tier_for_depth divide into
        // NaN/inf and silently pin the tier; fail loudly instead
        assert!(depth_per_tier.is_finite() && depth_per_tier > 0.0,
                "depth_per_tier must be finite and > 0, got {depth_per_tier}");
        tiers.sort_by(|a, b| b.partial_cmp(a).unwrap());
        CapacityController { tiers, depth_per_tier, ewma: 0.0, alpha: 0.4 }
    }

    /// Observe the current queue depth and pick a tier.
    pub fn choose(&mut self, queue_depth: usize) -> f32 {
        self.ewma = self.alpha * queue_depth as f64
            + (1.0 - self.alpha) * self.ewma;
        self.tier_for_depth(self.ewma)
    }

    /// Pure mapping (for tests / property checks): tier for a given
    /// smoothed depth without updating state.
    pub fn tier_for_depth(&self, depth: f64) -> f32 {
        let idx = (depth / self.depth_per_tier).floor() as usize;
        self.tiers[idx.min(self.tiers.len() - 1)]
    }

    /// Highest-capacity tier (what an idle system serves).
    pub fn top_tier(&self) -> f32 {
        self.tiers[0]
    }

    /// Current smoothed depth (EWMA state).
    pub fn smoothed_depth(&self) -> f64 {
        self.ewma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_monotone_in_depth() {
        let c = CapacityController::new(vec![1.0, 0.75, 0.5, 0.25], 4.0);
        let mut prev = f32::INFINITY;
        for d in 0..40 {
            let t = c.tier_for_depth(d as f64);
            assert!(t <= prev);
            prev = t;
        }
        assert_eq!(c.tier_for_depth(0.0), 1.0);
        assert_eq!(c.tier_for_depth(100.0), 0.25);
    }

    #[test]
    fn controller_hysteresis_smooths_spikes() {
        let mut c = CapacityController::new(vec![1.0, 0.5], 8.0);
        // single spike shouldn't immediately drop the tier
        assert_eq!(c.choose(0), 1.0);
        let t = c.choose(20); // ewma = 0.4*20 = 8 -> boundary
        let t2 = c.choose(0); // decays back
        assert!(t >= 0.5);
        assert!(t2 >= t - 1e-6 || t2 == 1.0);
    }

    #[test]
    fn controller_sorts_tiers() {
        let c = CapacityController::new(vec![0.25, 1.0, 0.5], 1.0);
        assert_eq!(c.tiers, vec![1.0, 0.5, 0.25]);
        assert_eq!(c.top_tier(), 1.0);
    }

    #[test]
    fn ewma_decays_back_to_top_tier() {
        let mut c = CapacityController::new(vec![1.0, 0.5, 0.25], 2.0);
        for _ in 0..10 {
            c.choose(50); // sustained overload
        }
        assert_eq!(c.choose(50), 0.25);
        for _ in 0..64 {
            c.choose(0); // queue empties
        }
        assert_eq!(c.choose(0), 1.0, "ewma {}", c.smoothed_depth());
    }
}
