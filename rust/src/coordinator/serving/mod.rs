//! Elastic serving subsystem — the systems realization of "variable
//! inference time compute" (paper §1), grown from the original
//! single-threaded monolith into an independently testable pipeline:
//!
//! ```text
//!   producers ──mpsc──▶ admission (engine thread)
//!                            │ bounded push (backpressure)
//!                            ▼
//!                     [AdmissionQueue]          queue.rs
//!                      /     |     \
//!               worker 0  worker 1  worker N-1   worker.rs
//!               pop_batch -> CapacityController  controller.rs
//!               form_batch (pad to B×T)          batcher.rs
//!               Executor::execute(tier, tokens)
//!                  |            |
//!              XlaExecutor   SimExecutor         worker.rs / sim.rs
//!              (PJRT, owns   (seeded latency
//!               non-Send      model, hermetic)
//!               handles)
//!                      \     |     /
//!                      [ServeReport]             report.rs
//! ```
//!
//! Under light load every request runs at capacity 1.0 (teacher-exact, see
//! the §4.1 equivalence); as the shared queue deepens the controller sheds
//! compute by routing batches to lower-capacity tiers, trading the paper's
//! measured quality-vs-capacity curve for throughput.  PJRT handles are
//! not `Send`, so each worker constructs its own [`Executor`] on its own
//! thread via the factory passed to [`ElasticServer::run`]; the
//! [`SimExecutor`] implementor makes the whole admission → batch →
//! tier-select → execute → complete pipeline runnable without artifacts.

pub mod batcher;
pub mod controller;
pub mod queue;
pub mod report;
pub mod sim;
pub mod worker;

pub use batcher::{form_batch, Batch};
pub use controller::CapacityController;
pub use queue::AdmissionQueue;
pub use report::{Completion, ServeReport};
pub use sim::{SimExecutor, SimSpec};
pub use worker::{Executor, XlaExecutor};

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

/// One inference request: a fixed-length token row.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
}

/// Tolerance for matching an f32 capacity against the configured
/// ladder — the single source of truth for tier identity across
/// worker dispatch, sim validation and report accounting.
pub(crate) const TIER_EPS: f32 = 1e-6;

/// The one rule for "is this the same tier?" in this subsystem.
pub(crate) fn tier_matches(a: f32, b: f32) -> bool {
    (a - b).abs() < TIER_EPS
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// (capacity, entry name), e.g. (0.5, "serve_cap50"), descending.
    pub tiers: Vec<(f32, String)>,
    /// queue depth per shed tier (see [`CapacityController`])
    pub depth_per_tier: f64,
    /// max time a worker waits filling a batch before running partial
    pub max_batch_wait: Duration,
    /// number of execution workers (each owns one `Executor`)
    pub workers: usize,
    /// admission queue bound; the admission loop blocks when full, so
    /// its mpsc front-end stops draining (see queue.rs on backpressure
    /// scope — the mpsc itself is unbounded)
    pub queue_bound: usize,
}

impl ServeConfig {
    /// The four static-capacity artifact tiers produced by `make
    /// artifacts` (python/compile/aot.py, configs.SERVE_TIERS).
    pub fn standard() -> ServeConfig {
        ServeConfig {
            tiers: vec![
                (1.0, "serve_cap100".into()),
                (0.75, "serve_cap75".into()),
                (0.5, "serve_cap50".into()),
                (0.25, "serve_cap25".into()),
            ],
            depth_per_tier: 8.0,
            max_batch_wait: Duration::from_millis(20),
            workers: 1,
            queue_bound: 256,
        }
    }

    /// Same tier ladder with synthetic entry names — for simulation
    /// executors that never resolve entries against a manifest.
    pub fn sim() -> ServeConfig {
        let mut cfg = ServeConfig::standard();
        for (cap, entry) in &mut cfg.tiers {
            *entry = format!("sim_cap{:02.0}", *cap * 100.0);
        }
        cfg
    }

    pub fn with_workers(mut self, workers: usize) -> ServeConfig {
        self.workers = workers.max(1);
        self
    }

    pub fn with_queue_bound(mut self, bound: usize) -> ServeConfig {
        self.queue_bound = bound.max(1);
        self
    }

    pub fn with_depth_per_tier(mut self, depth: f64) -> ServeConfig {
        self.depth_per_tier = depth;
        self
    }

    pub fn with_max_batch_wait(mut self, wait: Duration) -> ServeConfig {
        self.max_batch_wait = wait;
        self
    }

    /// Capacity ladder without entry names, descending.
    pub fn capacities(&self) -> Vec<f32> {
        self.tiers.iter().map(|(c, _)| *c).collect()
    }
}

/// The serving engine: admission on the calling thread, N execution
/// workers behind a shared bounded queue, one shared capacity controller
/// observing the global backlog.
///
/// The engine is backend-agnostic: it only knows the [`Executor`] trait.
/// Because PJRT handles are not `Send`, executors are constructed *on*
/// their worker thread by the `factory` passed to [`run`](Self::run)
/// (called once per worker with the worker index).
pub struct ElasticServer {
    cfg: ServeConfig,
}

impl ElasticServer {
    pub fn new(cfg: ServeConfig) -> ElasticServer {
        ElasticServer { cfg }
    }

    /// Serve requests from `rx` until `expected` have been admitted or the
    /// channel disconnects, then drain: every admitted request completes
    /// before this returns.  Worker errors abort the run (the queue is
    /// closed so no thread is left blocked) and surface as `Err`.
    ///
    /// The serving clock starts only after every worker's executor is
    /// built (a readiness latch), so compile/warmup never pollutes the
    /// reported wall time or throughput.  Requests stamped (`submitted`)
    /// *before* the fleet is ready still accrue the warmup wait in their
    /// per-request latencies — producers that should only start once the
    /// fleet is hot belong in [`run_when_ready`](Self::run_when_ready).
    pub fn run<F>(&self, factory: F, rx: Receiver<Request>, expected: usize)
                  -> Result<ServeReport>
    where
        F: Fn(usize) -> Result<Box<dyn Executor>> + Sync,
    {
        self.run_when_ready(factory, move || rx, expected)
    }

    /// Spawn `producer` on its own thread once every worker's executor
    /// is warm, serve everything it sends (up to `expected`), and join
    /// it before returning — even on error, where the dropped receiver
    /// makes the producer's next `send` fail and exit.  The common
    /// "open-loop load from a generator thread" shape without the
    /// caller juggling channels and join handles.
    pub fn run_with_producer<F, P>(&self, factory: F, producer: P,
                                   expected: usize) -> Result<ServeReport>
    where
        F: Fn(usize) -> Result<Box<dyn Executor>> + Sync,
        P: FnOnce(Sender<Request>) + Send + 'static,
    {
        let mut handle = None;
        let report = self.run_when_ready(factory, || {
            let (tx, rx) = std::sync::mpsc::channel();
            handle = Some(std::thread::spawn(move || producer(tx)));
            rx
        }, expected);
        if let Some(h) = handle {
            if let Err(payload) = h.join() {
                // a panicking producer must not yield a normal-looking
                // (short) report — propagate, like worker panics do
                std::panic::resume_unwind(payload);
            }
        }
        report
    }

    /// Like [`run`](Self::run), but the request source is created only
    /// after every worker's executor is warm: `source` runs on the
    /// calling thread once the readiness latch clears (spawn producers
    /// there), so no request's latency stamp predates a hot fleet.
    /// Worker panics (factory or executor) are converted into a closed
    /// queue + a latch arrival by a drop guard, so the engine aborts
    /// (propagating the panic at scope join) instead of hanging; the
    /// latch is arrival-only — no worker ever blocks on it — so no
    /// unwind path can strand a peer.
    pub fn run_when_ready<F, R>(&self, factory: F, source: R,
                                expected: usize) -> Result<ServeReport>
    where
        F: Fn(usize) -> Result<Box<dyn Executor>> + Sync,
        R: FnOnce() -> Receiver<Request>,
    {
        let caps = self.cfg.capacities();
        let workers = self.cfg.workers.max(1);
        let queue = AdmissionQueue::new(self.cfg.queue_bound);
        let controller = Mutex::new(CapacityController::new(
            caps.clone(), self.cfg.depth_per_tier));
        let completions: Mutex<Vec<Completion>> =
            Mutex::new(Vec::with_capacity(expected.min(1 << 20)));
        let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());
        let ready = ReadyLatch::new(workers);

        let start = std::thread::scope(|s| {
            let queue = &queue;
            let controller = &controller;
            let completions = &completions;
            let errors = &errors;
            let factory = &factory;
            let cfg = &self.cfg;
            let ready = &ready;
            let caps = &caps;
            // if the scope body unwinds (source() or the admission loop
            // panicking), workers blocked on the open queue must still
            // be released or thread::scope's join hangs mid-unwind;
            // closing twice on the normal path is a harmless no-op
            let _close_on_unwind = CloseOnDrop(queue);
            for w in 0..workers {
                s.spawn(move || {
                    // Abnormal exit (Err *or* panic, before or after
                    // arrival) must close the queue — else the admission
                    // loop blocks forever on a dead fleet — and must
                    // arrive at the latch exactly once.
                    let mut guard = WorkerGuard {
                        queue,
                        ready,
                        arrived: false,
                        clean_exit: false,
                    };
                    // executor built on this thread: PJRT handles never
                    // cross a thread boundary
                    let mut exec = match factory(w) {
                        Ok(e) => e,
                        Err(e) => {
                            errors.lock().unwrap().push(e.context(
                                format!("worker {w}: executor init")));
                            return; // guard closes queue + arrives
                        }
                    };
                    // a ladder mismatch between ServeConfig and the
                    // factory should abort here, not per-batch mid-run
                    for &c in caps.iter() {
                        if !exec.supports(c) {
                            errors.lock().unwrap().push(anyhow::anyhow!(
                                "worker {w}: {} executor does not \
                                 support configured tier {c}",
                                exec.name()));
                            return; // guard closes queue + arrives
                        }
                    }
                    ready.arrive();
                    guard.arrived = true;
                    let shared = worker::WorkerShared {
                        queue,
                        controller,
                        completions,
                        max_batch_wait: cfg.max_batch_wait,
                    };
                    match worker::run_worker(&shared, w, exec.as_mut()) {
                        Ok(_batches) => guard.clean_exit = true,
                        Err(e) => {
                            errors.lock().unwrap().push(e.context(
                                format!("worker {w}: execution")));
                            // guard closes the queue
                        }
                    }
                });
            }

            // compile/warmup happens on the workers before this clears;
            // the serving clock (and any producer spawned by `source`)
            // starts at readiness, not at spawn
            ready.wait_all();
            let rx = source();
            let start = Instant::now();

            // admission loop: bounded push propagates backpressure to the
            // producer channel when all workers are saturated
            let mut admitted = 0usize;
            while admitted < expected {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(req) => {
                        if queue.push(req).is_err() {
                            break; // a worker failed and closed the queue
                        }
                        admitted += 1;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if queue.is_closed() {
                            break;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            queue.close(); // workers drain the backlog, then exit
            start
        });

        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            // surface every worker failure, not just the first
            let msgs: Vec<String> =
                errs.iter().map(|e| format!("{e:#}")).collect();
            return Err(anyhow::anyhow!(
                "{}/{workers} workers failed: {}", msgs.len(),
                msgs.join(" | ")));
        }
        let completions = completions.into_inner().unwrap();
        Ok(ServeReport::new(completions, start.elapsed().as_secs_f64(),
                            &caps, workers))
    }
}

/// Scope-body drop guard: closes the queue when the engine's calling
/// thread unwinds, so blocked workers exit and the panic can propagate
/// through `thread::scope`'s join instead of deadlocking it.
struct CloseOnDrop<'a>(&'a AdmissionQueue);

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// One-shot readiness latch.  Workers *arrive* (never block); only the
/// engine thread waits for all arrivals.  Unlike `Barrier`, no unwind
/// path — a panicking spawn loop, a failing worker — can strand a peer
/// blocked on it, because nothing but the engine thread ever blocks.
struct ReadyLatch {
    count: Mutex<usize>,
    all: Condvar,
    target: usize,
}

impl ReadyLatch {
    fn new(target: usize) -> ReadyLatch {
        ReadyLatch { count: Mutex::new(0), all: Condvar::new(), target }
    }

    fn arrive(&self) {
        let mut c = self.count.lock().unwrap();
        *c += 1;
        if *c >= self.target {
            self.all.notify_all();
        }
    }

    fn wait_all(&self) {
        let mut c = self.count.lock().unwrap();
        while *c < self.target {
            c = self.all.wait(c).unwrap();
        }
    }
}

/// Worker-thread drop guard: on any abnormal exit (error return or
/// panic, before or after arrival) it closes the admission queue so no
/// producer or sibling blocks forever, and arrives at the readiness
/// latch if this thread has not yet (exactly-once).
struct WorkerGuard<'a> {
    queue: &'a AdmissionQueue,
    ready: &'a ReadyLatch,
    arrived: bool,
    clean_exit: bool,
}

impl Drop for WorkerGuard<'_> {
    fn drop(&mut self) {
        if !self.clean_exit {
            self.queue.close();
        }
        if !self.arrived {
            self.ready.arrive();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_config_mirrors_standard_ladder() {
        let std_cfg = ServeConfig::standard();
        let sim_cfg = ServeConfig::sim();
        assert_eq!(std_cfg.capacities(), sim_cfg.capacities());
        assert!(sim_cfg.tiers.iter().all(|(_, e)| e.starts_with("sim_")));
    }

    #[test]
    fn builders_clamp_to_valid_values() {
        let cfg = ServeConfig::standard().with_workers(0).with_queue_bound(0);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.queue_bound, 1);
    }

    #[test]
    #[should_panic(expected = "scoped thread panicked")]
    fn engine_propagates_factory_panics_instead_of_hanging() {
        // the WorkerGuard must close the queue and arrive at the latch
        // on a panicking factory, so the scope join re-raises a panic
        // (std::thread::scope's fixed "a scoped thread panicked"
        // message, since the worker's handle is implicitly joined)
        // instead of the admission loop hanging forever
        let server = ElasticServer::new(ServeConfig::sim().with_workers(1));
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        drop(tx);
        let _ = server.run(|_| panic!("factory blew up"), rx, 4);
    }

    #[test]
    fn engine_rejects_ladder_mismatch_at_init() {
        // config ladder [1.0, .75, .5, .25] vs executor ladder [.9, .1]:
        // must abort at worker init, not per-batch mid-run
        let server = ElasticServer::new(ServeConfig::sim().with_workers(1));
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        drop(tx);
        let err = server
            .run(sim::factory(SimSpec::instant(), vec![0.9, 0.1]), rx, 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("does not support"), "{err:#}");
    }

    #[test]
    fn engine_surfaces_factory_errors() {
        let server = ElasticServer::new(
            ServeConfig::sim().with_workers(2));
        let (tx, rx) = std::sync::mpsc::channel::<Request>();
        drop(tx);
        let err = server
            .run(|w| anyhow::bail!("no executor for worker {w}"), rx, 4)
            .unwrap_err();
        assert!(format!("{err:#}").contains("executor init"), "{err:#}");
    }
}
