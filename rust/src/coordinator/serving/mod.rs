//! Elastic serving subsystem — the systems realization of "variable
//! inference time compute" (paper §1), grown from the original
//! single-threaded monolith into a handle-based client API:
//!
//! ```text
//!   clients ──▶ EngineHandle::submit / try_submit        (this file)
//!                    │ atomic aggregate-bound reserve (backpressure) /
//!                    │ Admission::{Accepted(Response), Shed(reason)}
//!                    ▼ power-of-two-choices shard pick
//!       [shard 0] [shard 1] .. [shard N-1]               queue.rs
//!           │         │            │    (sharded AdmissionQueue:
//!           ▼         ▼            ▼     per-worker deques + atomic
//!       worker 0  worker 1  worker N-1   depth gauge + work stealing)
//!       └─ class "fast" ─┘  └─ "slow" ─┘  (WorkerClass: one executor
//!                                          factory + one controller
//!                                          per device class)
//!       pop_batch_keyed (tightest-slack head seeds the run,
//!                        ring order breaks ties — deadline-
//!                        aware stealing; class-compatible
//!                        runs only)                      batcher.rs
//!                 -> shed expired deadlines              worker.rs
//!                 -> per-class CapacityController        controller.rs
//!                    (backlog EWMA via the shared atomic
//!                     gauge + deadline slack vs THIS
//!                     class's learned exec times + floors)
//!       form_batch (pad to B×T)                          batcher.rs
//!       Executor::execute(tier, tokens) -> logits
//!          |            |
//!      XlaExecutor   SimExecutor                         worker.rs / sim.rs
//!      (PJRT, owns   (seeded latency
//!       non-Send      model, hermetic)
//!       handles)
//!              \     |     /
//!       per-request Response resolution (one-shot slot)
//!              +
//!       [ServeReport] with per-SLO-class and             report.rs
//!        per-worker-class sections
//! ```
//!
//! [`ElasticEngine::start`] spawns the workers and returns an
//! [`EngineHandle`] immediately (once every worker's executor is warm —
//! compile/warmup never pollutes serving timings).  Each
//! [`submit`](EngineHandle::submit) returns a [`Response`]: a one-shot
//! completion future that resolves to the request's logits, the tier it
//! was served at, and its queue/exec timings — or to a [`ServeError`]
//! if the request was shed (expired deadline), its worker failed, or
//! the engine shut down first.  [`try_submit`](EngineHandle::try_submit)
//! is the non-blocking admission probe: it returns an explicit
//! [`Admission`] verdict instead of blocking on a full queue.
//!
//! Every request carries an [`SloClass`]: an optional latency deadline
//! plus a quality floor tier.  Both flow into the serving worker
//! class's [`CapacityController`] — deadlines pull the served tier down
//! (cheaper = faster) and may shed a request outright once expired,
//! floors clamp it up — and [`ServeReport::class_sections`] accounts
//! for each class separately.
//!
//! The fleet itself may be **heterogeneous**: [`ServeConfig`] carries
//! [`WorkerClass`]es (name + worker count + executor factory — e.g. 2
//! GPU-backed workers and 2 CPU-backed ones behind the same queue),
//! started with [`ElasticEngine::start_fleet`].  Each class gets its
//! own capacity controller, so per-tier exec-time EWMAs learned on a
//! fast backend never demote (or mask demotion for) requests served by
//! a slow one, while all classes observe the same lock-free aggregate
//! depth gauge.  [`ElasticEngine::start`] is the one-class special
//! case.  [`ServeReport::worker_class_sections`] reports each class's
//! tier mix and learned latency model.
//!
//! The engine serves **two workloads** behind the same queue and
//! fleet: one-shot requests (above) and **streaming decode sessions**
//! ([`submit_stream`](EngineHandle::submit_stream), `stream/`).  A
//! session prefills its prompt, then re-enters the admission queue for
//! every generated token — decode steps from many sessions batch
//! together (continuous batching, with a step-kind batch-key dimension
//! keeping prefill and decode apart) and every step gets a *fresh*
//! tier decision from its serving class's controller, so a session's
//! compute degrades gracefully as its deadline budget burns.  Tokens
//! stream to the client through a bounded [`StreamResponse`] channel
//! ending in exactly one `Done`/`Shed` — the same exactly-once
//! drop-guard discipline as `Response`.
//! [`ServeReport::stream_sections`] accounts for sessions separately
//! (tokens/s, per-step tier trajectories, first-token latency).
//!
//! PJRT handles are not `Send`, so each worker constructs its own
//! [`Executor`] on its own thread via its class's factory; the
//! [`SimExecutor`] implementor makes the whole submit → admit → batch →
//! tier-select → execute → resolve pipeline runnable without artifacts
//! (per-class `SimSpec`s simulate a mixed fleet hermetically).

pub mod batcher;
pub mod controller;
pub mod queue;
pub mod report;
pub mod sim;
pub mod stream;
pub mod trace;
pub mod worker;

pub use batcher::{
    batch_key, batch_key_for, floor_rung, form_batch, form_rows, Batch,
    BatchKey, StepKind,
};
pub use controller::{BreakerState, CapacityController};
pub use queue::{AdmissionQueue, TryPushError};
pub use report::{
    ClassStats, Completion, FaultSection, ServeReport, ShedCause,
    ShedRecord, SpecSection, StreamSection, StreamShedRecord,
    WorkerClassInfo, WorkerClassStats,
};
pub use sim::{FaultPlan, SimExecutor, SimSpec};
pub use stream::arena::SessionArena;
pub use stream::{
    DecodeSession, StreamEvent, StreamRequest, StreamResponse,
    StreamStats, StreamTimeout,
};
pub use trace::{
    trace_export, ClassSnapshot, EngineSnapshot, Stamped, TraceCounts,
    TraceRecorder,
};
pub use worker::{ExecOutput, Executor};
#[cfg(feature = "pjrt")]
pub use worker::XlaExecutor;

use std::fmt;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::sync::{Rank, RankedCondvar, RankedMutex};

/// Service contract one request is submitted under: an optional total
/// latency deadline and a minimum acceptable capacity tier.  The class
/// `name` keys the per-class sections of [`ServeReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct SloClass {
    pub name: String,
    /// total latency budget (queue wait + execution); a request whose
    /// deadline has expired by the time a worker picks it up is shed
    /// (its [`Response`] resolves to [`ServeError::DeadlineExceeded`])
    pub deadline: Option<Duration>,
    /// minimum capacity tier this class accepts: the controller never
    /// serves the request below the smallest configured tier at or
    /// above this floor (0.0 = any tier, i.e. pure best-effort)
    pub floor_tier: f32,
}

impl SloClass {
    /// No deadline, no floor: serve whenever, at whatever tier the
    /// backlog dictates.
    pub fn best_effort() -> SloClass {
        SloClass {
            name: "best-effort".into(),
            deadline: None,
            floor_tier: 0.0,
        }
    }

    pub fn named(name: &str) -> SloClass {
        SloClass { name: name.into(), ..SloClass::best_effort() }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> SloClass {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_floor_tier(mut self, floor: f32) -> SloClass {
        self.floor_tier = floor;
        self
    }
}

impl Default for SloClass {
    fn default() -> SloClass {
        SloClass::best_effort()
    }
}

/// One inference request: a fixed-length token row plus its SLO class.
/// The `id` is caller-chosen correlation state (it is echoed back in
/// the [`Completion`]); the engine never interprets it.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub slo: SloClass,
}

impl Request {
    pub fn new(id: u64, tokens: Vec<i32>) -> Request {
        Request { id, tokens, slo: SloClass::best_effort() }
    }

    pub fn with_slo(mut self, slo: SloClass) -> Request {
        self.slo = slo;
        self
    }
}

/// Tolerance for matching an f32 capacity against the configured
/// ladder — the single source of truth for tier identity across
/// worker dispatch, sim validation and report accounting.
pub(crate) const TIER_EPS: f32 = 1e-6;

/// The one rule for "is this the same tier?" in this subsystem.
pub(crate) fn tier_matches(a: f32, b: f32) -> bool {
    (a - b).abs() < TIER_EPS
}

/// Boxed-executor factory owned by one worker class: called once per
/// worker, *on that worker's thread* (PJRT handles are not `Send`),
/// with the worker's global fleet index — so e.g. seeded sim executors
/// get distinct RNG streams even across classes.
pub type ExecutorFactory =
    dyn Fn(usize) -> Result<Box<dyn Executor>> + Send + Sync;

/// One class of workers in a (possibly heterogeneous) fleet: a name
/// (keys the report's [`WorkerClassStats`] sections), a worker count,
/// and the executor factory those workers build their backends with.
/// Each class gets its **own** [`CapacityController`], so the per-tier
/// exec-time EWMAs learned on one device class never leak into
/// another's deadline decisions.
#[derive(Clone)]
pub struct WorkerClass {
    pub name: String,
    pub workers: usize,
    pub factory: Arc<ExecutorFactory>,
}

impl WorkerClass {
    pub fn new<F>(name: &str, workers: usize, factory: F) -> WorkerClass
    where
        F: Fn(usize) -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        WorkerClass {
            name: name.into(),
            workers: workers.max(1),
            factory: Arc::new(factory),
        }
    }
}

impl fmt::Debug for WorkerClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("WorkerClass")
            .field("name", &self.name)
            .field("workers", &self.workers)
            .finish_non_exhaustive()
    }
}

/// Fault-tolerance policy for the fleet: how hard workers fight before
/// giving up on a batch, and how often a class may rebuild a crashed
/// executor before its workers are allowed to die.
///
/// Executor errors are classified in two kinds.  **Transient** errors
/// (any plain `Err` from [`Executor::execute`]) are retried with
/// bounded exponential backoff; a batch still failing after the
/// retries is *bisected* and the halves retried independently, so a
/// single poison request is quarantined (resolved with
/// [`ServeError::Poisoned`]) while its co-batched neighbours survive.
/// **Fatal** errors (a panic inside `execute`, or an error chain
/// carrying a [`FatalExecError`]) mean the executor itself is gone:
/// the worker's in-flight items are requeued and its supervisor
/// rebuilds the executor through the class factory — at most
/// `restart_budget` times per class — before the worker is allowed to
/// die.  Only when the *last* live worker dies does the engine close.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPolicy {
    /// transient-failure retries per (sub-)batch before bisection;
    /// 0 = bisect on the first failure
    pub max_retries: usize,
    /// base backoff before retry `n` (doubling per retry, capped at
    /// 64x); 0 disables the sleep entirely (hermetic tests)
    pub backoff_ms: u64,
    /// executor rebuilds each worker *class* may spend on fatal
    /// faults before its crashing workers are allowed to die;
    /// 0 = never respawn (every fatal fault kills its worker)
    pub restart_budget: usize,
}

impl FaultPolicy {
    pub fn with_max_retries(mut self, retries: usize) -> FaultPolicy {
        self.max_retries = retries;
        self
    }

    pub fn with_backoff_ms(mut self, ms: u64) -> FaultPolicy {
        self.backoff_ms = ms;
        self
    }

    pub fn with_restart_budget(mut self, budget: usize) -> FaultPolicy {
        self.restart_budget = budget;
        self
    }
}

impl Default for FaultPolicy {
    fn default() -> FaultPolicy {
        FaultPolicy { max_retries: 2, backoff_ms: 1, restart_budget: 4 }
    }
}

/// Marker error for **fatal** executor faults: wrap (or chain) one of
/// these in the `Err` returned by [`Executor::execute`] to tell the
/// worker its backend is unrecoverable — no retry, no bisection; the
/// batch is requeued and the supervisor rebuilds the executor under
/// the class's [`FaultPolicy::restart_budget`].  A panic inside
/// `execute` is treated identically.  Plain `Err`s without this
/// marker are transient and go through the retry/bisect ladder.
#[derive(Debug, Clone)]
pub struct FatalExecError(pub String);

impl fmt::Display for FatalExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "fatal executor fault: {}", self.0)
    }
}

impl std::error::Error for FatalExecError {}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// (capacity, entry name), e.g. (0.5, "serve_cap50"), descending.
    pub tiers: Vec<(f32, String)>,
    /// queue depth per shed tier (see [`CapacityController`])
    pub depth_per_tier: f64,
    /// max time a worker waits filling a batch before running partial
    pub max_batch_wait: Duration,
    /// number of execution workers (each owns one `Executor`) for the
    /// single-class [`ElasticEngine::start`] path; ignored when
    /// `worker_classes` is non-empty (the classes carry their own
    /// counts)
    pub workers: usize,
    /// admission queue bound (aggregate across all shards): `submit`
    /// blocks at the bound (backpressure), `try_submit` sheds with an
    /// explicit verdict
    pub queue_bound: usize,
    /// number of admission shards: 0 (the default) = one per worker;
    /// 1 = the pre-sharding single shared deque, kept for A/B
    /// benchmarking (see `BENCH_serving.json`) and tiny deployments
    pub queue_shards: usize,
    /// heterogeneous fleet topology for [`ElasticEngine::start_fleet`]:
    /// one entry per device class (empty = single-class engine via
    /// [`ElasticEngine::start`])
    pub worker_classes: Vec<WorkerClass>,
    /// pages per worker-class [`SessionArena`] — cached decode windows
    /// held between steps of a streaming session; 0 disables the arena
    /// (every decode step recomputes its window from the session table)
    pub arena_pages: usize,
    /// speculative draft ceiling for decode sessions: each admission
    /// drafts up to `spec_k` tokens at a cheap tier and verifies them
    /// in one top-tier pass (`stream::spec`).  0 (the default) =
    /// plain one-token decode.  The effective per-batch `k` adapts to
    /// the class's learned accept rate, never exceeding this ceiling.
    pub spec_k: usize,
    /// retry/backoff, poison-quarantine and respawn policy (see
    /// [`FaultPolicy`])
    pub fault_policy: FaultPolicy,
    /// flight-recorder ring capacity per event lane (one lane per
    /// worker + one engine lane; see [`trace::TraceRecorder`]).  0
    /// (the default) disables tracing entirely: no recorder is built,
    /// every emission site is a single `None` branch, no trace ids
    /// are consumed, and a seeded sim replays bit-identically to the
    /// untraced build
    pub trace_capacity: usize,
}

impl ServeConfig {
    /// The four static-capacity artifact tiers produced by `make
    /// artifacts` (python/compile/aot.py, configs.SERVE_TIERS).
    pub fn standard() -> ServeConfig {
        ServeConfig {
            tiers: vec![
                (1.0, "serve_cap100".into()),
                (0.75, "serve_cap75".into()),
                (0.5, "serve_cap50".into()),
                (0.25, "serve_cap25".into()),
            ],
            depth_per_tier: 8.0,
            max_batch_wait: Duration::from_millis(20),
            workers: 1,
            queue_bound: 256,
            queue_shards: 0,
            worker_classes: Vec::new(),
            arena_pages: 64,
            spec_k: 0,
            fault_policy: FaultPolicy::default(),
            trace_capacity: 0,
        }
    }

    /// Same tier ladder with synthetic entry names — for simulation
    /// executors that never resolve entries against a manifest.
    pub fn sim() -> ServeConfig {
        let mut cfg = ServeConfig::standard();
        for (cap, entry) in &mut cfg.tiers {
            *entry = format!("sim_cap{:02.0}", *cap * 100.0);
        }
        cfg
    }

    pub fn with_workers(mut self, workers: usize) -> ServeConfig {
        self.workers = workers.max(1);
        self
    }

    pub fn with_queue_bound(mut self, bound: usize) -> ServeConfig {
        self.queue_bound = bound.max(1);
        self
    }

    /// Override the admission shard count (0 = one shard per worker).
    pub fn with_queue_shards(mut self, shards: usize) -> ServeConfig {
        self.queue_shards = shards;
        self
    }

    /// Override the per-worker-class session-arena size (0 disables
    /// the arena — every decode step recomputes its window).
    pub fn with_arena_pages(mut self, pages: usize) -> ServeConfig {
        self.arena_pages = pages;
        self
    }

    /// Enable speculative decode with a draft ceiling of `k` tokens
    /// per admission (0 disables it — plain one-token decode).
    pub fn with_spec_k(mut self, k: usize) -> ServeConfig {
        self.spec_k = k;
        self
    }

    /// Override the fleet's fault-tolerance policy (retry count,
    /// backoff base, per-class restart budget).
    pub fn with_fault_policy(mut self, policy: FaultPolicy)
                             -> ServeConfig {
        self.fault_policy = policy;
        self
    }

    /// Enable the flight recorder with `capacity` events per lane
    /// (0, the default, disables tracing — zero overhead beyond one
    /// branch per emission site).
    pub fn with_trace_capacity(mut self, capacity: usize)
                               -> ServeConfig {
        self.trace_capacity = capacity;
        self
    }

    /// Append one worker class to the fleet topology (started with
    /// [`ElasticEngine::start_fleet`]).  `factory` is called once per
    /// worker of this class, on that worker's thread, with the worker's
    /// global fleet index.
    pub fn with_worker_class<F>(mut self, name: &str, workers: usize,
                                factory: F) -> ServeConfig
    where
        F: Fn(usize) -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        self.worker_classes.push(WorkerClass::new(name, workers, factory));
        self
    }

    /// Total workers across the configured topology: the sum of the
    /// class counts, or the flat `workers` field when no classes are
    /// declared.
    pub fn total_workers(&self) -> usize {
        if self.worker_classes.is_empty() {
            self.workers.max(1)
        } else {
            self.worker_classes.iter().map(|c| c.workers.max(1)).sum()
        }
    }

    pub fn with_depth_per_tier(mut self, depth: f64) -> ServeConfig {
        self.depth_per_tier = depth;
        self
    }

    pub fn with_max_batch_wait(mut self, wait: Duration) -> ServeConfig {
        self.max_batch_wait = wait;
        self
    }

    /// Capacity ladder without entry names, descending.
    pub fn capacities(&self) -> Vec<f32> {
        self.tiers.iter().map(|(c, _)| *c).collect()
    }
}

/// Why a request's [`Response`] did not resolve to a [`Reply`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// the SLO deadline expired before a worker could execute the
    /// request; it was shed without spending compute
    DeadlineExceeded,
    /// the engine was shutting down (or had shut down) before the
    /// request could be executed
    ShuttingDown,
    /// the request was dropped mid-flight — its worker panicked or the
    /// engine tore down while it was in a batch
    Dropped,
    /// the executor failed on the request's batch
    ExecFailed(String),
    /// the request was quarantined: its batch kept failing through the
    /// retry/bisect ladder until this request alone still failed — it
    /// is the poison, and it was shed so its co-batched neighbours
    /// could be served
    Poisoned(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded => {
                write!(f, "deadline expired before execution")
            }
            ServeError::ShuttingDown => {
                write!(f, "engine shutting down before execution")
            }
            ServeError::Dropped => {
                write!(f, "request dropped mid-flight (worker failure)")
            }
            ServeError::ExecFailed(msg) => {
                write!(f, "executor failed: {msg}")
            }
            ServeError::Poisoned(msg) => {
                write!(f, "request quarantined as poison: {msg}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What a resolved [`Response`] carries back to the caller: the
/// request's completion record (tier served, queue/exec timings) plus
/// its row of output logits.
#[derive(Debug, Clone)]
pub struct Reply {
    pub completion: Completion,
    pub logits: Vec<f32>,
}

enum SlotState {
    Pending,
    Ready(Result<Reply, ServeError>),
}

struct Slot {
    // Rank::ResponseSlot is a leaf: nothing else is ever acquired
    // while a slot is held (resolution writes and returns)
    state: RankedMutex<SlotState>,
    cv: RankedCondvar,
}

/// One-shot completion future for a submitted request, backed by a
/// mutex/condvar slot.  Exactly one resolution ever lands in the slot:
/// the engine side holds a unique [`Responder`] whose drop guard
/// resolves the slot if no explicit outcome did (worker panic, engine
/// teardown), so a `Response` can never be lost.
pub struct Response {
    id: u64,
    slot: Arc<Slot>,
}

impl Response {
    /// Create the (engine-side responder, caller-side response) pair.
    pub(crate) fn channel(id: u64) -> (Responder, Response) {
        let slot = Arc::new(Slot {
            state: RankedMutex::new(Rank::ResponseSlot,
                                    SlotState::Pending),
            cv: RankedCondvar::new(),
        });
        (Responder { slot: slot.clone(), done: false },
         Response { id, slot })
    }

    /// The caller-chosen request id this response answers.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Has the engine resolved this response yet?  (Non-blocking.)
    pub fn is_ready(&self) -> bool {
        !matches!(*self.slot.state.lock(), SlotState::Pending)
    }

    /// Block until the engine resolves this request.
    pub fn wait(self) -> Result<Reply, ServeError> {
        let mut st = self.slot.state.lock();
        loop {
            if let SlotState::Ready(r) =
                std::mem::replace(&mut *st, SlotState::Pending)
            {
                return r;
            }
            st = self.slot.cv.wait(st);
        }
    }

    /// Block for at most `timeout`; `None` means the request is still
    /// in flight (the response is consumed — its outcome is abandoned).
    pub fn wait_timeout(self, timeout: Duration)
                        -> Option<Result<Reply, ServeError>> {
        let deadline = Instant::now() + timeout;
        let mut st = self.slot.state.lock();
        loop {
            if let SlotState::Ready(r) =
                std::mem::replace(&mut *st, SlotState::Pending)
            {
                return Some(r);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) =
                self.slot.cv.wait_timeout(st, deadline - now);
            st = guard;
        }
    }
}

/// Engine-side write half of a [`Response`].  Not `Clone`: there is
/// exactly one, and its drop guard resolves the slot with
/// [`ServeError::Dropped`] if nothing else did — the exactly-once
/// backbone across worker panics and teardown.
pub(crate) struct Responder {
    slot: Arc<Slot>,
    done: bool,
}

impl Responder {
    pub(crate) fn fulfil(mut self, outcome: Result<Reply, ServeError>) {
        self.set(outcome);
    }

    fn set(&mut self, outcome: Result<Reply, ServeError>) {
        if self.done {
            return;
        }
        self.done = true;
        let mut st = self.slot.state.lock();
        *st = SlotState::Ready(outcome);
        drop(st);
        self.slot.cv.notify_all();
    }
}

impl Drop for Responder {
    fn drop(&mut self) {
        self.set(Err(ServeError::Dropped));
    }
}

/// Verdict of a non-blocking [`EngineHandle::try_submit`].
pub enum Admission {
    /// the request is in the queue; here is its completion future
    Accepted(Response),
    /// the request was NOT admitted — no compute was or will be spent
    /// on it, and no `Response` exists for it
    Shed(ShedReason),
}

/// Why `try_submit` refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// the bounded admission queue is at its bound — the only verdict
    /// load can produce (property-tested: never returned while the
    /// queue has room)
    QueueFull,
    /// the engine has shut down (or a worker failure closed the queue)
    ShuttingDown,
}

/// What a queued work item resolves into: a one-shot request's
/// response slot, or one step of a live decode session (the session's
/// authoritative state lives in the [`stream::SessionTable`]).
pub(crate) enum Outcome {
    OneShot(Responder),
    Stream(stream::StreamStep),
}

/// One queued unit: the request envelope (id, SLO; tokens only for
/// one-shots — a decode step's compute row comes from the session
/// table), its admission stamp (the clock base for *this item's*
/// queue-wait accounting; a decode step is re-stamped at every
/// re-admission), and its outcome half.
pub(crate) struct Pending {
    pub req: Request,
    pub submitted: Instant,
    pub outcome: Outcome,
    /// flight-recorder id for this request/session (0 = untraced
    /// engine; a session's continuation steps all carry the
    /// session's id)
    pub trace_id: u64,
}

impl Pending {
    /// Which workload this item belongs to: one-shot requests and a
    /// session's step 0 are prompt passes (prefill); later session
    /// steps are decode, draft, or verify per the step's phase
    /// ([`stream::spec::StepPhase`]).  Feeds the batch key's
    /// step-kind dimension, so the workloads never share an executed
    /// batch (drafts run cheap tiers, verifies run the top tier).
    pub(crate) fn kind(&self) -> StepKind {
        match &self.outcome {
            Outcome::OneShot(_) => StepKind::Prefill,
            Outcome::Stream(st) if st.step == 0 => StepKind::Prefill,
            Outcome::Stream(st) => match st.phase {
                stream::spec::StepPhase::Decode => StepKind::Decode,
                stream::spec::StepPhase::Draft => StepKind::Draft,
                stream::spec::StepPhase::Verify => StepKind::Verify,
            },
        }
    }

    /// Has this item's deadline expired at `now`?  One-shots burn
    /// their budget from item admission; decode steps burn the
    /// *session's* budget from session admission.
    pub(crate) fn deadline_expired_at(&self, now: Instant) -> bool {
        let Some(deadline) = self.req.slo.deadline else {
            return false;
        };
        now.saturating_duration_since(self.deadline_base()) >= deadline
    }

    /// Remaining deadline budget in ms at `now` (`None` = no
    /// deadline; may be negative for an expired item — maximally
    /// urgent).  For decode steps this is the session's remaining
    /// budget **divided by its remaining steps** — the per-step
    /// allowance the controller can actually spend on this batch — so
    /// a session degrades tiers gradually as its budget burns.
    pub(crate) fn slack_ms_at(&self, now: Instant) -> Option<f64> {
        let deadline = self.req.slo.deadline?;
        let elapsed = now.saturating_duration_since(self.deadline_base());
        let slack =
            deadline.as_secs_f64() * 1e3 - elapsed.as_secs_f64() * 1e3;
        match &self.outcome {
            Outcome::OneShot(_) => Some(slack),
            Outcome::Stream(st) => {
                let remaining =
                    st.max_steps.saturating_sub(st.step).max(1);
                Some(slack / remaining as f64)
            }
        }
    }

    fn deadline_base(&self) -> Instant {
        match &self.outcome {
            Outcome::OneShot(_) => self.submitted,
            Outcome::Stream(st) => st.started,
        }
    }
}

/// State shared between the handle and all worker threads.
pub(crate) struct EngineShared {
    pub queue: AdmissionQueue<Pending>,
    /// one capacity controller per worker class, indexed by class id:
    /// exec-time EWMAs learned on one backend class never demote (or
    /// mask demotion for) batches served by another, while every
    /// controller observes the same lock-free aggregate depth gauge
    pub controllers: Vec<RankedMutex<CapacityController>>,
    /// (class name, worker count) per class, indexed by class id
    pub classes: Vec<(String, usize)>,
    // The four report logs below all carry Rank::ShedLog: they are
    // appended one statement at a time and never held together, so a
    // shared near-last rank keeps the table small without permitting
    // any nesting among them.  Errors ranks strictly last.
    pub completions: RankedMutex<Vec<Completion>>,
    pub sheds: RankedMutex<Vec<ShedRecord>>,
    pub errors: RankedMutex<Vec<String>>,
    pub max_batch_wait: Duration,
    /// configured capacity ladder, descending — workers derive each
    /// request's batch-compatibility key against it without locking
    /// any controller
    pub caps: Vec<f32>,
    /// live decode sessions (the streaming subsystem's owner of
    /// session state; workers read compute rows and route step
    /// results through it)
    pub sessions: stream::SessionTable,
    /// completed decode sessions (terminal `Done`), appended by
    /// workers one lock per batch
    pub stream_done: RankedMutex<Vec<StreamStats>>,
    /// shed decode sessions (terminal `Shed`), appended by workers and
    /// by engine-side teardown
    pub stream_shed: RankedMutex<Vec<StreamShedRecord>>,
    /// one paged session arena per worker class, indexed by class id:
    /// workers of a class share cached decode windows, while classes
    /// never fight over each other's pages
    pub arenas: Vec<stream::arena::SessionArena>,
    /// speculative draft ceiling (`ServeConfig::spec_k`): 0 = plain
    /// decode; > 0 routes admitted sessions through draft/verify steps
    pub spec_k: usize,
    /// per-class speculative counters (drafted / accepted / rejected),
    /// indexed by class id; updated only at verify resolution so
    /// `drafted == accepted + rejected` holds even when a session is
    /// shed mid-draft
    pub spec: Vec<stream::spec::SpecCounters>,
    /// fleet fault-tolerance policy (retries, backoff, restart budget)
    pub policy: FaultPolicy,
    /// workers still running (init failures and abnormal deaths both
    /// decrement); the admission queue closes only when this hits 0 —
    /// one dead worker is a capacity loss, not an outage
    pub live_workers: AtomicUsize,
    /// per-class supervision state, indexed by class id
    pub health: Vec<ClassHealth>,
    /// per-class fault-ladder counters, indexed by class id
    pub faults: Vec<FaultStats>,
    /// flight recorder (`None` = tracing disabled, the default):
    /// every emission site is a single branch on this option
    pub trace: Option<Arc<TraceRecorder>>,
    /// live per-class serving stats (one-shot served/shed tallies +
    /// log2 latency histogram), indexed by class id — always on,
    /// feeding [`EngineHandle::snapshot`] mid-run
    pub live: Vec<trace::LiveClassStats>,
}

/// Per-class supervision state: how many workers failed to init, how
/// many restart tokens remain, and how many respawns were spent.
#[derive(Debug)]
pub(crate) struct ClassHealth {
    /// workers of this class that never finished init (factory error,
    /// ladder mismatch, or startup panic) — a class whose every worker
    /// fails init still fails `start` outright
    pub init_failures: AtomicUsize,
    /// restart tokens left (seeded from `FaultPolicy::restart_budget`)
    pub restarts_left: AtomicUsize,
    /// executors successfully rebuilt after a fatal fault
    pub respawns: AtomicUsize,
}

impl ClassHealth {
    fn new(budget: usize) -> ClassHealth {
        ClassHealth {
            init_failures: AtomicUsize::new(0),
            restarts_left: AtomicUsize::new(budget),
            respawns: AtomicUsize::new(0),
        }
    }
}

/// Per-class counters for the retry → bisect → quarantine ladder,
/// mirrored into [`WorkerClassInfo`] at shutdown.
#[derive(Debug, Default)]
pub(crate) struct FaultStats {
    /// transient execute failures that were retried
    pub retries: AtomicUsize,
    /// batch bisections after retries were exhausted
    pub splits: AtomicUsize,
    /// units (requests / sessions) quarantined as poison
    pub poisoned: AtomicUsize,
}

impl EngineShared {
    /// Free a terminated session's cached window in every class arena.
    /// Idempotent (each arena recycles at most once), so racing
    /// terminal paths — worker `Done`, engine shed, shutdown sweep —
    /// cannot double-free or leak a page.
    pub(crate) fn recycle_session(&self, session: u64) {
        for arena in &self.arenas {
            arena.recycle(session);
        }
    }

    /// One worker is gone (init failure or death, clean or not).
    /// Closes the admission queue only when the LAST live worker goes:
    /// a fleet with any worker left keeps serving — degraded, not dead.
    pub(crate) fn note_worker_dead(&self) {
        // AcqRel, Arc-refcount style: the decrement publishes this
        // worker's final writes (Release) and the thread that observes
        // 1 → 0 acquires all of them before closing the queue
        if self.live_workers.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queue.close();
        }
    }
}

/// The serving engine: [`start`](Self::start) spawns N execution
/// workers behind a shared bounded queue and returns an
/// [`EngineHandle`] for submitting requests and shutting down;
/// [`start_fleet`](Self::start_fleet) does the same for a
/// heterogeneous [`WorkerClass`] topology.
///
/// The engine is backend-agnostic: it only knows the [`Executor`]
/// trait.  Because PJRT handles are not `Send`, executors are
/// constructed *on* their worker thread by their class's factory
/// (called once per worker with the global worker index).
pub struct ElasticEngine;

impl ElasticEngine {
    /// Spawn a single-class worker fleet — the one-factory special case
    /// of [`start_fleet`](Self::start_fleet) — and return once every
    /// worker's executor is built and warm (so submission timings never
    /// include compile/warmup), or with an error if any worker failed
    /// to initialize — in which case the whole fleet is torn down.
    pub fn start<F>(cfg: ServeConfig, factory: F) -> Result<EngineHandle>
    where
        F: Fn(usize) -> Result<Box<dyn Executor>> + Send + Sync + 'static,
    {
        anyhow::ensure!(
            cfg.worker_classes.is_empty(),
            "ServeConfig declares worker classes; start their fleet with \
             ElasticEngine::start_fleet (start's factory would be \
             ambiguous)");
        let class = WorkerClass::new("default", cfg.workers, factory);
        ElasticEngine::start_classes(cfg, vec![class])
    }

    /// Spawn the heterogeneous fleet declared in
    /// [`ServeConfig::worker_classes`]: all classes share one admission
    /// queue and one tier ladder, but each class builds its executors
    /// from its own factory and learns its own per-tier latency model
    /// in its own [`CapacityController`].
    pub fn start_fleet(cfg: ServeConfig) -> Result<EngineHandle> {
        anyhow::ensure!(
            !cfg.worker_classes.is_empty(),
            "no worker classes declared; add ServeConfig::\
             with_worker_class entries or use ElasticEngine::start");
        let classes = cfg.worker_classes.clone();
        ElasticEngine::start_classes(cfg, classes)
    }

    fn start_classes(cfg: ServeConfig, classes: Vec<WorkerClass>)
                     -> Result<EngineHandle> {
        let caps = cfg.capacities();
        anyhow::ensure!(!caps.is_empty(), "no serving tiers configured");
        anyhow::ensure!(
            classes.iter().all(|c| !c.name.is_empty()),
            "worker class names must be non-empty");
        {
            let mut names: Vec<&str> =
                classes.iter().map(|c| c.name.as_str()).collect();
            names.sort_unstable();
            let n = names.len();
            names.dedup();
            anyhow::ensure!(names.len() == n,
                            "duplicate worker class names");
        }
        let workers: usize =
            classes.iter().map(|c| c.workers.max(1)).sum();
        let shards = if cfg.queue_shards == 0 {
            workers
        } else {
            cfg.queue_shards
        };
        let trace = (cfg.trace_capacity > 0).then(|| {
            Arc::new(TraceRecorder::new(
                cfg.trace_capacity,
                workers,
                classes.iter().map(|c| c.name.clone()).collect(),
                Instant::now(),
            ))
        });
        let shared = Arc::new(EngineShared {
            queue: AdmissionQueue::sharded(cfg.queue_bound, shards),
            controllers: classes
                .iter()
                .map(|_| {
                    RankedMutex::new(Rank::Controller,
                                     CapacityController::new(
                                         caps.clone(),
                                         cfg.depth_per_tier))
                })
                .collect(),
            classes: classes
                .iter()
                .map(|c| (c.name.clone(), c.workers.max(1)))
                .collect(),
            completions: RankedMutex::new(Rank::ShedLog, Vec::new()),
            sheds: RankedMutex::new(Rank::ShedLog, Vec::new()),
            errors: RankedMutex::new(Rank::Errors, Vec::new()),
            max_batch_wait: cfg.max_batch_wait,
            caps: caps.clone(),
            sessions: stream::SessionTable::new(),
            stream_done: RankedMutex::new(Rank::ShedLog, Vec::new()),
            stream_shed: RankedMutex::new(Rank::ShedLog, Vec::new()),
            arenas: classes
                .iter()
                .map(|_| stream::arena::SessionArena::new(cfg.arena_pages))
                .collect(),
            spec_k: cfg.spec_k,
            spec: classes
                .iter()
                .map(|_| stream::spec::SpecCounters::new())
                .collect(),
            policy: cfg.fault_policy,
            live_workers: AtomicUsize::new(workers),
            health: classes
                .iter()
                .map(|_| ClassHealth::new(cfg.fault_policy.restart_budget))
                .collect(),
            faults: classes.iter().map(|_| FaultStats::default()).collect(),
            trace,
            live: classes
                .iter()
                .map(|_| trace::LiveClassStats::default())
                .collect(),
        });
        let init = Arc::new(InitLatch::new());
        let caps = Arc::new(caps);
        let mut threads: Vec<JoinHandle<()>> = Vec::with_capacity(workers);
        let mut w = 0usize;
        for (ci, class) in classes.iter().enumerate() {
            for _ in 0..class.workers.max(1) {
                let shared = shared.clone();
                let factory = class.factory.clone();
                let init = init.clone();
                let caps = caps.clone();
                let cname = class.name.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("elastic-{cname}-{w}"))
                    .spawn(move || {
                        // Every exit — clean drain, init failure, or
                        // death after exhausting the restart budget —
                        // decrements the live-worker gauge; the LAST
                        // worker out closes the queue so submitters
                        // never block on a dead fleet.  The watch also
                        // reports to the init latch exactly once, so
                        // `start` never hangs on a panicking factory.
                        let mut watch = DeathWatch {
                            shared: shared.clone(),
                            init: init.clone(),
                            worker: w,
                            class_idx: ci,
                            reported: false,
                        };
                        // executor built on this thread: PJRT handles
                        // never cross a thread boundary
                        let mut exec = match (factory.as_ref())(w) {
                            Ok(e) => e,
                            Err(e) => {
                                watch.fail_init(format!(
                                    "worker {w} ({cname}): executor \
                                     init: {e:#}"));
                                return;
                            }
                        };
                        // a ladder mismatch between ServeConfig and the
                        // class factory should abort here, not per-batch
                        // mid-run
                        for &c in caps.iter() {
                            if !exec.supports(c) {
                                watch.fail_init(format!(
                                    "worker {w} ({cname}): {} executor \
                                     does not support configured tier {c}",
                                    exec.name()));
                                return;
                            }
                        }
                        watch.reported = true;
                        init.arrive(None);
                        // Supervised serve loop: a FATAL worker fault
                        // (panic inside execute, or a FatalExecError in
                        // the chain) hands the in-flight batch back
                        // here instead of killing the engine.  While
                        // the class has restart tokens, the executor is
                        // rebuilt through the factory and the batch
                        // requeued (pinned sessions re-home via the
                        // same requeue_to path stealing already uses);
                        // once the budget is spent, the batch resolves
                        // and the worker dies — the watch closes the
                        // queue only if it was the last one alive.
                        loop {
                            match worker::run_worker(&shared, w, ci,
                                                     exec.as_mut()) {
                                Ok(_batches) => break, // closed + drained
                                Err(fault) => {
                                    shared.errors.lock().push(
                                        format!(
                                            "worker {w} ({cname}): \
                                             execution: {}", fault.msg));
                                    match respawn_executor(
                                        factory.as_ref(), &shared, &caps,
                                        w, ci, &cname)
                                    {
                                        Some(fresh) => {
                                            exec = fresh;
                                            requeue_inflight(
                                                &shared, fault.inflight,
                                                &cname);
                                        }
                                        None => {
                                            worker::fail_batch(
                                                &shared, fault.inflight,
                                                &fault.msg, &cname, w);
                                            break; // watch notes death
                                        }
                                    }
                                }
                            }
                        }
                    });
                match spawned {
                    Ok(t) => threads.push(t),
                    Err(e) => {
                        shared.queue.close();
                        for t in threads {
                            let _ = t.join();
                        }
                        anyhow::bail!("spawning worker {w}: {e}");
                    }
                }
                w += 1;
            }
        }

        // compile/warmup happens on the workers before this clears; the
        // serving clock starts at readiness, not at spawn
        let failures = init.wait_for(workers);
        if !failures.is_empty() {
            // Degraded start: a class that kept at least one initialized
            // worker serves on at reduced capacity (the init errors are
            // recorded for the report's worker_errors).  Only a class
            // whose EVERY worker failed init is unservable — floored
            // traffic routed to it would hang — so that still aborts.
            let zero_class = shared.classes.iter().enumerate().any(
                |(ci, (_, n))| {
                    // Relaxed: every fetch_add happened before that
                    // worker's init.arrive, and wait_for's latch lock
                    // ordered those arrivals before this read
                    shared.health[ci].init_failures.load(Ordering::Relaxed)
                        >= *n
                });
            if zero_class {
                shared.queue.close();
                for t in threads {
                    let _ = t.join();
                }
                anyhow::bail!("{}/{workers} workers failed to start: {}",
                              failures.len(), failures.join(" | "));
            }
            shared.errors.lock()
                .extend(failures.iter().cloned());
        }
        Ok(EngineHandle {
            shared,
            threads,
            workers,
            started: Instant::now(),
        })
    }
}

/// Live handle to a running engine: submit requests, observe depth,
/// shut down.  Dropping the handle without calling
/// [`shutdown`](Self::shutdown) closes the queue (workers drain the
/// backlog and exit on their own) but discards the report.
pub struct EngineHandle {
    shared: Arc<EngineShared>,
    threads: Vec<JoinHandle<()>>,
    workers: usize,
    started: Instant,
}

impl EngineHandle {
    /// Submit one request, blocking while the admission queue is at its
    /// bound (client-side backpressure).  Always returns a [`Response`];
    /// if the engine is shutting down the response resolves immediately
    /// to [`ServeError::ShuttingDown`].  Time spent blocked here counts
    /// toward the request's queue wait — the admission stamp is taken
    /// before the push.
    pub fn submit(&self, req: Request) -> Response {
        let (responder, response) = Response::channel(req.id);
        // deadline-carrying requests are flagged urgent so the queue's
        // deadline-aware steal peek engages only while any are enqueued
        let urgent = req.slo.deadline.is_some();
        let trace_id = self
            .shared
            .trace
            .as_ref()
            .map_or(0, |t| t.alloc_trace_id());
        let pending = Pending {
            submitted: Instant::now(),
            req,
            outcome: Outcome::OneShot(responder),
            trace_id,
        };
        if let Some(t) = &self.shared.trace {
            t.admit(t.engine_lane(), trace_id);
        }
        let pushed = if urgent {
            self.shared.queue.push_urgent(pending)
        } else {
            self.shared.queue.push(pending)
        };
        match pushed {
            Ok(shard) => {
                if let Some(t) = &self.shared.trace {
                    t.place(t.engine_lane(), trace_id, shard);
                }
            }
            Err(p) => {
                self.record_engine_shed(&p);
                if let Outcome::OneShot(responder) = p.outcome {
                    responder.fulfil(Err(ServeError::ShuttingDown));
                }
            }
        }
        response
    }

    /// Non-blocking admission: the request is either accepted (with its
    /// completion future) or shed with an explicit verdict.  A
    /// [`ShedReason::QueueFull`] verdict is only ever produced when the
    /// bounded queue is genuinely at its bound.
    pub fn try_submit(&self, req: Request) -> Admission {
        let (responder, response) = Response::channel(req.id);
        let urgent = req.slo.deadline.is_some();
        let trace_id = self
            .shared
            .trace
            .as_ref()
            .map_or(0, |t| t.alloc_trace_id());
        let pending = Pending {
            submitted: Instant::now(),
            req,
            outcome: Outcome::OneShot(responder),
            trace_id,
        };
        if let Some(t) = &self.shared.trace {
            t.admit(t.engine_lane(), trace_id);
        }
        let pushed = if urgent {
            self.shared.queue.try_push_urgent(pending)
        } else {
            self.shared.queue.try_push(pending)
        };
        match pushed {
            Ok(shard) => {
                if let Some(t) = &self.shared.trace {
                    t.place(t.engine_lane(), trace_id, shard);
                }
                Admission::Accepted(response)
            }
            Err(TryPushError::Full(p)) => {
                // balance the Admit so every trace id reaches exactly
                // one terminal, even for never-admitted rejections
                if let Some(t) = &self.shared.trace {
                    t.terminal(t.engine_lane(), p.trace_id,
                               "rejected-full");
                }
                Admission::Shed(ShedReason::QueueFull)
            }
            Err(TryPushError::Closed(p)) => {
                // engine-side rejection: logged so the report's shed
                // totals reconcile with client-observed verdicts
                // (QueueFull sheds are deliberately NOT logged — they
                // never enter the engine and a load sweep would bury
                // the report under them)
                self.record_engine_shed(&p);
                Admission::Shed(ShedReason::ShuttingDown)
            }
        }
    }

    /// Live mid-run snapshot: queue/worker gauges, per-class counters
    /// and log2-bucket latency percentiles, session and speculative
    /// tallies, and — when tracing is on — the event ledger.  Safe to
    /// call at any time from any thread; reads atomics plus one brief
    /// lock per class (controller) and per report log.
    pub fn snapshot(&self) -> EngineSnapshot {
        let shared = &self.shared;
        let classes = shared
            .classes
            .iter()
            .enumerate()
            .map(|(ci, (name, _))| {
                let live = &shared.live[ci];
                let (breaker, breaker_trips) = {
                    let ctl = shared.controllers[ci].lock();
                    (ctl.breaker_state().name(), ctl.breaker_trips())
                };
                ClassSnapshot {
                    class: name.clone(),
                    // Relaxed gauge reads: a snapshot is a statistical
                    // observation, not a synchronization point
                    served: live.served.load(Ordering::Relaxed),
                    shed: live.shed.load(Ordering::Relaxed),
                    p50_ms: live.latency.quantile_ms(0.5),
                    p99_ms: live.latency.quantile_ms(0.99),
                    latency_samples: live.latency.count(),
                    breaker,
                    breaker_trips,
                    retries: shared.faults[ci]
                        .retries
                        .load(Ordering::Relaxed),
                    splits: shared.faults[ci]
                        .splits
                        .load(Ordering::Relaxed),
                    poisoned: shared.faults[ci]
                        .poisoned
                        .load(Ordering::Relaxed),
                    respawns: shared.health[ci]
                        .respawns
                        .load(Ordering::Relaxed),
                    cache_hits: shared.arenas[ci].hits(),
                    cache_misses: shared.arenas[ci].misses(),
                }
            })
            .collect::<Vec<_>>();
        let (served, shed) = classes.iter().fold(
            (0u64, 0u64),
            |(s, d), c| (s + c.served, d + c.shed));
        let (drafted, accepted, rejected) = shared.spec.iter().fold(
            (0usize, 0usize, 0usize),
            |(d, a, r), s| (d + s.drafted(), a + s.accepted(),
                            r + s.rejected()));
        EngineSnapshot {
            uptime_ms: self.started.elapsed().as_secs_f64() * 1e3,
            queue_depth: shared.queue.len(),
            urgent_depth: shared.queue.urgent_len(),
            // Relaxed: a snapshot gauge — the AcqRel decrement's
            // payload (final writes) is not consumed here
            live_workers: shared.live_workers.load(Ordering::Relaxed),
            served,
            shed,
            sessions_started: shared.sessions.sessions_started(),
            sessions_done: shared.stream_done.lock().len(),
            sessions_shed: shared.stream_shed.lock().len(),
            spec_drafted: drafted,
            spec_accepted: accepted,
            spec_rejected: rejected,
            classes,
            trace: shared.trace.as_ref().map(|t| t.counts()),
        }
    }

    /// The engine's flight recorder, when tracing is enabled — clone
    /// the `Arc` before [`shutdown`](Self::shutdown) to drain and
    /// export the buffered events after the fleet has quiesced.
    pub fn trace_recorder(&self) -> Option<Arc<TraceRecorder>> {
        self.shared.trace.clone()
    }

    /// Log one engine-side `ShuttingDown` rejection (worker_class
    /// "engine": no worker ever saw the request).
    fn record_engine_shed(&self, p: &Pending) {
        self.shared.sheds.lock().push(ShedRecord {
            id: p.req.id,
            class: p.req.slo.name.clone(),
            worker_class: "engine".into(),
            cause: ShedCause::ShuttingDown,
        });
        if let Some(t) = &self.shared.trace {
            t.terminal(t.engine_lane(), p.trace_id, "shed-shutdown");
        }
    }

    /// Start one streaming decode session: the prompt is prefilled,
    /// then up to `max_steps` tokens are generated autoregressively,
    /// each step re-entering the admission queue and getting a fresh
    /// per-step tier decision from the serving class's capacity
    /// controller (decode steps from many sessions batch together —
    /// continuous batching).  Tokens stream back through the returned
    /// [`StreamResponse`] as they land; the stream always ends in
    /// exactly one `Done` or `Shed` event.  Blocks at the admission
    /// bound like [`submit`](Self::submit); if the engine is shutting
    /// down, the stream resolves immediately to `Shed(ShuttingDown)`.
    ///
    /// The session's `SloClass` governs the whole session: `deadline`
    /// is the total budget from submission to the last token (burned
    /// budget shrinks the per-step slack the controller sees, so a
    /// session degrades tiers gracefully before it is ever shed), and
    /// `floor_tier` clamps every step.
    pub fn submit_stream(&self, req: StreamRequest) -> StreamResponse {
        // channel sized to the session: a full run (max_steps tokens +
        // one terminal) never blocks a worker on a slow consumer
        let cap = req.max_steps.max(1) + 1;
        let (sender, response) = stream::channel(req.id, cap);
        let urgent = req.slo.deadline.is_some();
        let trace_id = self
            .shared
            .trace
            .as_ref()
            .map_or(0, |t| t.alloc_trace_id());
        // admit pins the session to one shard; the prefill and every
        // continuation land there, so the workers that drain it keep
        // its arena page warm (placement affinity)
        let pending = self.shared.sessions.admit(
            req,
            sender,
            Instant::now(),
            self.shared.queue.shards(),
            self.shared.spec_k,
            trace_id,
        );
        if let Some(t) = &self.shared.trace {
            t.admit(t.engine_lane(), trace_id);
        }
        let shard = match &pending.outcome {
            Outcome::Stream(st) => st.shard,
            Outcome::OneShot(_) => unreachable!(
                "admit always yields a stream outcome"),
        };
        match self.shared.queue.push_pinned(shard, pending, urgent) {
            Ok(shard) => {
                if let Some(t) = &self.shared.trace {
                    t.place(t.engine_lane(), trace_id, shard);
                }
            }
            Err(p) => {
                if let Outcome::Stream(st) = p.outcome {
                    if let Some(rec) = self.shared.sessions.shed(
                        st.session, ServeError::ShuttingDown, "engine")
                    {
                        self.shared.stream_shed.lock().push(rec);
                        if let Some(t) = &self.shared.trace {
                            t.terminal(t.engine_lane(), p.trace_id,
                                       "shed-shutdown");
                        }
                    }
                    self.shared.recycle_session(st.session);
                }
            }
        }
        response
    }

    /// Begin a graceful shutdown without consuming the handle: stop
    /// admission (subsequent `submit`s resolve to `ShuttingDown`,
    /// `try_submit`s return `Shed(ShuttingDown)` — both logged as
    /// engine-side shed records), let the workers drain the backlog,
    /// and shed in-flight decode sessions at their next step boundary.
    /// Call [`shutdown`](Self::shutdown) afterwards to join the fleet
    /// and collect the report.
    pub fn close(&self) {
        self.shared.queue.close();
    }

    /// Graceful drain: refuse NEW admissions (fresh `submit`s and
    /// `submit_stream`s are turned away as if shutting down) while
    /// live decode sessions keep running — their continuations still
    /// requeue and their remaining steps execute normally.  Polls
    /// until every session has reached a terminal (`Done`) and the
    /// backlog is empty, or `timeout` elapses; then hard-closes the
    /// queue either way (sessions still live at the deadline are shed
    /// at their next step boundary, exactly as [`close`](Self::close)).
    /// Returns `true` iff the fleet drained fully within the budget.
    /// Call [`shutdown`](Self::shutdown) afterwards to join the
    /// workers and collect the report.
    pub fn close_drain(&self, timeout: Duration) -> bool {
        self.shared.queue.drain();
        let deadline = Instant::now() + timeout;
        let drained = loop {
            if self.shared.sessions.live() == 0
                && self.shared.queue.is_empty()
            {
                break true;
            }
            if Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        self.shared.queue.close();
        drained
    }

    /// Current aggregate admission backlog (what the controller
    /// observes) — a single atomic load, never a queue lock.
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Number of admission shards behind this engine (1 = the classic
    /// shared queue; the default is one shard per worker).
    pub fn queue_shards(&self) -> usize {
        self.shared.queue.shards()
    }

    /// The configured capacity ladder, descending.
    pub fn capacities(&self) -> &[f32] {
        &self.shared.caps
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The fleet topology: `(class name, workers)` per worker class, in
    /// declaration order (a single-factory engine reports one "default"
    /// class).
    pub fn worker_classes(&self) -> Vec<(String, usize)> {
        self.shared.classes.clone()
    }

    /// Drain and join: close admission, let the workers finish the
    /// backlog, join them, and return the aggregate report.  Every
    /// admitted request's `Response` is resolved before this returns —
    /// by a worker, or (if the fleet died early) with
    /// [`ServeError::ShuttingDown`] here.  Worker failures surface as
    /// `Err` after all responses are resolved.
    pub fn shutdown(mut self) -> Result<ServeReport> {
        self.shared.queue.close();
        let mut panics = 0usize;
        for t in std::mem::take(&mut self.threads) {
            if t.join().is_err() {
                panics += 1;
            }
        }
        // all workers are gone; anything still queued (fleet died
        // before draining) must be resolved, not leaked
        let mut engine_stream_sheds: Vec<StreamShedRecord> = Vec::new();
        loop {
            let left = self.shared.queue.pop_batch(256, Duration::ZERO);
            if left.is_empty() {
                break;
            }
            for p in left {
                match p.outcome {
                    Outcome::OneShot(responder) => {
                        responder.fulfil(Err(ServeError::ShuttingDown));
                        if let Some(t) = &self.shared.trace {
                            t.terminal(t.engine_lane(), p.trace_id,
                                       "shutdown-drain");
                        }
                    }
                    Outcome::Stream(st) => {
                        if let Some(rec) = self.shared.sessions.shed(
                            st.session, ServeError::ShuttingDown,
                            "engine")
                        {
                            engine_stream_sheds.push(rec);
                            if let Some(t) = &self.shared.trace {
                                t.terminal(t.engine_lane(), p.trace_id,
                                           "shutdown-drain");
                            }
                        }
                        self.shared.recycle_session(st.session);
                    }
                }
            }
        }
        // sessions with no queued step left (their in-flight item died
        // with a worker) must still get their terminal event — the
        // streaming exactly-once backbone at teardown
        for (tid, rec) in self
            .shared
            .sessions
            .shed_all(ServeError::ShuttingDown, "engine")
        {
            engine_stream_sheds.push(rec);
            if let Some(t) = &self.shared.trace {
                t.terminal(t.engine_lane(), tid, "shutdown-drain");
            }
        }
        // every live session now has its terminal; all remaining pages
        // belong to terminated sessions — free them in one sweep
        for arena in &self.shared.arenas {
            arena.clear();
        }
        if !engine_stream_sheds.is_empty() {
            self.shared
                .stream_shed
                .lock()
                .append(&mut engine_stream_sheds);
        }
        let errors =
            std::mem::take(&mut *self.shared.errors.lock());
        let completions =
            std::mem::take(&mut *self.shared.completions.lock());
        let sheds =
            std::mem::take(&mut *self.shared.sheds.lock());
        let stream_done =
            std::mem::take(&mut *self.shared.stream_done.lock());
        let stream_shed =
            std::mem::take(&mut *self.shared.stream_shed.lock());
        // Worker-level faults are a fleet health record, not a failure
        // of THIS call: every response above was resolved exactly once,
        // so the report is complete and the errors ride along in
        // `worker_errors` for post-mortems.  Only a join-level panic —
        // an unwind that escaped the supervision loop itself — still
        // makes shutdown fail.
        if panics > 0 {
            anyhow::bail!("{panics} worker(s) panicked{}",
                          if errors.is_empty() {
                              String::new()
                          } else {
                              format!(" ({} recorded fault(s): {})",
                                      errors.len(), errors.join(" | "))
                          });
        }
        let wall = self.started.elapsed().as_secs_f64();
        // snapshot each class's learned latency model into the report:
        // heterogeneous runs are judged by their per-class estimates
        let class_infos: Vec<WorkerClassInfo> = self
            .shared
            .classes
            .iter()
            .zip(self.shared.controllers.iter())
            .zip(self.shared.arenas.iter())
            .zip(self.shared.spec.iter())
            .zip(self.shared.faults.iter())
            .zip(self.shared.health.iter())
            .map(|((((((name, workers), ctl), arena), spec), faults),
                   health)| {
                let (exec_estimates_ms, breaker_trips) = {
                    let ctl = ctl.lock();
                    (ctl.exec_estimates(), ctl.breaker_trips())
                };
                WorkerClassInfo {
                    name: name.clone(),
                    workers: *workers,
                    exec_estimates_ms,
                    cache_hits: arena.hits(),
                    cache_misses: arena.misses(),
                    drafted: spec.drafted(),
                    accepted: spec.accepted(),
                    rejected: spec.rejected(),
                    verifies: spec.verifies(),
                    // Relaxed: pure statistics, read after the worker
                    // joins above (the join is the synchronization point)
                    retries: faults.retries.load(Ordering::Relaxed),
                    splits: faults.splits.load(Ordering::Relaxed),
                    poisoned: faults.poisoned.load(Ordering::Relaxed),
                    respawns: health.respawns.load(Ordering::Relaxed),
                    breaker_trips,
                }
            })
            .collect();
        let (hits, misses) = self.shared.arenas.iter().fold(
            (0usize, 0usize),
            |(h, m), a| (h + a.hits(), m + a.misses()));
        let (drafted, accepted, rejected) = self.shared.spec.iter().fold(
            (0usize, 0usize, 0usize),
            |(d, a, r), s| (d + s.drafted(), a + s.accepted(),
                            r + s.rejected()));
        Ok(ServeReport::new(completions, sheds, wall, &self.shared.caps,
                            self.workers)
            .with_worker_classes(class_infos)
            .with_streams(self.shared.sessions.sessions_started(),
                          stream_done, stream_shed)
            .with_cache(hits, misses)
            .with_spec(drafted, accepted, rejected,
                       self.shared.sessions.step_items())
            .with_worker_errors(errors))
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        // a dropped handle must not strand workers blocked on an open,
        // empty queue; they drain the backlog and exit detached
        self.shared.queue.close();
    }
}

/// Startup latch: every worker reports init success (`None`) or failure
/// (`Some(msg)`) exactly once; only `start` blocks on it.  No worker
/// ever waits here, so no unwind path can strand a peer.
struct InitLatch {
    // Rank::InitLatch is a leaf like ResponseSlot: arrivals write and
    // return, and no other serving lock is taken under it
    state: RankedMutex<(usize, Vec<String>)>,
    cv: RankedCondvar,
}

impl InitLatch {
    fn new() -> InitLatch {
        InitLatch {
            state: RankedMutex::new(Rank::InitLatch, (0, Vec::new())),
            cv: RankedCondvar::new(),
        }
    }

    fn arrive(&self, failure: Option<String>) {
        let mut st = self.state.lock();
        st.0 += 1;
        if let Some(msg) = failure {
            st.1.push(msg);
        }
        drop(st);
        self.cv.notify_all();
    }

    fn wait_for(&self, target: usize) -> Vec<String> {
        let mut st = self.state.lock();
        while st.0 < target {
            st = self.cv.wait(st);
        }
        st.1.clone()
    }
}

/// Worker-thread drop guard: every exit — clean drain, init failure,
/// supervised death, or an unwinding panic that escaped supervision —
/// decrements the fleet's live-worker gauge exactly once, so the
/// admission queue closes only when the LAST worker is gone (one dead
/// worker is lost capacity, not an outage).  It also reports to the
/// init latch if this thread has not yet (exactly-once, so `start`
/// cannot hang on a panicking factory).
struct DeathWatch {
    shared: Arc<EngineShared>,
    init: Arc<InitLatch>,
    worker: usize,
    class_idx: usize,
    reported: bool,
}

impl DeathWatch {
    /// Record an init failure (factory error or ladder mismatch) and
    /// report it to the latch; the caller returns right after, so the
    /// drop decrements the live gauge.
    fn fail_init(&mut self, msg: String) {
        // Relaxed: the increment is published to start's census read
        // by the init.arrive latch handoff that follows it
        self.shared.health[self.class_idx]
            .init_failures
            .fetch_add(1, Ordering::Relaxed);
        self.reported = true;
        self.init.arrive(Some(msg));
    }
}

impl Drop for DeathWatch {
    fn drop(&mut self) {
        if !self.reported {
            // factory panic: counts as an init failure for the
            // degraded-start census, and the latch must still hear
            // about it or `start` hangs
            self.shared.health[self.class_idx]
                .init_failures
                .fetch_add(1, Ordering::Relaxed);
            self.init.arrive(Some(format!(
                "worker {} died during startup", self.worker)));
        }
        self.shared.note_worker_dead();
    }
}

/// Try to rebuild a dead worker's executor through its class factory,
/// spending one restart token from the class budget.  Returns `None`
/// when the budget is exhausted or the rebuild itself fails (the token
/// is consumed either way — a factory that fails on respawn should not
/// get unlimited attempts).  The factory call is unwind-caught: a
/// panicking factory is a failed respawn, not a dead supervisor.
fn respawn_executor(factory: &ExecutorFactory, shared: &EngineShared,
                    caps: &[f32], worker: usize, class_idx: usize,
                    cname: &str) -> Option<Box<dyn Executor>> {
    let health = &shared.health[class_idx];
    // Relaxed: a pure token counter — the CAS itself decides who gets
    // the restart, no payload rides on its ordering
    if health
        .restarts_left
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed,
                      |n| n.checked_sub(1))
        .is_err()
    {
        shared.errors.lock().push(format!(
            "worker {worker} ({cname}): restart budget exhausted"));
        return None;
    }
    let rebuilt =
        std::panic::catch_unwind(AssertUnwindSafe(|| factory(worker)));
    let exec = match rebuilt {
        Ok(Ok(exec)) => exec,
        Ok(Err(e)) => {
            shared.errors.lock().push(format!(
                "worker {worker} ({cname}): respawn failed: {e:#}"));
            return None;
        }
        Err(_) => {
            shared.errors.lock().push(format!(
                "worker {worker} ({cname}): respawn factory panicked"));
            return None;
        }
    };
    // same ladder probe as startup: a rebuilt executor that lost a
    // tier would fault again on the first floored batch
    for &c in caps {
        if !exec.supports(c) {
            shared.errors.lock().push(format!(
                "worker {worker} ({cname}): respawned executor does \
                 not support configured tier {c}"));
            return None;
        }
    }
    // Relaxed statistic: read by report assembly after the joins
    health.respawns.fetch_add(1, Ordering::Relaxed);
    if let Some(t) = shared.trace.as_deref() {
        t.respawn(worker, class_idx);
    }
    Some(exec)
}

/// Put a faulted worker's in-flight batch back on the queue so the
/// respawned executor (or a stealing sibling) serves it.  Streaming
/// steps re-home to their pinned shard via `requeue_to` — the same
/// path stealing uses — so arena affinity survives the respawn.  If
/// the queue closed meanwhile (fleet-wide teardown won the race), the
/// items resolve as `ShuttingDown`, never leak.
fn requeue_inflight(shared: &EngineShared, items: Vec<Pending>,
                    class_name: &str) {
    for p in items {
        let urgent = p.req.slo.deadline.is_some();
        let trace_id = p.trace_id;
        let pin = match &p.outcome {
            Outcome::Stream(st) => Some(st.shard),
            Outcome::OneShot(_) => None,
        };
        let stale = match pin {
            Some(shard) => shared.queue.requeue_to(shard, p, urgent),
            None => shared.queue.requeue(p, urgent),
        };
        match stale {
            Ok(_) => {
                if let Some(t) = &shared.trace {
                    t.requeue(t.engine_lane(), trace_id);
                }
                continue;
            }
            Err(p) => {
                shared.sheds.lock().push(ShedRecord {
                    id: p.req.id,
                    class: p.req.slo.name.clone(),
                    worker_class: class_name.to_string(),
                    cause: ShedCause::ShuttingDown,
                });
                if let Some(t) = &shared.trace {
                    t.terminal(t.engine_lane(), p.trace_id,
                               "shed-shutdown");
                }
                match p.outcome {
                    Outcome::OneShot(responder) => {
                        responder.fulfil(
                            Err(ServeError::ShuttingDown));
                    }
                    Outcome::Stream(st) => {
                        shared.sessions.shed(st.session,
                                             ServeError::ShuttingDown,
                                             class_name);
                        shared.recycle_session(st.session);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_config_mirrors_standard_ladder() {
        let std_cfg = ServeConfig::standard();
        let sim_cfg = ServeConfig::sim();
        assert_eq!(std_cfg.capacities(), sim_cfg.capacities());
        assert!(sim_cfg.tiers.iter().all(|(_, e)| e.starts_with("sim_")));
    }

    #[test]
    fn builders_clamp_to_valid_values() {
        let cfg = ServeConfig::standard().with_workers(0).with_queue_bound(0);
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.queue_bound, 1);
        assert_eq!(cfg.queue_shards, 0, "default shards follow workers");
        assert_eq!(cfg.with_queue_shards(3).queue_shards, 3);
    }

    #[test]
    fn engine_defaults_to_one_shard_per_worker() {
        let cfg = ServeConfig::sim().with_workers(3);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(), caps)).unwrap();
        assert_eq!(engine.queue_shards(), 3);
        engine.shutdown().unwrap();
    }

    #[test]
    fn queue_shards_override_gives_shared_mode() {
        let cfg = ServeConfig::sim().with_workers(4).with_queue_shards(1);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(), caps)).unwrap();
        assert_eq!(engine.queue_shards(), 1,
                   "explicit shard count must win over the worker count");
        let seq = SimSpec::instant().seq_len;
        let responses: Vec<Response> = (0..16u64)
            .map(|id| engine.submit(Request::new(id, vec![0; seq])))
            .collect();
        for r in responses {
            r.wait().expect("shared mode must still serve everything");
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.completions.len(), 16);
    }

    #[test]
    fn fleet_of_two_classes_serves_and_reports_both() {
        let cfg = ServeConfig::sim()
            .with_queue_bound(64)
            .with_worker_class(
                "fast", 2,
                sim::factory(SimSpec::instant(),
                             ServeConfig::sim().capacities()))
            .with_worker_class(
                "slow", 1,
                sim::factory(SimSpec::instant(),
                             ServeConfig::sim().capacities()));
        assert_eq!(cfg.total_workers(), 3);
        let engine = ElasticEngine::start_fleet(cfg).unwrap();
        assert_eq!(engine.workers(), 3);
        assert_eq!(engine.queue_shards(), 3,
                   "auto sharding follows the fleet total");
        assert_eq!(engine.worker_classes(),
                   vec![("fast".to_string(), 2), ("slow".to_string(), 1)]);
        let seq = SimSpec::instant().seq_len;
        let responses: Vec<Response> = (0..24u64)
            .map(|id| engine.submit(Request::new(id, vec![0; seq])))
            .collect();
        for r in responses {
            r.wait().expect("fleet must serve everything");
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.completions.len(), 24);
        assert_eq!(report.worker_classes.len(), 2);
        assert!(report.completions.iter().all(
            |c| c.worker_class == "fast" || c.worker_class == "slow"));
        // global worker ids partition by declaration order: 0-1 fast,
        // 2 slow
        assert!(report.completions.iter().all(|c| match c.worker {
            0 | 1 => c.worker_class == "fast",
            2 => c.worker_class == "slow",
            _ => false,
        }));
    }

    #[test]
    fn start_rejects_configs_that_declare_classes() {
        let cfg = ServeConfig::sim().with_worker_class(
            "fast", 1,
            sim::factory(SimSpec::instant(),
                         ServeConfig::sim().capacities()));
        let err = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(),
                              ServeConfig::sim().capacities()))
            .err()
            .expect("start with declared classes must fail");
        assert!(format!("{err:#}").contains("start_fleet"), "{err:#}");
    }

    #[test]
    fn start_fleet_rejects_empty_and_duplicate_topologies() {
        let err = ElasticEngine::start_fleet(ServeConfig::sim())
            .err()
            .expect("empty topology must fail");
        assert!(format!("{err:#}").contains("no worker classes"),
                "{err:#}");
        let caps = ServeConfig::sim().capacities();
        let cfg = ServeConfig::sim()
            .with_worker_class(
                "gpu", 1, sim::factory(SimSpec::instant(), caps.clone()))
            .with_worker_class(
                "gpu", 1, sim::factory(SimSpec::instant(), caps));
        let err = ElasticEngine::start_fleet(cfg)
            .err()
            .expect("duplicate class names must fail");
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
    }

    #[test]
    fn fleet_init_failure_names_the_class() {
        let caps = ServeConfig::sim().capacities();
        let cfg = ServeConfig::sim()
            .with_worker_class(
                "ok", 1, sim::factory(SimSpec::instant(), caps))
            .with_worker_class("broken", 1, |w| {
                anyhow::bail!("no device for worker {w}")
            });
        let err = ElasticEngine::start_fleet(cfg)
            .err()
            .expect("failing class factory must fail start_fleet");
        let msg = format!("{err:#}");
        assert!(msg.contains("broken") && msg.contains("executor init"),
                "{msg}");
    }

    #[test]
    fn start_surfaces_factory_panics_instead_of_hanging() {
        // the DeathWatch must report to the init latch on a panicking
        // factory, so start() returns Err instead of blocking forever
        // on a latch nobody will arrive at
        let err = ElasticEngine::start(
            ServeConfig::sim().with_workers(1),
            |_| panic!("factory blew up"))
            .err()
            .expect("panicking factory must fail start");
        assert!(format!("{err:#}").contains("died during startup"),
                "{err:#}");
    }

    #[test]
    fn start_rejects_ladder_mismatch_at_init() {
        // config ladder [1.0, .75, .5, .25] vs executor ladder [.9, .1]:
        // must abort at worker init, not per-batch mid-run
        let err = ElasticEngine::start(
            ServeConfig::sim().with_workers(1),
            sim::factory(SimSpec::instant(), vec![0.9, 0.1]))
            .err()
            .expect("ladder mismatch must fail start");
        assert!(format!("{err:#}").contains("does not support"), "{err:#}");
    }

    #[test]
    fn start_surfaces_factory_errors() {
        let err = ElasticEngine::start(
            ServeConfig::sim().with_workers(2),
            |w| anyhow::bail!("no executor for worker {w}"))
            .err()
            .expect("failing factory must fail start");
        assert!(format!("{err:#}").contains("executor init"), "{err:#}");
    }

    #[test]
    fn submit_wait_shutdown_roundtrip() {
        let cfg = ServeConfig::sim().with_workers(1);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(), caps)).unwrap();
        let seq = SimSpec::instant().seq_len;
        let responses: Vec<Response> = (0..5u64)
            .map(|id| engine.submit(Request::new(id, vec![0; seq])))
            .collect();
        for (i, r) in responses.into_iter().enumerate() {
            assert_eq!(r.id(), i as u64);
            let reply = r.wait().expect("sim request must be served");
            assert_eq!(reply.completion.id, i as u64);
            assert_eq!(reply.completion.class, "best-effort");
            assert!(reply.completion.queue_ms >= 0.0);
            assert!(reply.completion.exec_ms >= 0.0);
            assert!(!reply.logits.is_empty(), "reply must carry logits");
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.completions.len(), 5);
        assert!(report.sheds.is_empty());
    }

    #[test]
    fn snapshot_reports_live_counters_and_trace_ledger() {
        let cfg = ServeConfig::sim()
            .with_workers(2)
            .with_trace_capacity(512);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(), caps)).unwrap();
        let seq = SimSpec::instant().seq_len;
        let responses: Vec<Response> = (0..8u64)
            .map(|id| engine.submit(Request::new(id, vec![0; seq])))
            .collect();
        for r in responses {
            r.wait().expect("sim request must be served");
        }
        let snap = engine.snapshot();
        assert_eq!(snap.served, 8,
                   "live served gauge settles before wait() returns");
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.classes.len(), 1);
        assert_eq!(snap.classes[0].class, "default");
        assert_eq!(snap.classes[0].latency_samples, 8);
        assert!(snap.classes[0].p99_ms >= snap.classes[0].p50_ms);
        assert_eq!(snap.classes[0].breaker, "closed");
        assert!(snap.uptime_ms >= 0.0);
        let counts = snap.trace.expect("tracing is enabled");
        assert!(counts.emitted > 0, "events were recorded");
        let rec = engine.trace_recorder().expect("recorder accessor");
        engine.shutdown().unwrap();
        let events = rec.drain();
        let admits =
            events.iter().filter(|e| e.kind() == "admit").count();
        let terminals =
            events.iter().filter(|e| e.kind() == "terminal").count();
        assert_eq!(admits, 8, "one admit per submitted request");
        assert_eq!(terminals, 8, "one terminal per admit");
        // every request span pairs: admit and terminal share an id
        for e in &events {
            if e.kind() == "terminal" {
                assert!(events.iter().any(|a| a.kind() == "admit"
                                          && a.trace_id == e.trace_id));
                assert_eq!(e.terminal_cause(), Some("served"));
            }
        }
        let c = rec.counts();
        assert_eq!(c.dropped + c.exported, c.emitted,
                   "ledger reconciles after drain");
    }

    #[test]
    fn untraced_engine_allocates_no_trace_ids() {
        let cfg = ServeConfig::sim().with_workers(1);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(), caps)).unwrap();
        assert!(engine.trace_recorder().is_none(),
                "trace_capacity 0 builds no recorder");
        let seq = SimSpec::instant().seq_len;
        let r = engine.submit(Request::new(0, vec![0; seq]));
        r.wait().expect("untraced engine serves normally");
        let snap = engine.snapshot();
        assert!(snap.trace.is_none());
        assert_eq!(snap.served, 1,
                   "live stats stay on without the recorder");
        engine.shutdown().unwrap();
    }

    #[test]
    fn teardown_survives_locks_poisoned_by_a_panicking_holder() {
        // A thread panics while holding the shed log and the error
        // log.  Pre-RankedMutex every later `.lock().unwrap()` on
        // those logs — the workers' batch appends and shutdown's
        // drains included — would have cascaded the panic; the ranked
        // locks absorb the poison, so serving continues and shutdown
        // still assembles a complete ServeReport.
        let cfg = ServeConfig::sim().with_workers(1);
        let caps = cfg.capacities();
        let engine = ElasticEngine::start(
            cfg, sim::factory(SimSpec::instant(), caps)).unwrap();
        let seq = SimSpec::instant().seq_len;
        let responses: Vec<Response> = (0..4u64)
            .map(|id| engine.submit(Request::new(id, vec![0; seq])))
            .collect();
        for r in responses {
            r.wait().expect("sim request must be served");
        }
        let shared = engine.shared.clone();
        let holder = std::thread::spawn(move || {
            // ShedLog then Errors: rank-increasing, so the checker
            // stays quiet — the panic is the point here
            let _sheds = shared.sheds.lock();
            let _errors = shared.errors.lock();
            panic!("die holding the report logs");
        });
        assert!(holder.join().is_err(), "holder must have panicked");
        let late = engine.submit(Request::new(99, vec![0; seq]));
        late.wait().expect("poisoned logs must not break serving");
        let report = engine
            .shutdown()
            .expect("shutdown must complete after lock poisoning");
        assert_eq!(report.completions.len(), 5,
                   "every served request reaches the report");
        assert!(report.sheds.is_empty());
    }

    #[test]
    fn always_failing_executor_quarantines_requests_not_the_engine() {
        // factory succeeds, executor fails every batch transiently:
        // the retry ladder exhausts, the singleton is quarantined as
        // poison — and the ENGINE STAYS UP.  Later submits resolve
        // Poisoned too, never ShuttingDown (pre-supervision, the
        // first failure killed the worker and closed the queue)
        struct FailExec;
        impl Executor for FailExec {
            fn batch(&self) -> usize {
                1
            }
            fn seq_len(&self) -> usize {
                4
            }
            fn execute(&mut self, _tier: f32, _tokens: &[i32])
                       -> Result<ExecOutput> {
                anyhow::bail!("backend exploded")
            }
        }
        let engine = ElasticEngine::start(
            ServeConfig::sim().with_workers(1).with_fault_policy(
                FaultPolicy::default().with_backoff_ms(0)),
            |_| Ok(Box::new(FailExec) as Box<dyn Executor>))
            .unwrap();
        let first = engine.submit(Request::new(0, vec![0; 4]));
        match first.wait() {
            Err(ServeError::Poisoned(msg)) => {
                assert!(msg.contains("backend exploded"), "{msg}");
            }
            other => panic!("want Poisoned, got {other:?}"),
        }
        assert!(!engine.shared.queue.is_closed(),
                "a transient-faulting batch must not kill the fleet");
        let late = engine.submit(Request::new(1, vec![0; 4]));
        match late.wait_timeout(Duration::from_secs(5)) {
            Some(Err(ServeError::Poisoned(_))) => {}
            other => panic!("want Poisoned, got {other:?}"),
        }
        let report = engine
            .shutdown()
            .expect("absorbed transient faults must not fail shutdown");
        let faults = report.fault_sections();
        assert_eq!(faults.len(), 1, "one faulting class");
        assert_eq!(faults[0].poisoned, 2, "both submits quarantined");
        assert!(faults[0].retries >= 2,
                "each quarantine rode the retry ladder first");
        assert_eq!(
            report.sheds.iter()
                .filter(|s| s.cause == ShedCause::Poisoned)
                .count(),
            2, "poisoned sheds are logged with their own cause");
    }

    #[test]
    fn degraded_start_tolerates_partial_init_with_a_surviving_worker() {
        // one of two workers fails init: the class still has capacity,
        // so the engine starts degraded — the failure is recorded in
        // worker_errors, and the surviving worker serves everything
        let spec = SimSpec::instant();
        let caps = ServeConfig::sim().capacities();
        let engine = ElasticEngine::start(
            ServeConfig::sim().with_workers(2),
            move |w| {
                if w == 1 {
                    anyhow::bail!("no device for worker {w}");
                }
                Ok(Box::new(SimExecutor::new(spec, &caps, w))
                    as Box<dyn Executor>)
            })
            .unwrap();
        let responses: Vec<Response> = (0..8u64)
            .map(|id| engine.submit(Request::new(id, vec![0; spec.seq_len])))
            .collect();
        for r in responses {
            r.wait().expect("degraded fleet must still serve");
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.completions.len(), 8);
        assert!(report.worker_errors.iter().any(
                    |e| e.contains("no device")),
                "init failure must be recorded: {:?}",
                report.worker_errors);
    }

    #[test]
    fn fatal_fault_respawns_executor_and_inflight_requests_survive() {
        // the first executor instance dies fatally mid-batch; the
        // supervisor rebuilds it through the factory, the in-flight
        // batch is requeued, and every request is served — callers
        // never observe the fault
        struct FlakyExec {
            deaths: Arc<AtomicUsize>,
        }
        impl Executor for FlakyExec {
            fn batch(&self) -> usize {
                2
            }
            fn seq_len(&self) -> usize {
                4
            }
            fn execute(&mut self, tier: f32, _tokens: &[i32])
                       -> Result<ExecOutput> {
                if self.deaths
                    .compare_exchange(0, 1, Ordering::Relaxed,
                                      Ordering::Relaxed)
                    .is_ok()
                {
                    return Err(FatalExecError("device lost".into())
                        .into());
                }
                Ok(ExecOutput { logits: vec![tier; 2] })
            }
        }
        let deaths = Arc::new(AtomicUsize::new(0));
        let d = deaths.clone();
        let engine = ElasticEngine::start(
            ServeConfig::sim().with_workers(1).with_fault_policy(
                FaultPolicy::default().with_backoff_ms(0)),
            move |_| Ok(Box::new(FlakyExec { deaths: d.clone() })
                as Box<dyn Executor>))
            .unwrap();
        let responses: Vec<Response> = (0..6u64)
            .map(|id| engine.submit(Request::new(id, vec![0; 4])))
            .collect();
        for r in responses {
            r.wait().expect("respawned executor must serve the requeue");
        }
        let report = engine.shutdown().unwrap();
        assert_eq!(report.completions.len(), 6);
        assert_eq!(deaths.load(Ordering::Relaxed), 1,
                   "exactly one death");
        let faults = report.fault_sections();
        assert_eq!(faults.len(), 1);
        assert_eq!(faults[0].respawns, 1);
        assert!(report.worker_errors.iter().any(
                    |e| e.contains("device lost")),
                "the absorbed fault must be recorded: {:?}",
                report.worker_errors);
    }

    #[test]
    fn exhausted_restart_budget_finally_closes_the_engine() {
        // every executor instance dies fatally on every batch: the
        // respawn ladder burns the class budget, the last failure
        // sheds the in-flight batch, and only THEN does the fleet's
        // final worker exit and close admission
        struct AlwaysFatal;
        impl Executor for AlwaysFatal {
            fn batch(&self) -> usize {
                1
            }
            fn seq_len(&self) -> usize {
                4
            }
            fn execute(&mut self, _tier: f32, _tokens: &[i32])
                       -> Result<ExecOutput> {
                Err(FatalExecError("device gone".into()).into())
            }
        }
        let engine = ElasticEngine::start(
            ServeConfig::sim().with_workers(1).with_fault_policy(
                FaultPolicy::default()
                    .with_backoff_ms(0)
                    .with_restart_budget(1)),
            |_| Ok(Box::new(AlwaysFatal) as Box<dyn Executor>))
            .unwrap();
        let first = engine.submit(Request::new(0, vec![0; 4]));
        match first.wait() {
            Err(ServeError::ExecFailed(msg)) => {
                assert!(msg.contains("device gone"), "{msg}");
            }
            other => panic!("want ExecFailed, got {other:?}"),
        }
        // the response resolves before the dying worker closes the
        // queue; wait for the close so the late submit can't race
        // into a still-open queue with no worker left to drain it
        while !engine.shared.queue.is_closed() {
            std::thread::yield_now();
        }
        let late = engine.submit(Request::new(1, vec![0; 4]));
        match late.wait_timeout(Duration::from_secs(5)) {
            Some(Err(ServeError::ShuttingDown)) => {}
            other => panic!("want ShuttingDown, got {other:?}"),
        }
        let report = engine
            .shutdown()
            .expect("budget exhaustion is recorded, not a panic");
        let faults = report.fault_sections();
        assert_eq!(faults.len(), 1);
        assert_eq!(faults[0].respawns, 1, "the one budgeted respawn ran");
        assert!(report.worker_errors.iter().any(
                    |e| e.contains("restart budget exhausted")),
                "{:?}", report.worker_errors);
    }
}
