//! Sharded, bounded MPMC admission queue with work stealing.
//!
//! The original queue was one `Mutex<VecDeque>` + condvar pair: every
//! submit, every worker pop and every controller `len()` observation
//! funnelled through the same lock, which dominated the sim-pipeline
//! hot path at 4+ workers.  This version splits the backlog into
//! per-worker **shards** (each its own small `Mutex<VecDeque>`) while
//! keeping every externally visible contract of the shared queue:
//!
//!  * **Aggregate bound.**  Admission is gated by one `AtomicUsize`
//!    depth gauge: a push first *reserves* a slot (CAS against the
//!    bound), then deposits into a shard.  [`try_push`] therefore
//!    returns `Full` iff the aggregate bound is genuinely hit — never
//!    because one shard happens to be long — and the gauge makes
//!    [`len`](AdmissionQueue::len) a single atomic load, so the
//!    capacity controller and report sampling never contend with
//!    submit/pop.
//!  * **Submit-side balance, slack-weighted under mixed SLO load.**
//!    Deposits pick a shard by power-of-two-choices: a round-robin
//!    probe plus one scrambled probe.  With no deadline'd work
//!    enqueued the shallower probe wins (ties go to the round-robin
//!    probe, so every shard is reachable) — the classic depth rule.
//!    While the urgent gauge is nonzero, placement weighs queued
//!    urgent work first: urgent pushes cluster onto urgent-rich
//!    probes and relaxed pushes avoid them (depth breaks ties), so
//!    the deadline-aware seed peek below — which skips urgent-free
//!    shards — has fewer shards to lock.
//!  * **Deadline-aware work stealing.**  [`pop_batch_as`] scans shards
//!    in ring order starting at the worker's own: an idle worker drains
//!    a hot sibling's shard instead of sleeping.  When seeding a batch,
//!    [`pop_batch_keyed`] peeks every non-empty shard's head and takes
//!    the one with the *tightest slack* (per the caller's slack
//!    function — the worker passes remaining deadline budget), so under
//!    mixed SLO load the run closest to its deadline is served first.
//!    Ties fall back to ring order.  Two guards keep this honest:
//!    the peek only engages while the queue holds items flagged urgent
//!    at push time ([`push_urgent`](AdmissionQueue::push_urgent) — the
//!    engine flags deadline-carrying requests), so deadline-free
//!    traffic pays exactly the old first-non-empty-shard cost; and
//!    every `FAIR_SEED_EVERY`-th seed ignores slack and takes the
//!    plain ring-order head, so a no-deadline head is served within a
//!    bounded number of its own worker's batches even under sustained
//!    deadline'd load (EDF priority, bounded unfairness).
//!  * **Class-aware batches.**  [`pop_batch_keyed`] seeds a batch with
//!    the chosen head and then only collects items whose key matches
//!    (skipped items keep their order) — the mechanism behind
//!    SLO-compatible batch formation in the worker (see `batcher.rs`).
//!  * **Drain-on-close.**  [`close`] wakes every sleeper; a pop that
//!    returns empty means closed *and* fully drained, exactly as
//!    before.
//!  * **Continuation re-admission.**  [`requeue`](AdmissionQueue::requeue)
//!    deposits a decode session's next step without reserving against
//!    the admission bound (a continuation is not a new admission;
//!    bounding it would deadlock the workers that must drain it) while
//!    still counting on the depth gauge, so the backlog signal and
//!    `Shed(QueueFull)` stay honest.
//!  * **Placement affinity.**  A stateful item can be pinned to a
//!    shard: [`requeue_to`](AdmissionQueue::requeue_to) re-deposits a
//!    continuation onto its *affine* shard instead of p2c (the engine
//!    pins each decode session at admission, so its steps keep landing
//!    where its arena pages live), [`push_pinned`](AdmissionQueue::push_pinned)
//!    does the same for a bound-reserving admission (the session's
//!    prefill), and the deadline-aware seed peek prices a head sitting
//!    on its own affine shard as *cheaper to serve* (its cached state
//!    is right there — stealing it elsewhere pays a recompute), via a
//!    fixed slack credit rather than depth alone.  Items with no
//!    affinity (every one-shot, and every caller of the non-affine
//!    entry points) behave exactly as before.
//!
//! Blocking uses two "doorbells" (a lost-wakeup-proof mutex/condvar
//! pair with a sleeper count so the uncontended path skips the lock):
//! consumers sleep for work, producers sleep for room.  The shard lock
//! (a `RankedMutex` at rank `QueueShard` — see `crate::sync` for the
//! lock-order table) is held only for deque surgery on one shard at a
//! time.  Ordering audit (PR 9): the depth gauge, the close flags, the
//! queue-wide urgent gauge and the doorbell sleeper counts stay
//! `SeqCst` — they carry the strand-a-request handshake (see
//! `deposit_reserved` / the exit-time re-check in `pop_batch_keyed`)
//! and the Dekker-style sleepers-vs-ready fast path — while the
//! per-shard length/urgent mirrors are advisory `Relaxed` hints.
//!
//! The queue is generic over its item: the engine stores `Pending`
//! (request + response slot), the tests push bare ids.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::sync::{Rank, RankedCondvar, RankedMutex};

/// Why a non-blocking push was refused.  The item is handed back so the
/// caller can account for it (e.g. resolve its response slot).
#[derive(Debug)]
pub enum TryPushError<T> {
    /// the aggregate depth is at its bound — the only condition that
    /// may surface to clients as a `Shed(QueueFull)` admission verdict
    Full(T),
    /// the queue has been closed — shutdown, or the *last* live
    /// worker died (a supervised worker fault respawns the executor
    /// instead of closing the queue; see the restart budget in
    /// `FaultPolicy`)
    Closed(T),
}

/// One admission shard: a small FIFO deque plus lock-free mirrors of
/// its length and its urgent-item count that submit-side probing and
/// the pop-side seed peek read without the lock.
struct Shard<T> {
    items: RankedMutex<VecDeque<T>>,
    /// mirror of `items.len()`, written under the shard lock, read
    /// lock-free by `pick_shard` and the pop-side empty-shard skip.
    /// Relaxed: purely an advisory placement/skip hint — the SeqCst
    /// depth gauge owns drain/exit correctness, so a stale read costs
    /// at most one redundant lock or one deferred peek
    len: AtomicUsize,
    /// queued items flagged urgent at push time, maintained under the
    /// shard lock (incremented on deposit, decremented when a sweep
    /// takes a finite-slack item).  Read lock-free by the slack-biased
    /// submit placement and by the deadline-aware seed peek, which
    /// skips shards holding no urgent work.  Like the queue-wide
    /// gauge, a slack-less pop path may skip decrements, so it can
    /// over-approximate — costing a redundant peek, never a missed
    /// urgent item.  Relaxed, same advisory-hint rationale as `len`.
    urgent: AtomicUsize,
}

/// Lost-wakeup-proof sleep/wake pair.  Waiters register in `sleepers`,
/// then re-check their condition under the gate lock before parking;
/// wakers make the condition true first and take the gate lock to
/// notify (skipped entirely while nobody is registered), so a wake
/// issued between a waiter's check and its park cannot be lost.
struct Doorbell {
    gate: RankedMutex<()>,
    cv: RankedCondvar,
    /// registered waiters.  SeqCst (Dekker-style): the waiter's
    /// register→re-check and the waker's make-ready→check-sleepers
    /// must interleave in one total order, or the skip-the-lock fast
    /// path in [`ring`](Doorbell::ring) could miss a racing sleeper.
    sleepers: AtomicUsize,
}

impl Doorbell {
    fn new() -> Doorbell {
        Doorbell {
            gate: RankedMutex::new(Rank::Doorbell, ()),
            cv: RankedCondvar::new(),
            sleepers: AtomicUsize::new(0),
        }
    }

    /// Block until `ready()` returns true (re-checked under the gate
    /// lock, so a ring between the check and the park cannot be lost)
    /// or until `deadline` passes.  Returns false iff it timed out.
    fn wait_until(&self, deadline: Option<Instant>,
                  ready: impl Fn() -> bool) -> bool {
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        let mut gate = self.gate.lock();
        let mut on_time = true;
        while !ready() {
            match deadline {
                None => gate = self.cv.wait(gate),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        on_time = false;
                        break;
                    }
                    let (g, _) = self.cv.wait_timeout(gate, d - now);
                    gate = g;
                }
            }
        }
        drop(gate);
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
        on_time
    }

    /// Wake every sleeper; skips the lock when nobody is registered
    /// (the hot-path case: rings are issued on every deposit).
    fn ring(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            self.ring_all();
        }
    }

    /// Unconditional wake (close path: must not miss a racing sleeper).
    fn ring_all(&self) {
        let _gate = self.gate.lock();
        self.cv.notify_all();
    }
}

/// Every `FAIR_SEED_EVERY`-th batch seed ignores slack and takes the
/// plain ring-order head: deadline'd traffic gets EDF priority, but a
/// no-deadline head still gets a guaranteed 1-in-K share of its own
/// worker's seeds, so its wait is bounded under any load.
const FAIR_SEED_EVERY: usize = 8;

/// Slack credit (ms) the deadline-aware seed peek grants a head that
/// sits on its own affine shard: serving it from here reuses its
/// cached arena state, while stealing it to a cold shard pays a
/// full-window recompute.  The credit models that recompute cost, so
/// between two comparably tight heads the cache-holding shard wins;
/// a genuinely tighter deadline elsewhere still outranks the credit.
/// `INFINITY - credit == INFINITY`, so affinity never promotes a
/// deadline-free head into the urgent peek.
const AFFINE_SEED_CREDIT_MS: f64 = 5.0;

/// Sharded bounded FIFO queue shared by the submitting clients and the
/// workers.  See the module docs for the contracts.
pub struct AdmissionQueue<T> {
    shards: Vec<Shard<T>>,
    /// aggregate admitted-but-unpopped depth — THE backpressure gauge
    depth: AtomicUsize,
    bound: usize,
    closed: AtomicBool,
    /// soft-close flag ([`drain`](AdmissionQueue::drain)): new
    /// admissions are refused as if the queue were closed, while
    /// continuations (`requeue`/`requeue_to`) keep landing — so live
    /// decode sessions can run to completion before the hard close
    draining: AtomicBool,
    /// consumers sleep here for work
    doorbell: Doorbell,
    /// producers sleep here for room
    vacancy: Doorbell,
    /// submit-side probe ticket (round-robin base of the two choices)
    ticket: AtomicUsize,
    /// enqueued items flagged urgent at push time (finite deadline
    /// slack).  The deadline-aware seed peek is skipped while this is
    /// zero, so deadline-free traffic never pays the cross-shard peek.
    /// (Items popped through a slack-less path — e.g. the shutdown
    /// drain via [`pop_batch`](AdmissionQueue::pop_batch) — are not
    /// decremented; the counter may over-approximate, which only means
    /// a redundant peek, never a missed urgent item.)
    urgent: AtomicUsize,
    /// seed round counter driving the `FAIR_SEED_EVERY` escape; starts
    /// at 1 so the first urgent seed is slack-aware (deterministic for
    /// tests and for the common lightly-loaded case)
    seed_tick: AtomicUsize,
}

impl<T> AdmissionQueue<T> {
    /// Single-shard queue — behaviourally the original shared queue
    /// (global FIFO), still used by unit tests and 1-worker engines.
    pub fn new(bound: usize) -> AdmissionQueue<T> {
        AdmissionQueue::sharded(bound, 1)
    }

    /// Queue with `shards` independent deques under one aggregate
    /// `bound`.  The engine uses one shard per worker.
    pub fn sharded(bound: usize, shards: usize) -> AdmissionQueue<T> {
        let shards = shards.max(1);
        AdmissionQueue {
            shards: (0..shards)
                .map(|_| Shard {
                    items: RankedMutex::new(Rank::QueueShard,
                                            VecDeque::new()),
                    len: AtomicUsize::new(0),
                    urgent: AtomicUsize::new(0),
                })
                .collect(),
            depth: AtomicUsize::new(0),
            bound: bound.max(1),
            closed: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            doorbell: Doorbell::new(),
            vacancy: Doorbell::new(),
            ticket: AtomicUsize::new(0),
            urgent: AtomicUsize::new(0),
            seed_tick: AtomicUsize::new(1),
        }
    }

    /// Number of shards (1 = the classic shared queue).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Reserve one slot against the aggregate bound.  Success means the
    /// caller owns a queue position and MUST deposit; failure means the
    /// bound is genuinely hit right now.
    fn try_reserve(&self) -> bool {
        let mut cur = self.depth.load(Ordering::SeqCst);
        loop {
            if cur >= self.bound {
                return false;
            }
            match self.depth.compare_exchange(
                cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Power-of-two-choices shard pick: a round-robin probe plus one
    /// scrambled probe.  With no urgent work enqueued the tiebreak is
    /// purely depth (keep the shallower; ties go to the round-robin
    /// probe so every shard is reachable even from an empty start).
    ///
    /// While the urgent gauge is nonzero, placement is **slack
    /// weighted**: an urgent push prefers the probe already holding
    /// more urgent work (urgent items concentrate on few shards, so
    /// the deadline-aware seed peek in [`pop_batch_keyed`] — which
    /// skips urgent-free shards — locks fewer of them), and a relaxed
    /// push prefers the probe holding *less* urgent work (relaxed
    /// arrivals stop landing in front of deadline'd items and the
    /// urgent shards stay short).  Depth breaks urgency ties, so the
    /// old balance rule is recovered exactly whenever urgency does not
    /// distinguish the probes — and always when no deadline'd work is
    /// enqueued (unit-tested).
    fn pick_shard(&self, urgent: bool) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        let t = self.ticket.fetch_add(1, Ordering::Relaxed);
        let a = t % n;
        let h = (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let b = (a + 1 + ((h >> 33) as usize) % (n - 1)) % n;
        if self.urgent.load(Ordering::SeqCst) > 0 {
            // Relaxed mirror reads: placement is a heuristic — a stale
            // probe only mis-balances one deposit, never loses an item
            let ua = self.shards[a].urgent.load(Ordering::Relaxed);
            let ub = self.shards[b].urgent.load(Ordering::Relaxed);
            if ua != ub {
                // urgent work clusters; relaxed work steers clear
                let b_wins = if urgent { ub > ua } else { ub < ua };
                return if b_wins { b } else { a };
            }
        }
        if self.shards[b].len.load(Ordering::Relaxed)
            < self.shards[a].len.load(Ordering::Relaxed)
        {
            b
        } else {
            a
        }
    }

    fn deposit(&self, item: T, urgent: bool) -> usize {
        self.deposit_to(self.pick_shard(urgent), item, urgent)
    }

    fn deposit_to(&self, s: usize, item: T, urgent: bool) -> usize {
        let shard = &self.shards[s];
        let mut items = shard.items.lock();
        // Relaxed mirror writes (advisory hints; published by the
        // shard-lock release for anyone who locks after us)
        items.push_back(item);
        shard.len.store(items.len(), Ordering::Relaxed);
        if urgent {
            shard.urgent.fetch_add(1, Ordering::Relaxed);
        }
        drop(items);
        self.doorbell.ring();
        s
    }

    /// Enqueue one item, blocking while the aggregate depth is at its
    /// bound.  `Ok` carries the shard the item landed on (the flight
    /// recorder's `Place` event; callers that don't trace ignore it).
    /// Returns the item back as `Err` if the queue has been closed
    /// (shutdown, or the last live worker died — individual worker
    /// faults are supervised and respawned, not queue-closing) so the
    /// caller can account for it.
    pub fn push(&self, item: T) -> Result<usize, T> {
        self.push_with(item, false, None)
    }

    /// Like [`push`](Self::push), but flags the item *urgent* — it
    /// carries a finite deadline slack, so the deadline-aware seed peek
    /// must engage while it is enqueued.  The engine routes
    /// deadline-carrying requests here; urgency must agree with the pop
    /// side's slack function (`urgent` ⟺ `slack(item).is_finite()`).
    pub fn push_urgent(&self, item: T) -> Result<usize, T> {
        self.push_with(item, true, None)
    }

    /// [`push`](Self::push)/[`push_urgent`](Self::push_urgent) with the
    /// shard chosen by the caller instead of p2c (`shard` wraps modulo
    /// the shard count).  The engine uses this to land a new decode
    /// session's prefill on the session's affine shard, so its arena
    /// pages are laid down where every later step will look for them.
    /// Bound, close and gauge semantics are identical to `push`.
    pub fn push_pinned(&self, shard: usize, item: T, urgent: bool)
                       -> Result<usize, T> {
        self.push_with(item, urgent, Some(shard))
    }

    /// Is the queue refusing *new admissions*?  True once closed or
    /// draining; continuations check only the hard-close flag.
    fn refusing_admissions(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
            || self.draining.load(Ordering::SeqCst)
    }

    fn push_with(&self, item: T, urgent: bool, at: Option<usize>)
                 -> Result<usize, T> {
        loop {
            if self.refusing_admissions() {
                return Err(item);
            }
            if self.try_reserve() {
                return self.deposit_reserved(item, urgent, at);
            }
            self.vacancy.wait_until(None, || {
                self.refusing_admissions()
                    || self.depth.load(Ordering::SeqCst) < self.bound
            });
        }
    }

    /// Non-blocking enqueue: admit the item iff the queue is open and
    /// the aggregate depth is below its bound (`Ok` carries the landing
    /// shard).  Never waits — this is the admission-verdict path, where
    /// "would block" must surface as an explicit `Full`.
    pub fn try_push(&self, item: T) -> Result<usize, TryPushError<T>> {
        self.try_push_with(item, false)
    }

    /// Non-blocking [`push_urgent`](Self::push_urgent).
    pub fn try_push_urgent(&self, item: T)
                           -> Result<usize, TryPushError<T>> {
        self.try_push_with(item, true)
    }

    fn try_push_with(&self, item: T, urgent: bool)
                     -> Result<usize, TryPushError<T>> {
        if self.refusing_admissions() {
            return Err(TryPushError::Closed(item));
        }
        if !self.try_reserve() {
            return Err(TryPushError::Full(item));
        }
        self.deposit_reserved(item, urgent, None)
            .map_err(TryPushError::Closed)
    }

    /// Second half of a push that already holds a reservation: re-check
    /// the close flag and either deposit or undo.  The re-check closes
    /// a strand-a-request race the old single-mutex queue excluded by
    /// construction: without it, a client could load `closed == false`,
    /// a failing worker could close the queue, every worker could
    /// observe `depth == 0 && closed` and exit, and only then would the
    /// client deposit — into a queue nobody will ever drain.  With it
    /// (plus the workers' exit-time depth re-check in
    /// [`pop_batch_keyed`]), a reservation made before close is always
    /// drained by a worker, and one that races close is undone here so
    /// the caller can resolve the item itself.
    fn deposit_reserved(&self, item: T, urgent: bool, at: Option<usize>)
                        -> Result<usize, T> {
        if self.refusing_admissions() {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            self.vacancy.ring();
            return Err(item);
        }
        if urgent {
            // incremented BEFORE the deposit: a consumer can only take
            // (and decrement for) the item after it lands in a shard,
            // so the counter never underflows
            self.urgent.fetch_add(1, Ordering::SeqCst);
        }
        Ok(match at {
            Some(s) => {
                self.deposit_to(s % self.shards.len(), item, urgent)
            }
            None => self.deposit(item, urgent),
        })
    }

    /// Re-enqueue a *continuation* — a decode session's next step —
    /// without reserving against the admission bound.  Continuations
    /// are not new admissions: making them compete for bound slots
    /// would let a full queue deadlock the workers that must drain it
    /// (every worker blocked re-admitting the step it just finished).
    /// The item still counts on the depth gauge, so the controller's
    /// backlog signal sees it and new `try_submit`s shed while the
    /// engine is saturated with in-flight sessions; the gauge may
    /// transiently exceed `bound`, which the reserve CAS already
    /// treats as full.  Fails only if the queue has been closed.
    pub fn requeue(&self, item: T, urgent: bool) -> Result<usize, T> {
        self.requeue_at(item, urgent, None)
    }

    /// Affine [`requeue`](Self::requeue): deposit the continuation onto
    /// `shard` (modulo the shard count) instead of p2c.  The engine
    /// routes every decode step here with the session's pinned shard,
    /// so a session's steps — and the arena pages its serving workers
    /// hold — stay together instead of scattering across the ring.
    /// Close semantics are identical to `requeue`: the item comes back
    /// as `Err` with no gauge leak, never a block (property-tested —
    /// an affine requeue against a closed queue must not deadlock).
    pub fn requeue_to(&self, shard: usize, item: T, urgent: bool)
                      -> Result<usize, T> {
        self.requeue_at(item, urgent, Some(shard))
    }

    fn requeue_at(&self, item: T, urgent: bool, at: Option<usize>)
                  -> Result<usize, T> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(item);
        }
        self.depth.fetch_add(1, Ordering::SeqCst);
        // same strand-race re-check as deposit_reserved: a close
        // between the flag load and the gauge bump must undo, or the
        // item deposits into a queue no worker will drain
        if self.closed.load(Ordering::SeqCst) {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            self.vacancy.ring();
            return Err(item);
        }
        if urgent {
            self.urgent.fetch_add(1, Ordering::SeqCst);
        }
        Ok(match at {
            Some(s) => {
                self.deposit_to(s % self.shards.len(), item, urgent)
            }
            None => self.deposit(item, urgent),
        })
    }

    /// Saturating decrement of the urgent gauge (a slack-less pop path
    /// may have skipped decrements, so never trust it to cover `n`).
    fn urgent_sub(&self, n: usize) {
        let mut cur = self.urgent.load(Ordering::SeqCst);
        while cur > 0 {
            let next = cur.saturating_sub(n);
            match self.urgent.compare_exchange(
                cur, next, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Move up to `max - out.len()` key-compatible items out of one
    /// shard (seeding `batch_key` from the shard's head when unset).
    /// Skipped items keep their order.  Taken finite-slack items are
    /// retired from the shard's urgent mirror (skipped while the
    /// queue-wide urgent gauge is zero, so deadline-free traffic never
    /// pays the slack calls).  The caller owns the aggregate gauge
    /// accounting.
    fn sweep_shard<K, F, S>(&self, s: usize, max: usize, key: &F,
                            slack: &S, batch_key: &mut Option<K>,
                            out: &mut Vec<T>)
    where
        K: PartialEq,
        F: Fn(&T) -> K,
        S: Fn(&T) -> f64,
    {
        let shard = &self.shards[s];
        // Relaxed empty-skip: a stale nonzero costs one redundant
        // lock; a stale zero defers this shard to the next sweep (the
        // SeqCst depth gauge keeps the worker looping until drained)
        if shard.len.load(Ordering::Relaxed) == 0 {
            return;
        }
        let track_urgent = self.urgent.load(Ordering::SeqCst) > 0;
        let mut urgent_taken = 0usize;
        let mut items = shard.items.lock();
        let mut skipped: VecDeque<T> = VecDeque::new();
        while out.len() < max {
            let Some(it) = items.pop_front() else { break };
            let matches = match batch_key {
                None => true,
                Some(k) => key(&it) == *k,
            };
            if matches {
                if batch_key.is_none() {
                    *batch_key = Some(key(&it));
                }
                if track_urgent && slack(&it).is_finite() {
                    urgent_taken += 1;
                }
                out.push(it);
            } else {
                skipped.push_back(it);
            }
        }
        if !skipped.is_empty() {
            // skipped items go back in front of the untouched tail,
            // in their original order
            skipped.extend(items.drain(..));
            *items = skipped;
        }
        shard.len.store(items.len(), Ordering::Relaxed);
        if urgent_taken > 0 {
            // saturating: a slack-less pop path (shutdown drain) may
            // have skipped decrements, leaving the mirror stale-high.
            // Relaxed CAS: the mirror is an advisory hint (see `Shard`)
            let mut cur = shard.urgent.load(Ordering::Relaxed);
            while cur > 0 {
                match shard.urgent.compare_exchange(
                    cur, cur.saturating_sub(urgent_taken),
                    Ordering::Relaxed, Ordering::Relaxed)
                {
                    Ok(_) => break,
                    Err(now) => cur = now,
                }
            }
        }
    }

    /// Scan shards from `worker`, moving out up to `max` total items
    /// whose key matches `batch_key`.  When the key is unset (batch
    /// seeding) and urgent items are enqueued, the seed is
    /// **deadline-aware**: every non-empty shard's head is peeked and
    /// the tightest-slack one (smallest `slack(head)`) is taken first,
    /// ring order from the worker's own shard breaking ties; every
    /// `FAIR_SEED_EVERY`-th such seed skips the peek and takes the
    /// plain ring-order head instead (bounded unfairness — see the
    /// module docs).  With no urgent items enqueued the seed is plain
    /// ring order, exactly the pre-deadline-aware behavior.  The fill
    /// sweep after the seed is plain ring order.  Skipped items keep
    /// their order.  Decrements the aggregate gauge (and the urgent
    /// gauge) by what was taken and rings producers waiting for room.
    ///
    /// Cost notes: the seed peek is one brief lock per non-empty shard,
    /// paid once per batch and only while deadline'd items are enqueued
    /// (single-shard and deadline-free queues skip it entirely); a
    /// keyed sweep over a shard with incompatible items is O(shard
    /// length) (pop + rebuild under the shard lock).  That is the
    /// inherent price of selective dequeue; it is bounded by the
    /// shard's share of the aggregate bound, and the phase-2 fill loop
    /// only re-sweeps on a depth change within `max_batch_wait`, so
    /// homogeneous traffic (the common case) never pays it.
    /// Returns the number of collected rows that came off a shard
    /// other than `worker`'s own — the work-stealing tally the flight
    /// recorder's `Steal` event reports.
    #[allow(clippy::too_many_arguments)]
    fn collect_into<K, F, S, A>(&self, worker: usize, max: usize, key: &F,
                                slack: &S, affine: &A,
                                batch_key: &mut Option<K>,
                                out: &mut Vec<T>) -> usize
    where
        K: PartialEq,
        F: Fn(&T) -> K,
        S: Fn(&T) -> f64,
        A: Fn(&T) -> Option<usize>,
    {
        let n = self.shards.len();
        let start = worker % n;
        let before = out.len();
        let mut stolen = 0usize;
        let mut seeded: Option<usize> = None;
        // the deadline-aware peek only engages when urgent items are
        // actually enqueued (deadline-free traffic — the common case —
        // pays exactly the old first-non-empty-shard seed), and every
        // FAIR_SEED_EVERY-th urgent seed falls back to ring order so a
        // no-deadline head is still served within a bounded number of
        // its own worker's batches
        if batch_key.is_none()
            && n > 1
            && self.urgent.load(Ordering::SeqCst) > 0
            && self.seed_tick.fetch_add(1, Ordering::Relaxed)
                % FAIR_SEED_EVERY
                != 0
        {
            // deadline-aware seed: prefer the tightest-slack head.
            // Only shards whose urgent mirror is nonzero are peeked —
            // with slack-weighted submit placement clustering urgent
            // work, that is typically far fewer than "every non-empty
            // shard" (the pre-PR-5 cost).  A relaxed-only shard can
            // never win the peek anyway: its head's slack is infinite.
            let mut best: Option<(usize, f64)> = None;
            for i in 0..n {
                let s = (start + i) % n;
                let shard = &self.shards[s];
                // Relaxed mirror reads: the peek is best-effort (a
                // missed shard is caught by the ring-order fill below)
                if shard.len.load(Ordering::Relaxed) == 0
                    || shard.urgent.load(Ordering::Relaxed) == 0
                {
                    continue;
                }
                let items = shard.items.lock();
                if let Some(head) = items.front() {
                    let mut sl = slack(head);
                    // affinity-aware steal cost: a head sitting on its
                    // own affine shard is cheaper to serve from here
                    // (its arena pages are local — stealing it to a
                    // cold shard would pay a recompute), so it peeks
                    // as if its slack were tighter by a fixed credit.
                    // Affinity-free items (`None` — every one-shot)
                    // keep their raw slack, and INFINITY stays
                    // INFINITY, so existing behavior is untouched.
                    if affine(head).map(|a| a % n) == Some(s) {
                        sl -= AFFINE_SEED_CREDIT_MS;
                    }
                    // strict < keeps the ring-order tiebreak
                    let better = match best {
                        None => true,
                        Some((_, b)) => sl < b,
                    };
                    if better {
                        best = Some((s, sl));
                    }
                }
            }
            if let Some((s, _)) = best {
                let pre = out.len();
                self.sweep_shard(s, max, key, slack, batch_key, out);
                if s != start {
                    stolen += out.len() - pre;
                }
                // the seed sweep took everything compatible there; the
                // racing case (another worker emptied it first) falls
                // through to normal ring-order seeding below
                if batch_key.is_some() {
                    seeded = Some(s);
                }
            }
        }
        for i in 0..n {
            if out.len() >= max {
                break;
            }
            let s = (start + i) % n;
            if seeded == Some(s) {
                continue;
            }
            let pre = out.len();
            self.sweep_shard(s, max, key, slack, batch_key, out);
            if s != start {
                stolen += out.len() - pre;
            }
        }
        let taken = out.len() - before;
        if taken > 0 {
            // retire taken urgent items from the queue-wide gauge (skip
            // the slack calls entirely when nothing urgent is enqueued;
            // the per-shard mirrors were already retired by the sweeps)
            if self.urgent.load(Ordering::SeqCst) > 0 {
                let urgent_taken = out[before..]
                    .iter()
                    .filter(|it| slack(it).is_finite())
                    .count();
                if urgent_taken > 0 {
                    self.urgent_sub(urgent_taken);
                }
            }
            self.depth.fetch_sub(taken, Ordering::SeqCst);
            self.vacancy.ring();
        }
        stolen
    }

    /// Pop up to `max` items as the (single-shard) worker 0.
    pub fn pop_batch(&self, max: usize, wait: Duration) -> Vec<T> {
        self.pop_batch_as(0, max, wait)
    }

    /// Pop up to `max` items preferring `worker`'s own shard, stealing
    /// from siblings in ring order when it runs dry.
    pub fn pop_batch_as(&self, worker: usize, max: usize,
                        wait: Duration) -> Vec<T> {
        self.pop_batch_keyed(worker, max, wait, |_| (), |_| f64::INFINITY)
    }

    /// Class-aware, deadline-aware pop: the tightest-slack available
    /// head (per `slack`; ring order from `worker`'s own shard breaks
    /// ties) seeds a batch key and only key-equal items join the batch
    /// (the worker uses the SLO compatibility key from `batcher.rs` and
    /// remaining deadline budget as slack; `f64::INFINITY` = no
    /// deadline).  Blocks until at least one item is available (or the
    /// queue is closed), then waits at most `wait` for compatible items
    /// to fill the batch.  The fill target is clamped to the aggregate
    /// bound: with `bound < max` the queue can never hold a full batch,
    /// so "bound waiting" is "full" and the worker must not burn the
    /// whole `wait` every cycle.  An empty return means closed *and*
    /// fully drained — the worker's signal to exit.
    pub fn pop_batch_keyed<K, F, S>(&self, worker: usize, max: usize,
                                    wait: Duration, key: F, slack: S)
                                    -> Vec<T>
    where
        K: PartialEq,
        F: Fn(&T) -> K,
        S: Fn(&T) -> f64,
    {
        self.pop_batch_keyed_affine(worker, max, wait, key, slack,
                                    |_| None)
    }

    /// [`pop_batch_keyed`](Self::pop_batch_keyed) with an affinity
    /// function: `affine(item)` names the shard the item is pinned to
    /// (`None` = unpinned).  The deadline-aware seed peek grants a
    /// head sitting on its own affine shard a fixed slack credit
    /// ([`AFFINE_SEED_CREDIT_MS`]) — cache-holding shards are cheaper
    /// to serve than raw slack suggests, because serving the head
    /// elsewhere pays a full-window recompute.  With `affine = |_|
    /// None` this is exactly `pop_batch_keyed`.
    pub fn pop_batch_keyed_affine<K, F, S, A>(
        &self, worker: usize, max: usize, wait: Duration, key: F,
        slack: S, affine: A) -> Vec<T>
    where
        K: PartialEq,
        F: Fn(&T) -> K,
        S: Fn(&T) -> f64,
        A: Fn(&T) -> Option<usize>,
    {
        self.pop_batch_keyed_affine_counting(worker, max, wait, key,
                                             slack, affine)
            .0
    }

    /// [`pop_batch_keyed_affine`](Self::pop_batch_keyed_affine) that
    /// also reports how many of the returned rows were *stolen* —
    /// taken from a shard other than `worker`'s own.  The flight
    /// recorder's `Steal` event carries the count; untraced workers
    /// use the plain variant.
    pub fn pop_batch_keyed_affine_counting<K, F, S, A>(
        &self, worker: usize, max: usize, wait: Duration, key: F,
        slack: S, affine: A) -> (Vec<T>, usize)
    where
        K: PartialEq,
        F: Fn(&T) -> K,
        S: Fn(&T) -> f64,
        A: Fn(&T) -> Option<usize>,
    {
        let max = max.max(1);
        let target = max.min(self.bound);
        let mut out: Vec<T> = Vec::new();
        let mut batch_key: Option<K> = None;
        let mut spins = 0usize;
        let mut stolen = 0usize;
        // phase 1: block until at least one item is in hand, or the
        // queue is closed and fully drained
        loop {
            stolen += self.collect_into(worker, max, &key, &slack,
                                        &affine, &mut batch_key,
                                        &mut out);
            if !out.is_empty() {
                break;
            }
            if self.depth.load(Ordering::SeqCst) == 0 {
                if self.closed.load(Ordering::SeqCst) {
                    // exit-time re-check, paired with deposit_reserved:
                    // a submit may have reserved between our depth load
                    // and the close flag landing.  A reservation made
                    // before close always bumps the gauge before we get
                    // here (SeqCst), so "still zero now" means no item
                    // can be in flight — safe to exit.
                    if self.depth.load(Ordering::SeqCst) == 0 {
                        return (out, stolen);
                    }
                    continue;
                }
                self.doorbell.wait_until(None, || {
                    self.depth.load(Ordering::SeqCst) > 0
                        || self.closed.load(Ordering::SeqCst)
                });
            } else {
                // an admitted item is still in flight to its shard
                // (between its depth reservation and its deposit).
                // Spin briefly — the window is normally nanoseconds —
                // then back off to the doorbell (deposits ring it) so
                // a preempted producer is not fought for CPU by every
                // idle worker on an oversubscribed host.
                spins += 1;
                if spins < 64 {
                    std::thread::yield_now();
                } else {
                    self.doorbell.wait_until(
                        Some(Instant::now() + Duration::from_micros(100)),
                        || {
                            self.closed.load(Ordering::SeqCst)
                                || self.depth.load(Ordering::SeqCst) == 0
                                || self.shards.iter().any(|s| {
                                    s.len.load(Ordering::Relaxed) > 0
                                })
                        });
                }
            }
        }
        // phase 2: bounded wait for compatible items to fill the batch.
        // The doorbell predicate is edge-style (any depth change since
        // the last sweep), so incompatible arrivals wake us once each
        // instead of spinning, and the deadline bounds the total wait.
        if out.len() < target && !wait.is_zero() {
            let deadline = Instant::now() + wait;
            while out.len() < target && !self.closed.load(Ordering::SeqCst) {
                stolen += self.collect_into(worker, max, &key, &slack,
                                            &affine, &mut batch_key,
                                            &mut out);
                if out.len() >= target {
                    break;
                }
                // `seen` is the *post-sweep* depth: a partial take above
                // changes the gauge, and capturing the pre-sweep value
                // made the wait below return immediately — one wasted
                // self-wake + re-sweep per partial batch
                let seen = self.depth.load(Ordering::SeqCst);
                if !self.doorbell.wait_until(Some(deadline), || {
                    self.depth.load(Ordering::SeqCst) != seen
                        || self.closed.load(Ordering::SeqCst)
                }) {
                    break; // timed out
                }
            }
            // final sweep: a deposit may have raced the close/timeout
            stolen += self.collect_into(worker, max, &key, &slack,
                                        &affine, &mut batch_key,
                                        &mut out);
        }
        if self.depth.load(Ordering::SeqCst) > 0 {
            // hand remaining work to an idle sibling promptly
            self.doorbell.ring();
        }
        (out, stolen)
    }

    /// Close the queue: pending pushes fail, workers drain and exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.doorbell.ring_all();
        self.vacancy.ring_all();
    }

    /// Begin a graceful drain: refuse new admissions (pushes fail
    /// exactly as if the queue were closed) while continuations keep
    /// flowing, so in-flight decode sessions run to completion instead
    /// of shedding at the next step boundary.  Producers blocked at
    /// the bound are woken to observe the refusal.  The caller decides
    /// when to follow up with the hard [`close`](Self::close).
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        self.vacancy.ring_all();
    }

    /// Has a graceful drain begun?  (A closed queue may report either;
    /// `drain` is a one-way soft stage before `close`.)
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Current aggregate backlog depth — one atomic load, no lock.
    /// This is what the capacity controller observes per batch and what
    /// report sampling reads; neither ever contends with submit/pop.
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[cfg(test)]
    fn shard_len(&self, s: usize) -> usize {
        self.shards[s].len.load(Ordering::Relaxed)
    }

    /// Deterministic shard placement for tests (bypasses the p2c pick).
    #[cfg(test)]
    fn push_to_shard(&self, s: usize, item: T) {
        assert!(self.try_reserve(), "push_to_shard over the bound");
        self.deposit_to(s, item, false);
    }

    /// [`push_to_shard`](Self::push_to_shard) for an urgent item.
    #[cfg(test)]
    fn push_to_shard_urgent(&self, s: usize, item: T) {
        assert!(self.try_reserve(), "push_to_shard over the bound");
        self.urgent.fetch_add(1, Ordering::SeqCst);
        self.deposit_to(s, item, true);
    }

    /// Enqueued items flagged urgent at push time (may transiently
    /// over-approximate; see the field docs).  One atomic load — the
    /// live snapshot's urgent-depth gauge.
    pub fn urgent_len(&self) -> usize {
        self.urgent.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_batch_bounds() {
        let q = AdmissionQueue::new(16);
        for id in 0..10u64 {
            q.push(id).unwrap();
        }
        let a = q.pop_batch(4, Duration::ZERO);
        let b = q.pop_batch(4, Duration::ZERO);
        assert_eq!(a, vec![0, 1, 2, 3]);
        assert_eq!(b, vec![4, 5, 6, 7]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = AdmissionQueue::new(4);
        q.push(0u64).unwrap();
        q.close();
        assert!(q.push(1).is_err());
        let got = q.pop_batch(8, Duration::ZERO);
        assert_eq!(got.len(), 1);
        assert!(q.pop_batch(8, Duration::ZERO).is_empty());
    }

    #[test]
    fn push_blocks_at_bound_until_popped() {
        let q = Arc::new(AdmissionQueue::new(2));
        q.push(0u64).unwrap();
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            // blocks until the consumer below makes room
            q2.push(2).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 2, "bound violated");
        let got = q.pop_batch(1, Duration::ZERO);
        assert_eq!(got[0], 0);
        t.join().unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn try_push_full_only_at_bound_and_closed_after_close() {
        let q = AdmissionQueue::new(2);
        assert!(q.try_push(0u64).is_ok());
        assert!(q.try_push(1).is_ok());
        match q.try_push(2) {
            Err(TryPushError::Full(item)) => assert_eq!(item, 2),
            other => panic!("expected Full at bound, got {other:?}"),
        }
        // popping makes room again: Full is tied to the bound, nothing else
        let got = q.pop_batch(1, Duration::ZERO);
        assert_eq!(got, vec![0]);
        assert!(q.try_push(2).is_ok());
        q.close();
        match q.try_push(3) {
            Err(TryPushError::Closed(item)) => assert_eq!(item, 3),
            other => panic!("expected Closed after close, got {other:?}"),
        }
    }

    #[test]
    fn bound_smaller_than_batch_does_not_dead_wait() {
        // bound 2 < batch 8: the queue can never fill the batch, so the
        // pop must return at the bound instead of burning the full wait
        let q = AdmissionQueue::new(2);
        q.push(0u64).unwrap();
        q.push(1).unwrap();
        let t0 = Instant::now();
        let got = q.pop_batch(8, Duration::from_millis(200));
        assert_eq!(got.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(100),
                "pop dead-waited {:?} for an unfillable batch",
                t0.elapsed());
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(AdmissionQueue::new(8));
        let n_producers = 4;
        let per_producer = 100u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p as u64 * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                loop {
                    let got = q.pop_batch(7, Duration::from_millis(1));
                    if got.is_empty() {
                        return ids;
                    }
                    ids.extend(got);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> =
            (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, want, "requests dropped or duplicated");
    }

    #[test]
    fn sharded_spreads_submissions_across_all_shards() {
        let q = AdmissionQueue::sharded(64, 4);
        for id in 0..32u64 {
            q.push(id).unwrap();
        }
        assert_eq!(q.len(), 32, "aggregate gauge must count all shards");
        for s in 0..4 {
            assert!(q.shard_len(s) > 0,
                    "p2c left shard {s} empty: {:?}",
                    (0..4).map(|i| q.shard_len(i)).collect::<Vec<_>>());
        }
    }

    #[test]
    fn single_popper_steals_across_all_shards() {
        // worker 2's own shard runs dry long before the backlog does:
        // ring-order stealing must still drain every shard
        let q = AdmissionQueue::sharded(64, 4);
        for id in 0..32u64 {
            q.push(id).unwrap();
        }
        let mut got: Vec<u64> = Vec::new();
        while got.len() < 32 {
            let batch = q.pop_batch_as(2, 8, Duration::ZERO);
            assert!(!batch.is_empty(), "pop on a non-empty queue");
            assert!(batch.len() <= 8);
            got.extend(batch);
        }
        got.sort_unstable();
        assert_eq!(got, (0..32).collect::<Vec<_>>(),
                   "stealing dropped or duplicated items");
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_bound_is_aggregate_not_per_shard() {
        let q = AdmissionQueue::sharded(4, 4);
        for id in 0..4u64 {
            assert!(q.try_push(id).is_ok(), "room below the aggregate bound");
        }
        match q.try_push(4) {
            Err(TryPushError::Full(item)) => assert_eq!(item, 4),
            other => panic!("want Full at aggregate bound, got {other:?}"),
        }
        let got = q.pop_batch_as(3, 2, Duration::ZERO);
        assert_eq!(got.len(), 2);
        assert!(q.try_push(4).is_ok());
        assert!(q.try_push(5).is_ok());
        assert!(matches!(q.try_push(6), Err(TryPushError::Full(_))),
                "aggregate bound must re-engage exactly");
    }

    #[test]
    fn sharded_close_drains_every_shard() {
        let q = AdmissionQueue::sharded(32, 3);
        for id in 0..10u64 {
            q.push(id).unwrap();
        }
        q.close();
        let mut got: Vec<u64> = Vec::new();
        loop {
            let batch = q.pop_batch_as(1, 4, Duration::ZERO);
            if batch.is_empty() {
                break;
            }
            got.extend(batch);
        }
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(q.is_empty());
    }

    #[test]
    fn keyed_pop_returns_homogeneous_batches_and_preserves_order() {
        let q = AdmissionQueue::new(16); // single shard: deterministic
        for id in 0..6u64 {
            q.push(id).unwrap();
        }
        let key = |id: &u64| *id % 2;
        let slack = |_: &u64| f64::INFINITY;
        let a = q.pop_batch_keyed(0, 8, Duration::ZERO, key, slack);
        assert_eq!(a, vec![0, 2, 4],
                   "head seeds the key; the other class is skipped");
        assert_eq!(q.len(), 3);
        let b = q.pop_batch_keyed(0, 8, Duration::ZERO, key, slack);
        assert_eq!(b, vec![1, 3, 5], "skipped items kept their order");
        assert!(q.is_empty());
    }

    #[test]
    fn keyed_pop_respects_max_within_class() {
        let q = AdmissionQueue::new(16);
        for id in 0..8u64 {
            q.push(id).unwrap();
        }
        let got = q.pop_batch_keyed(0, 3, Duration::ZERO, |_| (),
                                    |_| f64::INFINITY);
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(q.len(), 5);
    }

    #[test]
    fn placement_stays_depth_p2c_without_urgent_items() {
        // satellite acceptance (half 1): with no deadline'd work
        // enqueued, submit placement must be exactly the old depth-only
        // p2c.  With 2 shards both probes are always examined, so the
        // pick is deterministic: the shallower shard wins.
        let q = AdmissionQueue::sharded(16, 2);
        for id in 0..3u64 {
            q.push_to_shard(0, id);
        }
        q.push(100).unwrap();
        assert_eq!(q.shard_len(1), 1,
                   "relaxed push must take the shallower shard");
        assert_eq!(q.shard_len(0), 3);
    }

    #[test]
    fn urgent_placement_clusters_on_urgent_rich_shard() {
        // satellite acceptance (half 2a): while urgent work is
        // enqueued, an urgent push prefers the probe already holding
        // urgent items — even when it is deeper — so the seed peek has
        // fewer shards to visit.
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard_urgent(0, 0u64);
        q.push_to_shard(0, 1); // shard 0: depth 2 (1 urgent); shard 1: 0
        q.push_urgent(2).unwrap();
        assert_eq!(q.shard_len(0), 3,
                   "urgent push must cluster with queued urgent work");
        assert_eq!(q.shard_len(1), 0);
        assert_eq!(q.urgent_len(), 2);
    }

    #[test]
    fn relaxed_placement_avoids_urgent_shard_despite_depth() {
        // satellite acceptance (half 2b): a relaxed push under mixed
        // SLO load steers away from the urgent shard even when the
        // urgent-free shard is deeper — relaxed arrivals must not land
        // in front of deadline'd items
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard_urgent(0, 0u64); // shard 0: depth 1, urgent 1
        for id in 1..4u64 {
            q.push_to_shard(1, id); // shard 1: depth 3, urgent 0
        }
        q.push(100).unwrap();
        assert_eq!(q.shard_len(1), 4,
                   "relaxed push must avoid the urgent shard");
        assert_eq!(q.shard_len(0), 1);
    }

    #[test]
    fn requeue_bypasses_bound_but_respects_close() {
        let q = AdmissionQueue::new(1);
        q.push(0u64).unwrap();
        assert!(matches!(q.try_push(1), Err(TryPushError::Full(_))));
        // a continuation is not a new admission: it must land even at
        // the bound, and the gauge must count it
        q.requeue(2, false).unwrap();
        assert_eq!(q.len(), 2);
        // new admissions still see "full" while over the bound
        assert!(matches!(q.try_push(3), Err(TryPushError::Full(_))));
        let got = q.pop_batch(8, Duration::ZERO);
        assert_eq!(got, vec![0, 2]);
        assert_eq!(q.len(), 0);
        q.close();
        match q.requeue(4, true) {
            Err(item) => assert_eq!(item, 4),
            Ok(_) => panic!("requeue into a closed queue must fail"),
        }
        assert_eq!(q.len(), 0, "failed requeue must not leak the gauge");
    }

    #[test]
    fn drain_refuses_admissions_but_keeps_continuations_flowing() {
        let q = AdmissionQueue::new(4);
        q.push(0u64).unwrap();
        q.drain();
        assert!(q.is_draining());
        assert!(!q.is_closed(), "draining is not the hard close");
        assert!(q.push(1).is_err(),
                "new admissions must be refused while draining");
        assert!(matches!(q.try_push(2), Err(TryPushError::Closed(_))),
                "drain surfaces to clients as a shutdown, not Full");
        // continuations are the whole point: they must keep landing
        q.requeue(3, false).unwrap();
        q.requeue_to(0, 4, true).unwrap();
        let got = q.pop_batch(8, Duration::ZERO);
        assert_eq!(got, vec![0, 3, 4]);
        q.close();
        assert!(q.requeue(5, false).is_err(),
                "the hard close still stops continuations");
    }

    #[test]
    fn drain_wakes_producers_blocked_at_the_bound() {
        let q = Arc::new(AdmissionQueue::new(1));
        q.push(0u64).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.push(1u64));
        std::thread::sleep(Duration::from_millis(20));
        q.drain();
        assert!(t.join().unwrap().is_err(),
                "a producer blocked at the bound must fail fast on \
                 drain, not sleep through shutdown");
    }

    #[test]
    fn requeue_to_lands_on_the_affine_shard() {
        let q = AdmissionQueue::sharded(16, 4);
        // p2c would spread these; the affine requeue must not
        for id in 0..6u64 {
            q.requeue_to(2, id, false).unwrap();
        }
        assert_eq!(q.shard_len(2), 6,
                   "every affine continuation must land on its shard");
        assert_eq!(q.len(), 6);
        // out-of-range shard hints wrap instead of panicking
        q.requeue_to(6, 100, false).unwrap();
        assert_eq!(q.shard_len(2), 7);
    }

    #[test]
    fn push_pinned_lands_on_the_affine_shard_and_respects_bound() {
        let q = AdmissionQueue::sharded(2, 4);
        q.push_pinned(1, 0u64, false).unwrap();
        q.push_pinned(1, 1, true).unwrap();
        assert_eq!(q.shard_len(1), 2);
        assert_eq!(q.urgent_len(), 1);
        // the aggregate bound still applies to pinned admissions
        assert!(matches!(q.try_push(9), Err(TryPushError::Full(_))));
        q.close();
        assert!(q.push_pinned(1, 2, false).is_err());
    }

    #[test]
    fn requeue_to_into_closed_queue_fails_fast_without_leaks() {
        // satellite acceptance: an affine requeue against a closed
        // queue must return the item promptly (never block on a
        // doorbell nobody rings) and must not leak the depth gauge
        let q = AdmissionQueue::sharded(4, 2);
        q.close();
        for id in 0..8u64 {
            match q.requeue_to(id as usize, id, id % 2 == 0) {
                Err(item) => assert_eq!(item, id),
                Ok(_) => panic!("requeue_to into a closed queue"),
            }
        }
        assert_eq!(q.len(), 0, "failed affine requeues leaked the gauge");
        assert!(q.pop_batch(8, Duration::ZERO).is_empty());
    }

    #[test]
    fn affine_head_wins_the_seed_peek_over_a_slightly_tighter_head() {
        // two urgent heads: shard 0 holds slack 10 (no affinity),
        // shard 1 holds slack 12 *pinned to shard 1*.  Raw slack would
        // seed shard 0; the affinity credit (5 ms) prices shard 1's
        // head at 7 — cheaper to serve where its cache lives.
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard_urgent(0, 10u64);
        q.push_to_shard_urgent(1, 21);
        let slack = |id: &u64| if *id == 10 { 10.0 } else { 12.0 };
        let affine =
            |id: &u64| if *id == 21 { Some(1usize) } else { None };
        let key = |id: &u64| *id;
        let got = q.pop_batch_keyed_affine(0, 1, Duration::ZERO, key,
                                           slack, affine);
        assert_eq!(got, vec![21],
                   "cache-holding head must win the steal peek");
        // without affinity the raw slack decides, proving the credit
        // (not ordering luck) flipped the choice above
        let q2 = AdmissionQueue::sharded(16, 2);
        q2.push_to_shard_urgent(0, 10u64);
        q2.push_to_shard_urgent(1, 21);
        let got = q2.pop_batch_keyed(0, 1, Duration::ZERO, key, slack);
        assert_eq!(got, vec![10]);
    }

    #[test]
    fn affinity_credit_never_outranks_a_truly_tight_deadline() {
        // shard 1's head is pinned here but slack 30; shard 0's head
        // has 2 ms left — the credit (5 ms) must not starve it
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard_urgent(0, 1u64);
        q.push_to_shard_urgent(1, 2);
        let slack = |id: &u64| if *id == 1 { 2.0 } else { 30.0 };
        let affine =
            |id: &u64| if *id == 2 { Some(1usize) } else { None };
        let got = q.pop_batch_keyed_affine(0, 1, Duration::ZERO,
                                           |id: &u64| *id, slack, affine);
        assert_eq!(got, vec![1],
                   "a genuinely tighter deadline outranks affinity");
    }

    #[test]
    fn counting_pop_reports_only_cross_shard_rows_as_stolen() {
        // worker 0's home shard holds one row; shard 1 holds two.  A
        // batch of three must count exactly the two foreign rows as
        // stolen — home-shard rows are free
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard(0, 1u64);
        q.push_to_shard(1, 2);
        q.push_to_shard(1, 3);
        let key = |_: &u64| 0u8;
        let slack = |_: &u64| f64::INFINITY;
        let affine = |_: &u64| None;
        let (mut got, stolen) = q.pop_batch_keyed_affine_counting(
            0, 3, Duration::ZERO, key, slack, affine);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3]);
        assert_eq!(stolen, 2, "exactly the shard-1 rows were stolen");
        // a home-only pop steals nothing
        let q2 = AdmissionQueue::sharded(16, 2);
        q2.push_to_shard(0, 7u64);
        let (got, stolen) = q2.pop_batch_keyed_affine_counting(
            0, 1, Duration::ZERO, key, slack, affine);
        assert_eq!(got, vec![7]);
        assert_eq!(stolen, 0);
    }

    #[test]
    fn urgent_requeue_feeds_the_slack_seed() {
        // a decode step requeued urgent must engage the deadline-aware
        // seed exactly like an urgent push (the gauges agree)
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard(0, 0u64); // relaxed head on the worker's shard
        q.requeue(1, true).unwrap(); // urgent continuation, p2c-placed
        assert_eq!(q.urgent_len(), 1);
        let slack = |id: &u64| if *id == 1 { 1.0 } else { f64::INFINITY };
        let key = |id: &u64| *id;
        let got = q.pop_batch_keyed(0, 1, Duration::ZERO, key, slack);
        assert_eq!(got, vec![1], "urgent continuation must seed first");
        assert_eq!(q.urgent_len(), 0);
    }

    #[test]
    fn steal_seed_prefers_tightest_slack_head() {
        // satellite acceptance: three shards, one item each.  Ring
        // order from worker 0 would seed shard 0's relaxed head (it is
        // also the oldest admission); deadline-aware seeding must take
        // the tightest-slack compatible head first — shard 1, then
        // shard 2, and the relaxed head last.
        let q = AdmissionQueue::sharded(16, 3);
        q.push_to_shard(0, 0u64); // relaxed: no deadline
        q.push_to_shard_urgent(1, 1); // tight: 5 ms of slack
        q.push_to_shard_urgent(2, 2); // medium: 50 ms of slack
        let slack = |id: &u64| [f64::INFINITY, 5.0, 50.0][*id as usize];
        // every id is its own class, so each pop returns only its seed
        let key = |id: &u64| *id;
        let a = q.pop_batch_keyed(0, 4, Duration::ZERO, key, slack);
        assert_eq!(a, vec![1], "tightest-slack head must seed first");
        assert_eq!(q.urgent_len(), 1, "taken urgent items must retire");
        let b = q.pop_batch_keyed(0, 4, Duration::ZERO, key, slack);
        assert_eq!(b, vec![2], "then the next-tightest");
        let c = q.pop_batch_keyed(0, 4, Duration::ZERO, key, slack);
        assert_eq!(c, vec![0], "the relaxed head goes last");
        assert!(q.is_empty());
        assert_eq!(q.urgent_len(), 0);
    }

    #[test]
    fn seed_peek_disengages_without_urgent_items() {
        // deadline-free traffic must pay the plain ring-order seed: a
        // later-shard head with (nominally) tighter slack is NOT
        // preferred when nothing was pushed urgent — the slack peek is
        // gated on the urgent gauge, not on the slack function
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard(0, 0u64);
        q.push_to_shard(1, 1);
        let slack = |id: &u64| if *id == 1 { 1.0 } else { f64::INFINITY };
        let key = |id: &u64| *id;
        let got = q.pop_batch_keyed(0, 1, Duration::ZERO, key, slack);
        assert_eq!(got, vec![0],
                   "no urgent items enqueued => ring-order seed");
    }

    #[test]
    fn fair_seed_escape_bounds_relaxed_head_wait() {
        // sustained urgent load on shard 1 vs one relaxed head on
        // shard 0: slack-aware seeds serve the urgent heads, but the
        // FAIR_SEED_EVERY-th seed must fall back to ring order and
        // serve the relaxed head — its wait is bounded, not starved
        let q = AdmissionQueue::sharded(32, 2);
        q.push_to_shard(0, 100u64); // relaxed: no deadline
        for id in 0..10u64 {
            q.push_to_shard_urgent(1, id); // tight, tightest first
        }
        // closed so the final pop returns empty instead of blocking
        // (pops still drain everything queued before the close)
        q.close();
        let slack = |id: &u64| {
            if *id < 100 { *id as f64 + 1.0 } else { f64::INFINITY }
        };
        let key = |id: &u64| *id; // every pop takes exactly its seed
        let mut order = Vec::new();
        while let Some(&got) =
            q.pop_batch_keyed(0, 1, Duration::ZERO, key, slack).first()
        {
            order.push(got);
        }
        // seed_tick starts at 1, so seeds 1..=7 are slack-aware (urgent
        // heads 0..7 in FIFO order), the 8th is the ring-order escape
        // (the relaxed head), then slack-aware resumes
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5, 6, 100, 7, 8, 9],
                   "relaxed head must be served by the fairness escape");
        assert_eq!(q.urgent_len(), 0);
    }

    #[test]
    fn steal_seed_ties_break_in_ring_order() {
        // equal slack everywhere (the all-best-effort case): the seed
        // must fall back to ring order from the worker's own shard, so
        // no shard starves
        let q = AdmissionQueue::sharded(16, 3);
        q.push_to_shard(0, 0u64);
        q.push_to_shard(1, 1);
        q.push_to_shard(2, 2);
        let got =
            q.pop_batch_as(2, 1, Duration::ZERO);
        assert_eq!(got, vec![2], "worker 2's ring starts at its own shard");
        let got = q.pop_batch_as(2, 1, Duration::ZERO);
        assert_eq!(got, vec![0], "then wraps in ring order");
    }

    #[test]
    fn seed_slack_only_picks_the_head_batch_still_groups_by_key() {
        // the tight head seeds the batch; key-compatible items from
        // other shards still join it, incompatible ones stay queued
        let q = AdmissionQueue::sharded(16, 2);
        q.push_to_shard(0, 10u64); // relaxed (even = key 0)
        q.push_to_shard_urgent(0, 13); // tight (odd = key 1), buried
        q.push_to_shard_urgent(1, 11); // tight (odd = key 1)
        // slack: odd ids are tight, even ids have no deadline
        let slack =
            |id: &u64| if *id % 2 == 1 { 1.0 } else { f64::INFINITY };
        let key = |id: &u64| *id % 2;
        let got = q.pop_batch_keyed(0, 4, Duration::ZERO, key, slack);
        // shard 1's head (11) is the tightest *head*; 13 is tight too
        // but buried behind a relaxed head, so it cannot seed — it
        // joins 11's batch as a key-compatible steal instead
        assert_eq!(got, vec![11, 13],
                   "tight head seeds; compatible buried item joins");
        let rest = q.pop_batch_keyed(0, 4, Duration::ZERO, key, slack);
        assert_eq!(rest, vec![10], "the relaxed head is served next");
    }

    #[test]
    fn sharded_concurrent_stealing_consumers_lose_nothing() {
        let q = Arc::new(AdmissionQueue::sharded(16, 4));
        let n_producers = 4;
        let per_producer = 150u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p as u64 * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for w in 0..4usize {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                loop {
                    let got =
                        q.pop_batch_as(w, 5, Duration::from_micros(200));
                    if got.is_empty() {
                        return ids;
                    }
                    ids.extend(got);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> =
            (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, want, "requests dropped or duplicated");
        assert_eq!(q.len(), 0, "aggregate gauge must return to zero");
    }
}
