//! Bounded multi-producer / multi-consumer admission queue.
//!
//! `std::sync::mpsc` channels are unbounded (and their receivers are
//! single-consumer), so the serving engine uses this small
//! `Mutex<VecDeque>` + condvar queue instead: pushers block in
//! [`AdmissionQueue::push`] once `bound` items are waiting, and every
//! worker pops batches from the shared front in FIFO order.  Closing
//! wakes all waiters; a worker seeing an empty pop after close knows
//! the backlog is fully drained.
//!
//! Since the handle-based front-end, clients push into this queue
//! *directly* (no mpsc bridge in between): [`push`](AdmissionQueue::push)
//! is the blocking backpressure path behind `EngineHandle::submit`, and
//! [`try_push`](AdmissionQueue::try_push) is the non-blocking admission
//! probe behind `try_submit` — its `Full` rejection is the one and only
//! source of `Admission::Shed(ShedReason::QueueFull)` verdicts, so a
//! shed verdict always means the bound was genuinely hit.
//!
//! The queue is generic over its item: the engine stores
//! `Pending` (request + response slot), the tests push bare ids.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why a non-blocking push was refused.  The item is handed back so the
/// caller can account for it (e.g. resolve its response slot).
#[derive(Debug)]
pub enum TryPushError<T> {
    /// the queue is at its bound — the only condition that may surface
    /// to clients as a `Shed(QueueFull)` admission verdict
    Full(T),
    /// the queue has been closed (shutdown or a failed worker)
    Closed(T),
}

/// Bounded FIFO queue shared by the submitting clients and the workers.
pub struct AdmissionQueue<T> {
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    bound: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(bound: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            bound: bound.max(1),
        }
    }

    /// Enqueue one item, blocking while the queue is at its bound.
    /// Returns the item back as `Err` if the queue has been closed
    /// (shutdown or a failed worker) so the caller can account for it.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(item);
            }
            if st.items.len() < self.bound {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking enqueue: admit the item iff the queue is open and
    /// below its bound.  Never waits — this is the admission-verdict
    /// path, where "would block" must surface as an explicit `Full`.
    pub fn try_push(&self, item: T) -> Result<(), TryPushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(TryPushError::Closed(item));
        }
        if st.items.len() >= self.bound {
            return Err(TryPushError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop up to `max` items from the front.  Blocks until at least one
    /// item is available (or the queue is closed), then waits at most
    /// `wait` for the batch to fill.  The fill target is clamped to the
    /// queue bound: with `bound < max` the queue can never hold a full
    /// batch (producers block at the bound), so "bound waiting" is
    /// "full" and the worker must not burn the whole `wait` every cycle.
    /// An empty return means closed *and* fully drained — the worker's
    /// signal to exit.
    pub fn pop_batch(&self, max: usize, wait: Duration) -> Vec<T> {
        let max = max.max(1);
        let target = max.min(self.bound);
        let mut st = self.state.lock().unwrap();
        loop {
            // phase 1: block until work exists or shutdown is complete
            while st.items.is_empty() {
                if st.closed {
                    return Vec::new();
                }
                st = self.not_empty.wait(st).unwrap();
            }
            // phase 2: bounded wait for a fuller batch
            let deadline = Instant::now() + wait;
            while st.items.len() < target && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self
                    .not_empty
                    .wait_timeout(st, deadline - now)
                    .unwrap();
                st = guard;
                if st.items.is_empty() {
                    // another worker drained the queue while we slept
                    break;
                }
                if timeout.timed_out() {
                    break;
                }
            }
            if st.items.is_empty() {
                if st.closed {
                    return Vec::new();
                }
                continue; // restart phase 1
            }
            let take = st.items.len().min(max);
            let out: Vec<T> = st.items.drain(..take).collect();
            let leftover = !st.items.is_empty();
            drop(st);
            self.not_full.notify_all();
            if leftover {
                // hand remaining work to an idle sibling promptly
                self.not_empty.notify_one();
            }
            return out;
        }
    }

    /// Close the queue: pending pushes fail, workers drain and exit.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Current backlog depth (what the capacity controller observes).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_batch_bounds() {
        let q = AdmissionQueue::new(16);
        for id in 0..10u64 {
            q.push(id).unwrap();
        }
        let a = q.pop_batch(4, Duration::ZERO);
        let b = q.pop_batch(4, Duration::ZERO);
        assert_eq!(a, vec![0, 1, 2, 3]);
        assert_eq!(b, vec![4, 5, 6, 7]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = AdmissionQueue::new(4);
        q.push(0u64).unwrap();
        q.close();
        assert!(q.push(1).is_err());
        let got = q.pop_batch(8, Duration::ZERO);
        assert_eq!(got.len(), 1);
        assert!(q.pop_batch(8, Duration::ZERO).is_empty());
    }

    #[test]
    fn push_blocks_at_bound_until_popped() {
        let q = std::sync::Arc::new(AdmissionQueue::new(2));
        q.push(0u64).unwrap();
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            // blocks until the consumer below makes room
            q2.push(2).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 2, "bound violated");
        let got = q.pop_batch(1, Duration::ZERO);
        assert_eq!(got[0], 0);
        t.join().unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn try_push_full_only_at_bound_and_closed_after_close() {
        let q = AdmissionQueue::new(2);
        assert!(q.try_push(0u64).is_ok());
        assert!(q.try_push(1).is_ok());
        match q.try_push(2) {
            Err(TryPushError::Full(item)) => assert_eq!(item, 2),
            other => panic!("expected Full at bound, got {other:?}"),
        }
        // popping makes room again: Full is tied to the bound, nothing else
        let got = q.pop_batch(1, Duration::ZERO);
        assert_eq!(got, vec![0]);
        assert!(q.try_push(2).is_ok());
        q.close();
        match q.try_push(3) {
            Err(TryPushError::Closed(item)) => assert_eq!(item, 3),
            other => panic!("expected Closed after close, got {other:?}"),
        }
    }

    #[test]
    fn bound_smaller_than_batch_does_not_dead_wait() {
        // bound 2 < batch 8: the queue can never fill the batch, so the
        // pop must return at the bound instead of burning the full wait
        let q = AdmissionQueue::new(2);
        q.push(0u64).unwrap();
        q.push(1).unwrap();
        let t0 = Instant::now();
        let got = q.pop_batch(8, Duration::from_millis(200));
        assert_eq!(got.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(100),
                "pop dead-waited {:?} for an unfillable batch",
                t0.elapsed());
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = std::sync::Arc::new(AdmissionQueue::new(8));
        let n_producers = 4;
        let per_producer = 100u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p as u64 * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                loop {
                    let got = q.pop_batch(7, Duration::from_millis(1));
                    if got.is_empty() {
                        return ids;
                    }
                    ids.extend(got);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> =
            (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, want, "requests dropped or duplicated");
    }
}
