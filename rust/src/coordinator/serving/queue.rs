//! Sharded, bounded MPMC admission queue with work stealing.
//!
//! The original queue was one `Mutex<VecDeque>` + condvar pair: every
//! submit, every worker pop and every controller `len()` observation
//! funnelled through the same lock, which dominated the sim-pipeline
//! hot path at 4+ workers.  This version splits the backlog into
//! per-worker **shards** (each its own small `Mutex<VecDeque>`) while
//! keeping every externally visible contract of the shared queue:
//!
//!  * **Aggregate bound.**  Admission is gated by one `AtomicUsize`
//!    depth gauge: a push first *reserves* a slot (CAS against the
//!    bound), then deposits into a shard.  [`try_push`] therefore
//!    returns `Full` iff the aggregate bound is genuinely hit — never
//!    because one shard happens to be long — and the gauge makes
//!    [`len`](AdmissionQueue::len) a single atomic load, so the
//!    capacity controller and report sampling never contend with
//!    submit/pop.
//!  * **Submit-side balance.**  Deposits pick a shard by
//!    power-of-two-choices: a round-robin probe plus one scrambled
//!    probe, keep the shallower (ties go to the round-robin probe, so
//!    every shard is reachable).
//!  * **Work stealing.**  [`pop_batch_as`] scans shards in ring order
//!    starting at the worker's own: an idle worker drains a hot
//!    sibling's shard instead of sleeping.  The ring always takes the
//!    first available head, so no shard starves.
//!  * **Class-aware batches.**  [`pop_batch_keyed`] seeds a batch with
//!    the first available item and then only collects items whose key
//!    matches (skipped items keep their order) — the mechanism behind
//!    SLO-compatible batch formation in the worker (see `batcher.rs`).
//!  * **Drain-on-close.**  [`close`] wakes every sleeper; a pop that
//!    returns empty means closed *and* fully drained, exactly as
//!    before.
//!
//! Blocking uses two "doorbells" (a lost-wakeup-proof mutex/condvar
//! pair with a sleeper count so the uncontended path skips the lock):
//! consumers sleep for work, producers sleep for room.  `Mutex` is held
//! only for deque surgery on one shard at a time; the gauge, the closed
//! flag and the shard-length mirrors are all `SeqCst` atomics.
//!
//! The queue is generic over its item: the engine stores `Pending`
//! (request + response slot), the tests push bare ids.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a non-blocking push was refused.  The item is handed back so the
/// caller can account for it (e.g. resolve its response slot).
#[derive(Debug)]
pub enum TryPushError<T> {
    /// the aggregate depth is at its bound — the only condition that
    /// may surface to clients as a `Shed(QueueFull)` admission verdict
    Full(T),
    /// the queue has been closed (shutdown or a failed worker)
    Closed(T),
}

/// One admission shard: a small FIFO deque plus a mirror of its length
/// that submit-side probing reads without the lock.
struct Shard<T> {
    items: Mutex<VecDeque<T>>,
    /// mirror of `items.len()`, written under the shard lock, read
    /// lock-free by `pick_shard` and the pop-side empty-shard skip
    len: AtomicUsize,
}

/// Lost-wakeup-proof sleep/wake pair.  Waiters register in `sleepers`,
/// then re-check their condition under the gate lock before parking;
/// wakers make the condition true first and take the gate lock to
/// notify (skipped entirely while nobody is registered), so a wake
/// issued between a waiter's check and its park cannot be lost.
struct Doorbell {
    gate: Mutex<()>,
    cv: Condvar,
    sleepers: AtomicUsize,
}

impl Doorbell {
    fn new() -> Doorbell {
        Doorbell {
            gate: Mutex::new(()),
            cv: Condvar::new(),
            sleepers: AtomicUsize::new(0),
        }
    }

    /// Block until `ready()` returns true (re-checked under the gate
    /// lock, so a ring between the check and the park cannot be lost)
    /// or until `deadline` passes.  Returns false iff it timed out.
    fn wait_until(&self, deadline: Option<Instant>,
                  ready: impl Fn() -> bool) -> bool {
        self.sleepers.fetch_add(1, Ordering::SeqCst);
        let mut gate = self.gate.lock().unwrap();
        let mut on_time = true;
        while !ready() {
            match deadline {
                None => gate = self.cv.wait(gate).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        on_time = false;
                        break;
                    }
                    let (g, _) = self.cv.wait_timeout(gate, d - now).unwrap();
                    gate = g;
                }
            }
        }
        drop(gate);
        self.sleepers.fetch_sub(1, Ordering::SeqCst);
        on_time
    }

    /// Wake every sleeper; skips the lock when nobody is registered
    /// (the hot-path case: rings are issued on every deposit).
    fn ring(&self) {
        if self.sleepers.load(Ordering::SeqCst) > 0 {
            self.ring_all();
        }
    }

    /// Unconditional wake (close path: must not miss a racing sleeper).
    fn ring_all(&self) {
        let _gate = self.gate.lock().unwrap();
        self.cv.notify_all();
    }
}

/// Sharded bounded FIFO queue shared by the submitting clients and the
/// workers.  See the module docs for the contracts.
pub struct AdmissionQueue<T> {
    shards: Vec<Shard<T>>,
    /// aggregate admitted-but-unpopped depth — THE backpressure gauge
    depth: AtomicUsize,
    bound: usize,
    closed: AtomicBool,
    /// consumers sleep here for work
    doorbell: Doorbell,
    /// producers sleep here for room
    vacancy: Doorbell,
    /// submit-side probe ticket (round-robin base of the two choices)
    ticket: AtomicUsize,
}

impl<T> AdmissionQueue<T> {
    /// Single-shard queue — behaviourally the original shared queue
    /// (global FIFO), still used by unit tests and 1-worker engines.
    pub fn new(bound: usize) -> AdmissionQueue<T> {
        AdmissionQueue::sharded(bound, 1)
    }

    /// Queue with `shards` independent deques under one aggregate
    /// `bound`.  The engine uses one shard per worker.
    pub fn sharded(bound: usize, shards: usize) -> AdmissionQueue<T> {
        let shards = shards.max(1);
        AdmissionQueue {
            shards: (0..shards)
                .map(|_| Shard {
                    items: Mutex::new(VecDeque::new()),
                    len: AtomicUsize::new(0),
                })
                .collect(),
            depth: AtomicUsize::new(0),
            bound: bound.max(1),
            closed: AtomicBool::new(false),
            doorbell: Doorbell::new(),
            vacancy: Doorbell::new(),
            ticket: AtomicUsize::new(0),
        }
    }

    /// Number of shards (1 = the classic shared queue).
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Reserve one slot against the aggregate bound.  Success means the
    /// caller owns a queue position and MUST deposit; failure means the
    /// bound is genuinely hit right now.
    fn try_reserve(&self) -> bool {
        let mut cur = self.depth.load(Ordering::SeqCst);
        loop {
            if cur >= self.bound {
                return false;
            }
            match self.depth.compare_exchange(
                cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
            {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }

    /// Power-of-two-choices shard pick: a round-robin probe plus one
    /// scrambled probe, keep the shallower.  Ties go to the round-robin
    /// probe so every shard is reachable even from an empty start.
    fn pick_shard(&self) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        let t = self.ticket.fetch_add(1, Ordering::Relaxed);
        let a = t % n;
        let h = (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let b = (a + 1 + ((h >> 33) as usize) % (n - 1)) % n;
        if self.shards[b].len.load(Ordering::SeqCst)
            < self.shards[a].len.load(Ordering::SeqCst)
        {
            b
        } else {
            a
        }
    }

    fn deposit(&self, item: T) {
        self.deposit_to(self.pick_shard(), item);
    }

    fn deposit_to(&self, s: usize, item: T) {
        let shard = &self.shards[s];
        let mut items = shard.items.lock().unwrap();
        items.push_back(item);
        shard.len.store(items.len(), Ordering::SeqCst);
        drop(items);
        self.doorbell.ring();
    }

    /// Enqueue one item, blocking while the aggregate depth is at its
    /// bound.  Returns the item back as `Err` if the queue has been
    /// closed (shutdown or a failed worker) so the caller can account
    /// for it.
    pub fn push(&self, item: T) -> Result<(), T> {
        loop {
            if self.closed.load(Ordering::SeqCst) {
                return Err(item);
            }
            if self.try_reserve() {
                return self.deposit_reserved(item);
            }
            self.vacancy.wait_until(None, || {
                self.closed.load(Ordering::SeqCst)
                    || self.depth.load(Ordering::SeqCst) < self.bound
            });
        }
    }

    /// Non-blocking enqueue: admit the item iff the queue is open and
    /// the aggregate depth is below its bound.  Never waits — this is
    /// the admission-verdict path, where "would block" must surface as
    /// an explicit `Full`.
    pub fn try_push(&self, item: T) -> Result<(), TryPushError<T>> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(TryPushError::Closed(item));
        }
        if !self.try_reserve() {
            return Err(TryPushError::Full(item));
        }
        self.deposit_reserved(item).map_err(TryPushError::Closed)
    }

    /// Second half of a push that already holds a reservation: re-check
    /// the close flag and either deposit or undo.  The re-check closes
    /// a strand-a-request race the old single-mutex queue excluded by
    /// construction: without it, a client could load `closed == false`,
    /// a failing worker could close the queue, every worker could
    /// observe `depth == 0 && closed` and exit, and only then would the
    /// client deposit — into a queue nobody will ever drain.  With it
    /// (plus the workers' exit-time depth re-check in
    /// [`pop_batch_keyed`]), a reservation made before close is always
    /// drained by a worker, and one that races close is undone here so
    /// the caller can resolve the item itself.
    fn deposit_reserved(&self, item: T) -> Result<(), T> {
        if self.closed.load(Ordering::SeqCst) {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            self.vacancy.ring();
            return Err(item);
        }
        self.deposit(item);
        Ok(())
    }

    /// Scan shards in ring order from `worker`, moving out up to `max`
    /// total items whose key matches `batch_key` (seeding the key from
    /// the first available item when unset — the first non-empty
    /// shard's head is always taken, so no shard or class starves).
    /// Skipped items keep their order.  Decrements the aggregate gauge
    /// by what was taken and rings producers waiting for room.
    ///
    /// Cost note: a keyed sweep over a shard with incompatible items is
    /// O(shard length) (pop + rebuild under the shard lock).  That is
    /// the inherent price of selective dequeue; it is bounded by the
    /// shard's share of the aggregate bound, and the phase-2 fill loop
    /// only re-sweeps on a depth change within `max_batch_wait`, so
    /// homogeneous traffic (the common case) never pays it.
    fn collect_into<K, F>(&self, worker: usize, max: usize, key: &F,
                          batch_key: &mut Option<K>, out: &mut Vec<T>)
    where
        K: PartialEq,
        F: Fn(&T) -> K,
    {
        let n = self.shards.len();
        let start = worker % n;
        let before = out.len();
        for i in 0..n {
            if out.len() >= max {
                break;
            }
            let shard = &self.shards[(start + i) % n];
            if shard.len.load(Ordering::SeqCst) == 0 {
                continue;
            }
            let mut items = shard.items.lock().unwrap();
            let mut skipped: VecDeque<T> = VecDeque::new();
            while out.len() < max {
                let Some(it) = items.pop_front() else { break };
                let matches = match batch_key {
                    None => true,
                    Some(k) => key(&it) == *k,
                };
                if matches {
                    if batch_key.is_none() {
                        *batch_key = Some(key(&it));
                    }
                    out.push(it);
                } else {
                    skipped.push_back(it);
                }
            }
            if !skipped.is_empty() {
                // skipped items go back in front of the untouched tail,
                // in their original order
                skipped.extend(items.drain(..));
                *items = skipped;
            }
            shard.len.store(items.len(), Ordering::SeqCst);
        }
        let taken = out.len() - before;
        if taken > 0 {
            self.depth.fetch_sub(taken, Ordering::SeqCst);
            self.vacancy.ring();
        }
    }

    /// Pop up to `max` items as the (single-shard) worker 0.
    pub fn pop_batch(&self, max: usize, wait: Duration) -> Vec<T> {
        self.pop_batch_as(0, max, wait)
    }

    /// Pop up to `max` items preferring `worker`'s own shard, stealing
    /// from siblings in ring order when it runs dry.
    pub fn pop_batch_as(&self, worker: usize, max: usize,
                        wait: Duration) -> Vec<T> {
        self.pop_batch_keyed(worker, max, wait, |_| ())
    }

    /// Class-aware pop: like [`pop_batch_as`], but the first available
    /// item seeds a batch key and only key-equal items join the batch
    /// (the worker uses the SLO compatibility key from `batcher.rs`).
    /// Blocks until at least one item is available (or the queue is
    /// closed), then waits at most `wait` for compatible items to fill
    /// the batch.  The fill target is clamped to the aggregate bound:
    /// with `bound < max` the queue can never hold a full batch, so
    /// "bound waiting" is "full" and the worker must not burn the whole
    /// `wait` every cycle.  An empty return means closed *and* fully
    /// drained — the worker's signal to exit.
    pub fn pop_batch_keyed<K, F>(&self, worker: usize, max: usize,
                                 wait: Duration, key: F) -> Vec<T>
    where
        K: PartialEq,
        F: Fn(&T) -> K,
    {
        let max = max.max(1);
        let target = max.min(self.bound);
        let mut out: Vec<T> = Vec::new();
        let mut batch_key: Option<K> = None;
        let mut spins = 0usize;
        // phase 1: block until at least one item is in hand, or the
        // queue is closed and fully drained
        loop {
            self.collect_into(worker, max, &key, &mut batch_key, &mut out);
            if !out.is_empty() {
                break;
            }
            if self.depth.load(Ordering::SeqCst) == 0 {
                if self.closed.load(Ordering::SeqCst) {
                    // exit-time re-check, paired with deposit_reserved:
                    // a submit may have reserved between our depth load
                    // and the close flag landing.  A reservation made
                    // before close always bumps the gauge before we get
                    // here (SeqCst), so "still zero now" means no item
                    // can be in flight — safe to exit.
                    if self.depth.load(Ordering::SeqCst) == 0 {
                        return out;
                    }
                    continue;
                }
                self.doorbell.wait_until(None, || {
                    self.depth.load(Ordering::SeqCst) > 0
                        || self.closed.load(Ordering::SeqCst)
                });
            } else {
                // an admitted item is still in flight to its shard
                // (between its depth reservation and its deposit).
                // Spin briefly — the window is normally nanoseconds —
                // then back off to the doorbell (deposits ring it) so
                // a preempted producer is not fought for CPU by every
                // idle worker on an oversubscribed host.
                spins += 1;
                if spins < 64 {
                    std::thread::yield_now();
                } else {
                    self.doorbell.wait_until(
                        Some(Instant::now() + Duration::from_micros(100)),
                        || {
                            self.closed.load(Ordering::SeqCst)
                                || self.depth.load(Ordering::SeqCst) == 0
                                || self.shards.iter().any(|s| {
                                    s.len.load(Ordering::SeqCst) > 0
                                })
                        });
                }
            }
        }
        // phase 2: bounded wait for compatible items to fill the batch.
        // The doorbell predicate is edge-style (any depth change since
        // the last sweep), so incompatible arrivals wake us once each
        // instead of spinning, and the deadline bounds the total wait.
        if out.len() < target && !wait.is_zero() {
            let deadline = Instant::now() + wait;
            while out.len() < target && !self.closed.load(Ordering::SeqCst) {
                let seen = self.depth.load(Ordering::SeqCst);
                self.collect_into(worker, max, &key, &mut batch_key,
                                  &mut out);
                if out.len() >= target {
                    break;
                }
                if !self.doorbell.wait_until(Some(deadline), || {
                    self.depth.load(Ordering::SeqCst) != seen
                        || self.closed.load(Ordering::SeqCst)
                }) {
                    break; // timed out
                }
            }
            // final sweep: a deposit may have raced the close/timeout
            self.collect_into(worker, max, &key, &mut batch_key, &mut out);
        }
        if self.depth.load(Ordering::SeqCst) > 0 {
            // hand remaining work to an idle sibling promptly
            self.doorbell.ring();
        }
        out
    }

    /// Close the queue: pending pushes fail, workers drain and exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.doorbell.ring_all();
        self.vacancy.ring_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Current aggregate backlog depth — one atomic load, no lock.
    /// This is what the capacity controller observes per batch and what
    /// report sampling reads; neither ever contends with submit/pop.
    pub fn len(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[cfg(test)]
    fn shard_len(&self, s: usize) -> usize {
        self.shards[s].len.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_batch_bounds() {
        let q = AdmissionQueue::new(16);
        for id in 0..10u64 {
            q.push(id).unwrap();
        }
        let a = q.pop_batch(4, Duration::ZERO);
        let b = q.pop_batch(4, Duration::ZERO);
        assert_eq!(a, vec![0, 1, 2, 3]);
        assert_eq!(b, vec![4, 5, 6, 7]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = AdmissionQueue::new(4);
        q.push(0u64).unwrap();
        q.close();
        assert!(q.push(1).is_err());
        let got = q.pop_batch(8, Duration::ZERO);
        assert_eq!(got.len(), 1);
        assert!(q.pop_batch(8, Duration::ZERO).is_empty());
    }

    #[test]
    fn push_blocks_at_bound_until_popped() {
        let q = Arc::new(AdmissionQueue::new(2));
        q.push(0u64).unwrap();
        q.push(1).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            // blocks until the consumer below makes room
            q2.push(2).unwrap();
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 2, "bound violated");
        let got = q.pop_batch(1, Duration::ZERO);
        assert_eq!(got[0], 0);
        t.join().unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn try_push_full_only_at_bound_and_closed_after_close() {
        let q = AdmissionQueue::new(2);
        assert!(q.try_push(0u64).is_ok());
        assert!(q.try_push(1).is_ok());
        match q.try_push(2) {
            Err(TryPushError::Full(item)) => assert_eq!(item, 2),
            other => panic!("expected Full at bound, got {other:?}"),
        }
        // popping makes room again: Full is tied to the bound, nothing else
        let got = q.pop_batch(1, Duration::ZERO);
        assert_eq!(got, vec![0]);
        assert!(q.try_push(2).is_ok());
        q.close();
        match q.try_push(3) {
            Err(TryPushError::Closed(item)) => assert_eq!(item, 3),
            other => panic!("expected Closed after close, got {other:?}"),
        }
    }

    #[test]
    fn bound_smaller_than_batch_does_not_dead_wait() {
        // bound 2 < batch 8: the queue can never fill the batch, so the
        // pop must return at the bound instead of burning the full wait
        let q = AdmissionQueue::new(2);
        q.push(0u64).unwrap();
        q.push(1).unwrap();
        let t0 = Instant::now();
        let got = q.pop_batch(8, Duration::from_millis(200));
        assert_eq!(got.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(100),
                "pop dead-waited {:?} for an unfillable batch",
                t0.elapsed());
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(AdmissionQueue::new(8));
        let n_producers = 4;
        let per_producer = 100u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p as u64 * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                loop {
                    let got = q.pop_batch(7, Duration::from_millis(1));
                    if got.is_empty() {
                        return ids;
                    }
                    ids.extend(got);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> =
            (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, want, "requests dropped or duplicated");
    }

    #[test]
    fn sharded_spreads_submissions_across_all_shards() {
        let q = AdmissionQueue::sharded(64, 4);
        for id in 0..32u64 {
            q.push(id).unwrap();
        }
        assert_eq!(q.len(), 32, "aggregate gauge must count all shards");
        for s in 0..4 {
            assert!(q.shard_len(s) > 0,
                    "p2c left shard {s} empty: {:?}",
                    (0..4).map(|i| q.shard_len(i)).collect::<Vec<_>>());
        }
    }

    #[test]
    fn single_popper_steals_across_all_shards() {
        // worker 2's own shard runs dry long before the backlog does:
        // ring-order stealing must still drain every shard
        let q = AdmissionQueue::sharded(64, 4);
        for id in 0..32u64 {
            q.push(id).unwrap();
        }
        let mut got: Vec<u64> = Vec::new();
        while got.len() < 32 {
            let batch = q.pop_batch_as(2, 8, Duration::ZERO);
            assert!(!batch.is_empty(), "pop on a non-empty queue");
            assert!(batch.len() <= 8);
            got.extend(batch);
        }
        got.sort_unstable();
        assert_eq!(got, (0..32).collect::<Vec<_>>(),
                   "stealing dropped or duplicated items");
        assert!(q.is_empty());
    }

    #[test]
    fn sharded_bound_is_aggregate_not_per_shard() {
        let q = AdmissionQueue::sharded(4, 4);
        for id in 0..4u64 {
            assert!(q.try_push(id).is_ok(), "room below the aggregate bound");
        }
        match q.try_push(4) {
            Err(TryPushError::Full(item)) => assert_eq!(item, 4),
            other => panic!("want Full at aggregate bound, got {other:?}"),
        }
        let got = q.pop_batch_as(3, 2, Duration::ZERO);
        assert_eq!(got.len(), 2);
        assert!(q.try_push(4).is_ok());
        assert!(q.try_push(5).is_ok());
        assert!(matches!(q.try_push(6), Err(TryPushError::Full(_))),
                "aggregate bound must re-engage exactly");
    }

    #[test]
    fn sharded_close_drains_every_shard() {
        let q = AdmissionQueue::sharded(32, 3);
        for id in 0..10u64 {
            q.push(id).unwrap();
        }
        q.close();
        let mut got: Vec<u64> = Vec::new();
        loop {
            let batch = q.pop_batch_as(1, 4, Duration::ZERO);
            if batch.is_empty() {
                break;
            }
            got.extend(batch);
        }
        got.sort_unstable();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        assert!(q.is_empty());
    }

    #[test]
    fn keyed_pop_returns_homogeneous_batches_and_preserves_order() {
        let q = AdmissionQueue::new(16); // single shard: deterministic
        for id in 0..6u64 {
            q.push(id).unwrap();
        }
        let key = |id: &u64| *id % 2;
        let a = q.pop_batch_keyed(0, 8, Duration::ZERO, key);
        assert_eq!(a, vec![0, 2, 4],
                   "head seeds the key; the other class is skipped");
        assert_eq!(q.len(), 3);
        let b = q.pop_batch_keyed(0, 8, Duration::ZERO, key);
        assert_eq!(b, vec![1, 3, 5], "skipped items kept their order");
        assert!(q.is_empty());
    }

    #[test]
    fn keyed_pop_respects_max_within_class() {
        let q = AdmissionQueue::new(16);
        for id in 0..8u64 {
            q.push(id).unwrap();
        }
        let got = q.pop_batch_keyed(0, 3, Duration::ZERO, |_| ());
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(q.len(), 5);
    }

    #[test]
    fn sharded_concurrent_stealing_consumers_lose_nothing() {
        let q = Arc::new(AdmissionQueue::sharded(16, 4));
        let n_producers = 4;
        let per_producer = 150u64;
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    q.push(p as u64 * per_producer + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for w in 0..4usize {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut ids = Vec::new();
                loop {
                    let got =
                        q.pop_batch_as(w, 5, Duration::from_micros(200));
                    if got.is_empty() {
                        return ids;
                    }
                    ids.extend(got);
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let want: Vec<u64> =
            (0..n_producers as u64 * per_producer).collect();
        assert_eq!(all, want, "requests dropped or duplicated");
        assert_eq!(q.len(), 0, "aggregate gauge must return to zero");
    }
}
