//! Streaming decode sessions — the engine's first *stateful* workload.
//!
//! A one-shot request enters the admission queue once and leaves with a
//! single `Response`.  A **decode session** re-enters the queue on
//! every autoregressive step: `EngineHandle::submit_stream` registers a
//! [`DecodeSession`] in the [`SessionTable`] and admits its step-0 item
//! (a *prefill* — the prompt pass); each completed step samples one
//! token, streams it to the client as a [`StreamEvent::Token`], and —
//! if the session has budget left — is turned by the table into a
//! fresh *decode* work item that re-enters the same sharded queue.
//! Decode steps from many sessions therefore batch together under the
//! ordinary `batcher::batch_key` compatibility rules (continuous
//! batching), with the [`StepKind`](super::batcher::StepKind)
//! dimension keeping prefill and decode runs apart.
//!
//! Because every step is a fresh pass through admission, every step
//! gets a **fresh tier decision** from the serving class's
//! `CapacityController` — the paper's per-step input-dependent compute
//! made operational.  The worker feeds the controller the session's
//! *remaining per-step budget* (`deadline slack / steps left`), so a
//! session that started comfortably at tier 1.0 degrades down the
//! ladder as its budget burns instead of being shed at the cliff.
//!
//! Delivery discipline mirrors the one-shot `Response` slot: the
//! engine holds exactly one [`StreamSender`] per session (it lives in
//! the session's table entry), every terminal outcome goes through its
//! exactly-once guard, and its drop guard emits a final
//! [`StreamEvent::Shed`] if nothing else did — so a `StreamResponse`
//! always observes `Token* (Done | Shed)`, across worker panics,
//! mid-decode shutdown, and expired deadlines (property-tested in
//! `tests/properties.rs`).  A session whose decode row keeps failing
//! under the worker's retry/bisect ladder is shed with
//! `ServeError::Poisoned` — quarantining one poison session while its
//! co-batched neighbours (and co-packed verify rows) keep streaming —
//! and a supervised worker respawn re-homes the session's next step to
//! its pinned shard via the same `requeue_to` path stealing uses.
//!
//! The channel is bounded, sized to the session (`max_steps` tokens
//! plus one terminal event): memory per session is bounded while the
//! engine never blocks on a slow consumer — a worker thread stalled on
//! one client's unread tokens would stall every session behind it.  A
//! dropped `StreamResponse` discards further tokens silently; a token
//! refused at the cap for a *live* receiver is counted
//! ([`StreamStats::tokens_dropped`]) instead of vanishing, and
//! `SessionTable::admit` asserts the cap covers the session's step
//! budget so the counter stays zero on every engine-constructed
//! channel.
//!
//! The event-order contract (`Token* (Done|Shed)`) is enforced **by
//! the channel itself**: [`StreamSender::token`] discards tokens once
//! a terminal has been enqueued.  That guard is what lets the
//! [`SessionTable`] deliver events under its *per-session* entry locks
//! — an `advance` racing a `shed` can lose the race safely — instead
//! of serializing every decode step in the fleet on one table-wide
//! mutex (the pre-arena design).
//!
//! Cached decode state lives in the per-worker-class [`arena`]
//! module: each completed step deposits the session's next window row
//! into the executing class's paged arena, so the next step is served
//! incrementally (O(1) in window length on the modeled sim cost)
//! instead of recomputed from the table.
//!
//! Speculative decode (the [`spec`] module) layers a second step
//! shape on top: when the engine runs with `spec_k > 0`, a session's
//! post-prefill steps alternate between **draft** items (k cheap
//! low-tier micro-steps producing proposed tokens) and **verify**
//! items (one top-tier pass over the whole draft run).  The table
//! still owns all authoritative state — the draft buffer lives inside
//! [`DecodeSession`] and is consumed exactly once by the verify
//! resolution, whether the proposals are accepted or rejected.

pub mod arena;
pub mod spec;

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::sync::{Rank, RankedCondvar, RankedMutex};

use super::report::StreamShedRecord;
use super::{Pending, Request, ServeError, SloClass};
use spec::{DraftBuf, StepPhase};

/// One streaming decode request: a prompt to prefill, a token budget,
/// and the SLO the whole *session* runs under (`deadline` is the total
/// session budget, submit → last token; `floor_tier` clamps every
/// step's tier).
#[derive(Debug, Clone)]
pub struct StreamRequest {
    /// caller-chosen correlation id, echoed in stats and records
    pub id: u64,
    pub prompt: Vec<i32>,
    /// number of tokens to generate (clamped to >= 1 at admission)
    pub max_steps: usize,
    pub slo: SloClass,
}

impl StreamRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_steps: usize)
               -> StreamRequest {
        StreamRequest {
            id,
            prompt,
            max_steps,
            slo: SloClass::best_effort(),
        }
    }

    pub fn with_slo(mut self, slo: SloClass) -> StreamRequest {
        self.slo = slo;
        self
    }
}

/// What a [`StreamResponse`] yields, in order: zero or more `Token`s
/// (strictly increasing `step`, starting at 0), then exactly one
/// terminal `Done` or `Shed`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// one generated token, with the step index and the capacity tier
    /// the step's batch was served at
    Token { step: usize, tier: f32, token: i32 },
    /// the session generated its full `max_steps` budget
    Done(StreamStats),
    /// the session was terminated early; no further tokens will come
    /// (tokens already delivered remain valid)
    Shed(ServeError),
}

impl StreamEvent {
    /// Is this event a terminal (`Done`/`Shed`)?
    pub fn is_terminal(&self) -> bool {
        !matches!(self, StreamEvent::Token { .. })
    }
}

/// Per-session completion record, delivered inside
/// [`StreamEvent::Done`] and aggregated by
/// `ServeReport::stream_sections`.
#[derive(Debug, Clone)]
pub struct StreamStats {
    /// the caller-chosen session id
    pub id: u64,
    /// SLO class name the session ran under
    pub class: String,
    /// tokens generated (== `max_steps` for a `Done` session)
    pub steps: usize,
    /// tier served at each step, in step order — the per-step
    /// elasticity trajectory
    pub tiers: Vec<f32>,
    /// session wall time, submit → last token, ms
    pub total_ms: f64,
    /// submit → first token (prefill) latency, ms
    pub first_token_ms: f64,
    /// tokens refused at the channel cap while the receiver was still
    /// alive — a step-index gap the client can now account for instead
    /// of silently losing.  Always 0 for engine-constructed channels
    /// (the cap is sized to `max_steps`, asserted at admission).
    pub tokens_dropped: usize,
}

enum ChanState {
    /// terminal not yet enqueued
    Open,
    /// terminal enqueued but not yet consumed by the receiver
    Terminated,
    /// terminal consumed: `recv` returns `None` from here on
    Finished,
}

struct Chan {
    /// Rank::StreamChan sits *above* Rank::SessionEntry: the table
    /// delivers events while holding a session's entry lock (see
    /// `advance`), so the channel lock must nest inside it.
    inner: RankedMutex<ChanInner>,
    cv: RankedCondvar,
}

struct ChanInner {
    events: VecDeque<StreamEvent>,
    state: ChanState,
    rx_alive: bool,
    /// token-event bound (terminals are always accepted): sized to the
    /// session at creation, so a full run never blocks the engine
    cap: usize,
    /// tokens refused at the cap while the receiver was alive — a real
    /// loss the client would see as a step gap, surfaced through
    /// [`StreamStats::tokens_dropped`] (post-terminal and
    /// dead-receiver discards are *not* drops: they are the contract)
    dropped: usize,
}

/// Create one session channel: (engine-side sender, caller-side
/// response).  `cap` bounds buffered token events.
pub(crate) fn channel(id: u64, cap: usize)
                      -> (StreamSender, StreamResponse) {
    let chan = Arc::new(Chan {
        inner: RankedMutex::new(Rank::StreamChan, ChanInner {
            events: VecDeque::new(),
            state: ChanState::Open,
            rx_alive: true,
            cap: cap.max(1),
            dropped: 0,
        }),
        cv: RankedCondvar::new(),
    });
    (StreamSender { chan: chan.clone(), done: false },
     StreamResponse { id, chan })
}

/// Engine-side write half of a session stream.  Not `Clone`: there is
/// exactly one per session (owned by its [`SessionTable`] entry), and
/// its drop guard emits `Shed(Dropped)` if no explicit terminal did —
/// the exactly-once backbone, mirroring the one-shot `Responder`.
pub(crate) struct StreamSender {
    chan: Arc<Chan>,
    done: bool,
}

impl StreamSender {
    /// Deliver one token event.  Never blocks: the channel is sized to
    /// the session, and a dropped receiver discards tokens silently.
    ///
    /// Order is enforced *here*, not by the caller's locking: once a
    /// terminal has been enqueued (`state != Open`) the token is
    /// discarded, so an `advance` that loses a race against a `shed`
    /// cannot violate the `Token* (Done|Shed)` contract.  This guard
    /// is what makes per-session table locking safe.
    pub(crate) fn token(&self, step: usize, tier: f32, token: i32) {
        let mut inner = self.chan.inner.lock();
        if !matches!(inner.state, ChanState::Open) {
            return; // terminal already enqueued: the contract wins
        }
        if !inner.rx_alive {
            return; // receiver gone: nobody will read it
        }
        if inner.events.len() >= inner.cap {
            // a live receiver just lost a token — count it so the
            // terminal stats can surface the gap
            inner.dropped += 1;
            return;
        }
        inner.events.push_back(StreamEvent::Token { step, tier, token });
        drop(inner);
        self.chan.cv.notify_all();
    }

    /// Tokens refused at the cap for a live receiver so far.
    pub(crate) fn drops(&self) -> usize {
        self.chan.inner.lock().dropped
    }

    /// The channel's token-event bound (terminals bypass it).
    pub(crate) fn cap(&self) -> usize {
        self.chan.inner.lock().cap
    }

    /// Has this sender already delivered its terminal?  Used by the
    /// table to detect a session terminated by a concurrent path.
    pub(crate) fn is_done(&self) -> bool {
        self.done
    }

    /// Terminal success.  Exactly-once: later terminals are ignored.
    pub(crate) fn finish(mut self, stats: StreamStats) {
        self.terminate(StreamEvent::Done(stats));
    }

    /// Non-consuming [`finish`](Self::finish): for senders that stay
    /// embedded in a shared per-session entry (the entry itself is
    /// dropped later; the drop guard sees `done` and stays quiet).
    pub(crate) fn finish_ref(&mut self, stats: StreamStats) {
        self.terminate(StreamEvent::Done(stats));
    }

    /// Terminal failure.  Exactly-once: later terminals are ignored.
    pub(crate) fn shed(mut self, err: ServeError) {
        self.terminate(StreamEvent::Shed(err));
    }

    /// Non-consuming [`shed`](Self::shed), same contract as
    /// [`finish_ref`](Self::finish_ref).
    pub(crate) fn shed_ref(&mut self, err: ServeError) {
        self.terminate(StreamEvent::Shed(err));
    }

    fn terminate(&mut self, ev: StreamEvent) {
        if self.done {
            return;
        }
        self.done = true;
        let mut inner = self.chan.inner.lock();
        if matches!(inner.state, ChanState::Open) {
            // terminals bypass the token cap: they are the last event
            inner.events.push_back(ev);
            inner.state = ChanState::Terminated;
        }
        drop(inner);
        self.chan.cv.notify_all();
    }
}

impl Drop for StreamSender {
    fn drop(&mut self) {
        self.terminate(StreamEvent::Shed(ServeError::Dropped));
    }
}

/// [`StreamResponse::recv_timeout`] gave up: no event arrived within
/// the timeout, but the stream is still live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamTimeout;

/// Caller-side read half: consume the session's events as they land.
/// Yields `Token`s in step order, then exactly one `Done`/`Shed`, then
/// `None`.  Dropping it mid-stream is safe — the engine keeps decoding
/// (or shedding) the session; its remaining tokens are discarded.
pub struct StreamResponse {
    id: u64,
    chan: Arc<Chan>,
}

impl StreamResponse {
    /// The caller-chosen session id this stream answers.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block for the next event; `None` means the terminal event has
    /// already been consumed — the stream is over.
    pub fn recv(&self) -> Option<StreamEvent> {
        let mut inner = self.chan.inner.lock();
        loop {
            if let Some(ev) = inner.events.pop_front() {
                if ev.is_terminal() {
                    inner.state = ChanState::Finished;
                }
                return Some(ev);
            }
            if matches!(inner.state, ChanState::Finished) {
                return None;
            }
            inner = self.chan.cv.wait(inner);
        }
    }

    /// Like [`recv`](Self::recv) but gives up after `timeout`:
    /// `Err(StreamTimeout)` means nothing arrived in time (the stream
    /// is still live), `Ok(None)` means the stream is over.
    pub fn recv_timeout(&self, timeout: std::time::Duration)
                        -> Result<Option<StreamEvent>, StreamTimeout> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.chan.inner.lock();
        loop {
            if let Some(ev) = inner.events.pop_front() {
                if ev.is_terminal() {
                    inner.state = ChanState::Finished;
                }
                return Ok(Some(ev));
            }
            if matches!(inner.state, ChanState::Finished) {
                return Ok(None);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(StreamTimeout);
            }
            let (guard, _) =
                self.chan.cv.wait_timeout(inner, deadline - now);
            inner = guard;
        }
    }

    /// Drain the stream to its terminal, discarding token events:
    /// `Ok(stats)` if the session completed, `Err(reason)` if it was
    /// shed.
    pub fn wait(self) -> Result<StreamStats, ServeError> {
        loop {
            match self.recv() {
                Some(StreamEvent::Token { .. }) => continue,
                Some(StreamEvent::Done(stats)) => return Ok(stats),
                Some(StreamEvent::Shed(err)) => return Err(err),
                // unreachable: the terminal precedes None, and we
                // consume every event ourselves
                None => return Err(ServeError::Dropped),
            }
        }
    }
}

impl Drop for StreamResponse {
    fn drop(&mut self) {
        let mut inner = self.chan.inner.lock();
        inner.rx_alive = false;
        inner.events.clear(); // nobody will read them
    }
}

/// One live decode session, owned by the [`SessionTable`].
pub struct DecodeSession {
    /// caller-chosen id (echoed in events, stats and shed records)
    pub id: u64,
    pub prompt: Vec<i32>,
    /// tokens generated so far, in step order
    pub generated: Vec<i32>,
    pub max_steps: usize,
    pub slo: SloClass,
    /// session admission stamp — the deadline clock and the base of
    /// `total_ms`/`first_token_ms`
    pub(crate) started: Instant,
    /// tier served at each completed step
    pub(crate) tiers: Vec<f32>,
    pub(crate) first_token_ms: f64,
    pub(crate) sender: StreamSender,
    /// speculative draft ceiling for this session (0 = plain decode);
    /// snapshotted from the engine config at admission
    pub(crate) spec_k: usize,
    /// in-flight speculative proposals: filled by a draft step,
    /// consumed exactly once by the matching verify resolution
    pub(crate) draft: Option<DraftBuf>,
    /// flight-recorder id allocated at session admission (0 when
    /// tracing is off); every step item the session circulates —
    /// decode, draft and verify alike — carries this same id, so the
    /// whole stream renders as one request track in the Chrome export
    pub(crate) trace_id: u64,
}

/// Thin, queue-circulating handle for one pending decode step.  The
/// session's authoritative state (prompt, generated tokens, the stream
/// sender) stays in the [`SessionTable`]; the item carries only what
/// the queue's key/slack closures need without a table lock.
pub(crate) struct StreamStep {
    /// session key in the table (engine-internal, collision-free even
    /// when callers reuse ids)
    pub session: u64,
    /// 0-based index of the step this item will execute (0 = prefill)
    pub step: usize,
    pub max_steps: usize,
    /// session admission stamp (deadline clock — NOT this step's
    /// re-admission stamp)
    pub started: Instant,
    /// affine queue shard, pinned at admission: continuations are
    /// re-deposited here (not p2c) so the workers that hold the
    /// session's arena pages keep serving it, and the steal peek
    /// prices cache-holding heads as cheaper to serve
    pub shard: usize,
    /// which step shape this item executes as: plain decode, a
    /// speculative draft run, or the matching verify pass.  Step 0 is
    /// always a prefill regardless of phase (see `Pending::kind`).
    pub phase: StepPhase,
}

/// What the table decided after one executed step.
pub(crate) enum Advance {
    /// the session has budget left: re-admit this item
    Requeue(Pending),
    /// the session just generated its last token; stats recorded here
    /// were already delivered through the stream
    Done(StreamStats),
    /// the session no longer exists (terminated concurrently) — the
    /// step result is discarded
    Gone,
}

/// One registered session behind its own lock.  The table's map holds
/// `Arc<SessionEntry>`, so the table-wide mutex is held only for the
/// key lookup (or insert/remove) — step bookkeeping and event delivery
/// happen under this per-session lock, and decode steps of *different*
/// sessions never contend.
pub(crate) struct SessionEntry {
    state: RankedMutex<DecodeSession>,
}

/// Owner of all live decode sessions: registers new sessions, serves
/// each step's compute row to the workers, and turns every completed
/// step into either a re-admission or a terminal event.  One instance
/// per engine, shared by the handle and every worker.
///
/// Locking discipline: the map mutex and a session's entry mutex are
/// never held together *across* sessions, and nothing takes the map
/// mutex while holding an entry mutex — `advance` drops the entry
/// guard before removing a completed session.  A terminal racing a
/// step is resolved by the sender: whichever path terminates first
/// wins (`is_done`), and a late `token()` is discarded by the
/// channel's own order guard.
pub(crate) struct SessionTable {
    /// Rank::SessionMap < Rank::SessionEntry: the map lock is held
    /// only for lookup/insert/remove, never while an entry lock is
    /// taken *and* kept — `advance` drops the entry guard before
    /// re-taking the map to remove a completed session.
    sessions: RankedMutex<HashMap<u64, Arc<SessionEntry>>>,
    /// Relaxed: a pure unique-key allocator — no ordering carried
    next_key: AtomicU64,
    /// Relaxed statistics counter, read by report assembly after the
    /// workers join
    started: AtomicUsize,
    /// stream work items ever handed to the queue (the step-0 admit
    /// plus every requeue — draft and verify items included).  The
    /// denominator of the report's tokens-per-admission metric: plain
    /// decode pays exactly one item per token, speculative decode
    /// fewer when drafts are accepted.
    step_items: AtomicUsize,
}

impl Default for SessionTable {
    fn default() -> SessionTable {
        SessionTable::new()
    }
}

impl SessionTable {
    pub(crate) fn new() -> SessionTable {
        SessionTable {
            sessions: RankedMutex::new(Rank::SessionMap, HashMap::new()),
            next_key: AtomicU64::new(0),
            started: AtomicUsize::new(0),
            step_items: AtomicUsize::new(0),
        }
    }

    /// Sessions ever admitted (the reconciliation base: every started
    /// session ends in exactly one completion or shed record).
    pub(crate) fn sessions_started(&self) -> usize {
        self.started.load(Ordering::Relaxed)
    }

    /// Stream work items ever handed to the queue (see the field doc).
    pub(crate) fn step_items(&self) -> usize {
        self.step_items.load(Ordering::Relaxed)
    }

    /// Count one stream work item entering circulation.  Every path
    /// that constructs a stream `Pending` (admit, decode requeue, the
    /// spec module's draft→verify and verify→draft hops) calls this
    /// exactly once per item.
    pub(crate) fn note_step_item(&self) {
        self.step_items.fetch_add(1, Ordering::Relaxed);
    }

    /// Register one new session and build its step-0 (prefill) work
    /// item.  The caller pushes the item into the admission queue.
    /// `shards` is the queue's shard count: the session is pinned to
    /// shard `key % shards` for the life of the stream (placement
    /// affinity — continuations and arena pages stay together).
    ///
    /// Panics if the sender's channel cap cannot hold the session's
    /// full token budget: a correctly sized channel is the invariant
    /// that keeps [`StreamStats::tokens_dropped`] at zero.
    ///
    /// `spec_k` is the engine's speculative draft ceiling (0 = plain
    /// decode): it decides whether post-prefill steps circulate as
    /// `Draft`/`Verify` items or plain `Decode` items.
    ///
    /// `trace_id` is the session's flight-recorder id (0 = untraced);
    /// it rides every step item the session ever circulates.
    pub(crate) fn admit(&self, req: StreamRequest, sender: StreamSender,
                        started: Instant, shards: usize,
                        spec_k: usize, trace_id: u64) -> Pending {
        let key = self.next_key.fetch_add(1, Ordering::Relaxed);
        let max_steps = req.max_steps.max(1);
        assert!(sender.cap() >= max_steps,
                "stream channel cap {} cannot hold max_steps {}: a full \
                 run would drop tokens for a live receiver",
                sender.cap(), max_steps);
        let shard = (key % shards.max(1) as u64) as usize;
        let slo = req.slo.clone();
        let entry = Arc::new(SessionEntry {
            state: RankedMutex::new(Rank::SessionEntry, DecodeSession {
                id: req.id,
                prompt: req.prompt,
                generated: Vec::new(),
                max_steps,
                slo: req.slo,
                started,
                tiers: Vec::new(),
                first_token_ms: 0.0,
                sender,
                spec_k,
                draft: None,
                trace_id,
            }),
        });
        self.sessions.lock().insert(key, entry);
        self.started.fetch_add(1, Ordering::Relaxed);
        self.note_step_item();
        Pending {
            req: Request { id: req.id, tokens: Vec::new(), slo },
            submitted: started,
            trace_id,
            outcome: super::Outcome::Stream(StreamStep {
                session: key,
                step: 0,
                max_steps,
                started,
                shard,
                phase: StepPhase::Decode,
            }),
        }
    }

    /// Clone one session's entry handle out of the map (the table lock
    /// is held only for this lookup).
    fn entry(&self, key: u64) -> Option<Arc<SessionEntry>> {
        self.sessions.lock().get(&key).cloned()
    }

    /// The compute row for one session's next step: the last `seq_len`
    /// tokens of `prompt ++ generated` (a sliding window once the
    /// sequence outgrows the executor shape; `form_rows` zero-pads
    /// shorter rows).  `None` if the session no longer exists.  This
    /// is the *recompute* path — the arena hit path serves the same
    /// window without touching the table at all.
    pub(crate) fn compute_row(&self, key: u64, seq_len: usize)
                              -> Option<Vec<i32>> {
        let entry = self.entry(key)?;
        let sess = entry.state.lock();
        if sess.sender.is_done() {
            return None; // terminated concurrently: step is stale
        }
        let total = sess.prompt.len() + sess.generated.len();
        let start = total.saturating_sub(seq_len);
        let mut row = Vec::with_capacity(total - start);
        if start < sess.prompt.len() {
            row.extend_from_slice(&sess.prompt[start..]);
            row.extend_from_slice(&sess.generated);
        } else {
            row.extend_from_slice(
                &sess.generated[start - sess.prompt.len()..]);
        }
        Some(row)
    }

    /// Record one executed step: deliver the token event, then either
    /// hand back the session's next work item (continuous batching:
    /// the caller re-admits it) or complete the session.  `now` is the
    /// worker's post-execution stamp.
    ///
    /// Runs under the session's *own* lock — concurrent steps of other
    /// sessions proceed untouched.  Delivery inside the entry lock is
    /// safe against a racing `shed` because the channel itself
    /// enforces event order.
    pub(crate) fn advance(&self, st: &StreamStep, token: i32, tier: f32,
                          now: Instant) -> Advance {
        let Some(entry) = self.entry(st.session) else {
            return Advance::Gone;
        };
        let mut sess = entry.state.lock();
        if sess.sender.is_done() {
            return Advance::Gone; // shed won the race: discard the step
        }
        sess.generated.push(token);
        sess.tiers.push(tier);
        if st.step == 0 {
            sess.first_token_ms =
                now.saturating_duration_since(sess.started)
                    .as_secs_f64() * 1e3;
        }
        sess.sender.token(st.step, tier, token);
        if sess.generated.len() >= sess.max_steps {
            let stats = StreamStats {
                id: sess.id,
                class: sess.slo.name.clone(),
                steps: sess.generated.len(),
                tiers: sess.tiers.clone(),
                total_ms: now
                    .saturating_duration_since(sess.started)
                    .as_secs_f64() * 1e3,
                first_token_ms: sess.first_token_ms,
                tokens_dropped: sess.sender.drops(),
            };
            sess.sender.finish_ref(stats.clone());
            // entry lock released before the map lock: SessionMap
            // ranks below SessionEntry, so holding both this way
            // round would trip the rank checker (and rightly so)
            drop(sess);
            self.sessions.lock().remove(&st.session);
            return Advance::Done(stats);
        }
        let req = Request {
            id: sess.id,
            tokens: Vec::new(),
            slo: sess.slo.clone(),
        };
        // a speculative session's post-prefill steps circulate as
        // draft runs; plain sessions keep the one-token decode shape
        let phase = if sess.spec_k > 0 {
            StepPhase::Draft
        } else {
            StepPhase::Decode
        };
        let trace_id = sess.trace_id;
        drop(sess);
        self.note_step_item();
        Advance::Requeue(Pending {
            req,
            submitted: now,
            trace_id,
            outcome: super::Outcome::Stream(StreamStep {
                session: st.session,
                step: st.step + 1,
                max_steps: st.max_steps,
                started: st.started,
                shard: st.shard,
                phase,
            }),
        })
    }

    /// Terminate one session with a `Shed` event and return its record
    /// for the engine's stream-shed log.  `None` if the session no
    /// longer exists or already terminated (a racing `advance` may
    /// still hold an entry handle; the sender's exactly-once guard and
    /// the channel's order guard make the race benign).
    pub(crate) fn shed(&self, key: u64, err: ServeError,
                       worker_class: &str) -> Option<StreamShedRecord> {
        let entry = self.sessions.lock().remove(&key)?;
        let mut sess = entry.state.lock();
        if sess.sender.is_done() {
            return None; // completion won the race: nothing to shed
        }
        let rec = StreamShedRecord {
            id: sess.id,
            class: sess.slo.name.clone(),
            worker_class: worker_class.to_string(),
            steps_done: sess.generated.len(),
            reason: err.clone(),
        };
        sess.sender.shed_ref(err);
        Some(rec)
    }

    /// Terminate every remaining session (engine shutdown: sessions
    /// whose in-flight step died with a worker, or that never got one).
    /// Each shed comes back with the session's flight-recorder id so
    /// the caller can emit the balancing `Terminal` event — this is
    /// the one terminal path with no `Pending` in hand to read it from.
    pub(crate) fn shed_all(&self, err: ServeError, worker_class: &str)
                           -> Vec<(u64, StreamShedRecord)> {
        let drained: Vec<Arc<SessionEntry>> = {
            let mut sessions = self.sessions.lock();
            sessions.drain().map(|(_, e)| e).collect()
        };
        drained
            .into_iter()
            .filter_map(|entry| {
                let mut sess = entry.state.lock();
                if sess.sender.is_done() {
                    return None; // already terminated concurrently
                }
                let rec = StreamShedRecord {
                    id: sess.id,
                    class: sess.slo.name.clone(),
                    worker_class: worker_class.to_string(),
                    steps_done: sess.generated.len(),
                    reason: err.clone(),
                };
                sess.sender.shed_ref(err.clone());
                Some((sess.trace_id, rec))
            })
            .collect()
    }

    /// Number of currently live sessions — what `close_drain` polls to
    /// decide the fleet has finished its in-flight work.
    pub(crate) fn live(&self) -> usize {
        self.sessions.lock().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn channel_delivers_tokens_then_exactly_one_terminal() {
        let (tx, rx) = channel(7, 8);
        tx.token(0, 1.0, 42);
        tx.token(1, 0.5, 43);
        tx.finish(StreamStats {
            id: 7,
            class: "best-effort".into(),
            steps: 2,
            tiers: vec![1.0, 0.5],
            total_ms: 1.0,
            first_token_ms: 0.5,
            tokens_dropped: 0,
        });
        assert_eq!(rx.id(), 7);
        match rx.recv() {
            Some(StreamEvent::Token { step: 0, tier, token: 42 }) => {
                assert_eq!(tier, 1.0);
            }
            other => panic!("want token 0, got {other:?}"),
        }
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Token { step: 1, .. })));
        match rx.recv() {
            Some(StreamEvent::Done(stats)) => {
                assert_eq!(stats.steps, 2);
                assert_eq!(stats.tiers, vec![1.0, 0.5]);
            }
            other => panic!("want Done, got {other:?}"),
        }
        assert!(rx.recv().is_none(), "after the terminal: None forever");
        assert!(rx.recv().is_none());
    }

    #[test]
    fn dropped_sender_sheds_with_dropped() {
        let (tx, rx) = channel(0, 4);
        tx.token(0, 1.0, 1);
        drop(tx); // no explicit terminal: the drop guard must emit one
        assert!(matches!(rx.recv(), Some(StreamEvent::Token { .. })));
        match rx.recv() {
            Some(StreamEvent::Shed(ServeError::Dropped)) => {}
            other => panic!("want Shed(Dropped), got {other:?}"),
        }
        assert!(rx.recv().is_none());
    }

    #[test]
    fn explicit_shed_wins_over_drop_guard() {
        let (tx, rx) = channel(0, 4);
        tx.shed(ServeError::ShuttingDown);
        // shed consumed the sender; its drop guard must not double-fire
        match rx.recv() {
            Some(StreamEvent::Shed(ServeError::ShuttingDown)) => {}
            other => panic!("want Shed(ShuttingDown), got {other:?}"),
        }
        assert!(rx.recv().is_none());
    }

    #[test]
    fn recv_timeout_distinguishes_live_from_finished() {
        let (tx, rx) = channel(0, 4);
        assert!(rx.recv_timeout(Duration::from_millis(10)).is_err(),
                "live stream with no event must time out");
        tx.shed(ServeError::DeadlineExceeded);
        assert!(matches!(rx.recv_timeout(Duration::from_secs(5)),
                         Ok(Some(StreamEvent::Shed(_)))));
        assert!(matches!(rx.recv_timeout(Duration::from_millis(1)),
                         Ok(None)),
                "finished stream must report None, not a timeout");
    }

    #[test]
    fn dropped_receiver_discards_tokens_but_sender_survives() {
        let (tx, rx) = channel(0, 4);
        drop(rx);
        tx.token(0, 1.0, 1); // must not block or panic
        tx.finish(StreamStats {
            id: 0,
            class: "x".into(),
            steps: 1,
            tiers: vec![1.0],
            total_ms: 0.0,
            first_token_ms: 0.0,
            tokens_dropped: 0,
        });
    }

    #[test]
    fn table_windows_compute_rows_to_seq_len() {
        let table = SessionTable::new();
        let (tx, _rx) = channel(1, 8);
        let pending = table.admit(
            StreamRequest::new(1, vec![10, 11, 12], 4), tx,
            Instant::now(), 4, 0, 0);
        let key = match &pending.outcome {
            crate::coordinator::serving::Outcome::Stream(st) => st.session,
            _ => panic!("stream admit must yield a stream item"),
        };
        assert_eq!(table.sessions_started(), 1);
        assert_eq!(table.live(), 1);
        // prompt shorter than seq_len: the whole prompt
        assert_eq!(table.compute_row(key, 8).unwrap(), vec![10, 11, 12]);
        // prompt longer than seq_len: the tail window
        assert_eq!(table.compute_row(key, 2).unwrap(), vec![11, 12]);
        // generated tokens extend the window
        let st = StreamStep {
            session: key, step: 0, max_steps: 4,
            started: Instant::now(), shard: 0,
            phase: StepPhase::Decode,
        };
        match table.advance(&st, 99, 1.0, Instant::now()) {
            Advance::Requeue(_) => {}
            _ => panic!("budget left: must requeue"),
        }
        assert_eq!(table.compute_row(key, 3).unwrap(), vec![11, 12, 99]);
        // unknown keys are None, not a panic
        assert!(table.compute_row(key + 100, 4).is_none());
    }

    #[test]
    fn table_completes_at_max_steps_and_sheds_exactly_once() {
        let table = SessionTable::new();
        let (tx, rx) = channel(5, 8);
        let t0 = Instant::now();
        let pending = table.admit(StreamRequest::new(5, vec![1], 2), tx,
                                  t0, 4, 0, 0);
        let key = match &pending.outcome {
            crate::coordinator::serving::Outcome::Stream(st) => st.session,
            _ => panic!("stream admit must yield a stream item"),
        };
        let st0 = StreamStep { session: key, step: 0, max_steps: 2,
                               started: t0, shard: 0,
                               phase: StepPhase::Decode };
        let st1 = match table.advance(&st0, 7, 1.0, Instant::now()) {
            Advance::Requeue(p) => match p.outcome {
                crate::coordinator::serving::Outcome::Stream(st) => st,
                _ => panic!("requeue must stay a stream item"),
            },
            _ => panic!("step 0 of 2 must requeue"),
        };
        match table.advance(&st1, 8, 0.5, Instant::now()) {
            Advance::Done(stats) => {
                assert_eq!(stats.steps, 2);
                assert_eq!(stats.tiers, vec![1.0, 0.5]);
                assert!(stats.first_token_ms >= 0.0);
            }
            _ => panic!("step 1 of 2 must complete"),
        }
        assert_eq!(table.live(), 0);
        // the session is gone: advancing or shedding it is a no-op
        assert!(matches!(table.advance(&st1, 9, 1.0, Instant::now()),
                         Advance::Gone));
        assert!(table.shed(key, ServeError::ShuttingDown, "engine")
            .is_none());
        // the stream saw both tokens then exactly one Done
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Token { step: 0, token: 7, .. })));
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Token { step: 1, token: 8, .. })));
        assert!(matches!(rx.recv(), Some(StreamEvent::Done(_))));
        assert!(rx.recv().is_none());
    }

    #[test]
    fn post_terminal_token_is_discarded_by_the_channel() {
        // the order contract must hold even when the producer races a
        // terminal: the channel itself discards late tokens, with no
        // table lock in the picture
        let (mut tx, rx) = channel(3, 8);
        tx.token(0, 1.0, 10);
        tx.shed_ref(ServeError::DeadlineExceeded);
        tx.token(1, 1.0, 11); // late step from a racing worker
        tx.token(2, 1.0, 12);
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Token { step: 0, .. })));
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Shed(
                             ServeError::DeadlineExceeded))));
        assert!(rx.recv().is_none(),
                "tokens pushed after the terminal must never surface");
    }

    #[test]
    fn cap_drops_for_a_live_receiver_are_counted() {
        // cap 2, three tokens, receiver alive and unread: the third is
        // refused — but counted, so the terminal stats surface the gap
        let (mut tx, rx) = channel(9, 2);
        tx.token(0, 1.0, 1);
        tx.token(1, 1.0, 2);
        tx.token(2, 1.0, 3); // over cap: dropped, not lost silently
        assert_eq!(tx.drops(), 1);
        let stats = StreamStats {
            id: 9,
            class: "x".into(),
            steps: 3,
            tiers: vec![1.0; 3],
            total_ms: 1.0,
            first_token_ms: 0.5,
            tokens_dropped: tx.drops(),
        };
        tx.finish_ref(stats);
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Token { step: 0, .. })));
        assert!(matches!(rx.recv(),
                         Some(StreamEvent::Token { step: 1, .. })));
        match rx.recv() {
            Some(StreamEvent::Done(stats)) => {
                assert_eq!(stats.tokens_dropped, 1,
                           "the gap must be visible in the stats");
            }
            other => panic!("want Done, got {other:?}"),
        }
        assert!(rx.recv().is_none());
    }

    #[test]
    #[should_panic(expected = "cannot hold max_steps")]
    fn admit_rejects_a_channel_too_small_for_the_budget() {
        let table = SessionTable::new();
        let (tx, _rx) = channel(1, 2); // cap 2 < max_steps 8
        table.admit(StreamRequest::new(1, vec![1], 8), tx,
                    Instant::now(), 4, 0, 0);
    }

    #[test]
    fn concurrent_advance_and_shed_keep_strict_order() {
        // the bug-2 regression: with per-session entry locks there is
        // no table-wide mutex serializing advance against shed — the
        // channel's own guards must keep the event stream well-formed
        // under every interleaving
        for delay_us in [0u64, 50, 200, 800, 2000] {
            let table = Arc::new(SessionTable::new());
            let (tx, rx) = channel(1, 128);
            let pending = table.admit(
                StreamRequest::new(1, vec![1, 2], 100), tx,
                Instant::now(), 4, 0, 0);
            let mut st = match pending.outcome {
                crate::coordinator::serving::Outcome::Stream(st) => st,
                _ => panic!("stream admit must yield a stream item"),
            };
            let session_key = st.session;
            let t = {
                let table = Arc::clone(&table);
                std::thread::spawn(move || loop {
                    match table.advance(&st, st.step as i32, 1.0,
                                        Instant::now()) {
                        Advance::Requeue(p) => {
                            st = match p.outcome {
                                crate::coordinator::serving::Outcome
                                    ::Stream(s) => s,
                                _ => unreachable!(),
                            };
                            std::thread::sleep(
                                Duration::from_micros(100));
                        }
                        Advance::Done(_) => return true,
                        Advance::Gone => return false,
                    }
                })
            };
            std::thread::sleep(Duration::from_micros(delay_us));
            let shed_rec =
                table.shed(session_key, ServeError::ShuttingDown, "test");
            let done = t.join().unwrap();
            // exactly one terminal path won
            assert!(done != shed_rec.is_some(),
                    "session must end in exactly one of Done/Shed \
                     (done={done}, shed={})", shed_rec.is_some());
            // the client stream: strictly increasing steps, then one
            // terminal, then None — no post-terminal tokens, ever
            let mut next_step = 0usize;
            let mut terminals = 0usize;
            while let Some(ev) = rx.recv() {
                match ev {
                    StreamEvent::Token { step, .. } => {
                        assert_eq!(terminals, 0,
                                   "token after a terminal");
                        assert_eq!(step, next_step,
                                   "steps must be gapless in order");
                        next_step += 1;
                    }
                    _ => terminals += 1,
                }
            }
            assert_eq!(terminals, 1, "exactly one terminal event");
            assert_eq!(table.live(), 0);
        }
    }

    #[test]
    fn shed_all_terminates_every_live_session() {
        let table = SessionTable::new();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            let (tx, rx) = channel(id, 4);
            table.admit(StreamRequest::new(id, vec![1], 4), tx,
                        Instant::now(), 2, 0, 0);
            rxs.push(rx);
        }
        let recs = table.shed_all(ServeError::ShuttingDown, "engine");
        assert_eq!(recs.len(), 3);
        assert!(recs.iter().all(|(tid, r)| *tid == 0
            && r.worker_class == "engine"
            && r.steps_done == 0
            && r.reason == ServeError::ShuttingDown));
        assert_eq!(table.live(), 0);
        for rx in rxs {
            match rx.wait() {
                Err(ServeError::ShuttingDown) => {}
                other => panic!("want ShuttingDown, got {other:?}"),
            }
        }
    }
}
