//! Per-worker-class paged session-state arena — the KV-cache analogue
//! for the sim serving engine.
//!
//! Every decode step needs the session's current sliding window (the
//! last `seq_len` tokens of `prompt ++ generated`).  Before the arena,
//! each re-admitted step recomputed that window from the
//! [`SessionTable`](super::SessionTable) under its locks, so per-step
//! cost grew with window length — the exact redundancy ElastiFormer
//! exists to remove.  The arena keeps each live session's *next*
//! window in a fixed pool of pages, deposited by the worker that just
//! executed the previous step:
//!
//! - **hit**: the page's `next_step` matches the step about to run —
//!   the cached window is served directly, no table access, no window
//!   reconstruction (O(1) in window length on the modeled sim cost);
//! - **miss / spill**: the page was evicted (pool full), the step was
//!   stolen by a worker class that never served this session, or the
//!   cached step index is stale — fall back to the table recompute;
//! - **recycle**: every terminal path (`Done`, `Shed`, `shed_all`,
//!   worker panic cleanup) frees the session's page exactly once;
//!   recycling is idempotent, so racing terminal paths cannot
//!   double-free or leak.
//!
//! One arena per **worker class**: workers of a class share executors
//! of one shape, so their pages are interchangeable, while a fast and
//! a slow class never fight over slots.  Placement affinity (the
//! session's pinned queue shard, see
//! [`StreamStep::shard`](super::StreamStep)) keeps continuations
//! landing on the workers that hold the pages, which is what makes
//! the hit rate high rather than accidental.
//!
//! The pool is fixed-size by construction (`pages` slots, allocated
//! once): admitting more concurrent sessions than pages does not grow
//! memory — least-recently-touched pages spill, and spilled sessions
//! keep decoding through the recompute path.
//!
//! **Speculative decode** ([`spec`](super::spec)) rides the same page
//! lifecycle with one refinement: a *draft* step reads its base
//! window through the normal `lookup` path, but its micro-rounds
//! evolve the window locally and deposit **nothing** — the page (and
//! the session table) still describe the pre-draft state while the
//! proposals are in flight.  Only the *verify* resolution stores a
//! page, keyed to the step index after the accepted prefix, so a
//! rejected draft leaves no poisoned window behind: the next draft
//! re-reads the authoritative state.  Terminal paths recycle exactly
//! once whether a session dies mid-draft, mid-verify, or in plain
//! decode — the draft buffer lives in the session table, never in a
//! page, so there is no second allocation to leak.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::sync::{Rank, RankedMutex};

/// One page slot: a session's cached window, valid for exactly one
/// upcoming step.
struct Page {
    session: u64,
    /// the step index this window serves — a lookup for any other
    /// step is a miss (stale page), so a page can never feed a wrong
    /// row to a reordered or replayed step
    next_step: usize,
    /// the session's sliding window for `next_step`, already trimmed
    /// to the executor's `seq_len`
    window: Vec<i32>,
}

struct ArenaInner {
    /// fixed pool, allocated once at construction
    slots: Vec<Option<Page>>,
    /// slot indices currently unoccupied
    free: Vec<usize>,
    /// session key → occupied slot index
    by_session: HashMap<u64, usize>,
    /// least-recently-touched session order, front = next to spill
    lru: VecDeque<u64>,
}

impl ArenaInner {
    /// Pool invariant: every slot is either free or owned by exactly
    /// one session.
    fn check(&self) {
        debug_assert_eq!(self.free.len() + self.by_session.len(),
                         self.slots.len(),
                         "arena slot leak or double-free");
        debug_assert_eq!(self.lru.len(), self.by_session.len(),
                         "lru out of sync with the session map");
    }

    fn touch(&mut self, session: u64) {
        if let Some(pos) = self.lru.iter().position(|&s| s == session) {
            self.lru.remove(pos);
        }
        self.lru.push_back(session);
    }
}

/// Paged cache of per-session decode windows for one worker class.
/// All methods are lock-internal and safe to call from every worker
/// thread; `pages == 0` builds a disabled arena (every lookup misses,
/// every store is a no-op) so the recompute path stays exercisable.
pub struct SessionArena {
    inner: RankedMutex<ArenaInner>,
    // Relaxed counters throughout: pure statistics, read by report
    // assembly after the worker joins — no ordering carried
    hits: AtomicUsize,
    misses: AtomicUsize,
    recycled: AtomicUsize,
    evicted: AtomicUsize,
}

impl SessionArena {
    pub fn new(pages: usize) -> SessionArena {
        SessionArena {
            inner: RankedMutex::new(Rank::ArenaPool, ArenaInner {
                slots: (0..pages).map(|_| None).collect(),
                free: (0..pages).rev().collect(),
                by_session: HashMap::new(),
                lru: VecDeque::new(),
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            recycled: AtomicUsize::new(0),
            evicted: AtomicUsize::new(0),
        }
    }

    /// Serve the cached window for `session`'s step `step`, if the
    /// arena holds a page valid for exactly that step.  Counts a hit
    /// or a miss — callers only consult the arena for decode steps
    /// (step >= 1), so prefills never dilute the hit rate.
    pub fn lookup(&self, session: u64, step: usize) -> Option<Vec<i32>> {
        let mut inner = self.inner.lock();
        let hit = inner.by_session.get(&session).copied().and_then(|i| {
            inner.slots[i]
                .as_ref()
                .filter(|p| p.next_step == step)
                .map(|p| p.window.clone())
        });
        match hit {
            Some(window) => {
                inner.touch(session);
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(window)
            }
            None => {
                drop(inner);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Deposit `session`'s window for its upcoming `next_step`,
    /// claiming a page (or refreshing the session's existing one).
    /// When the pool is full the least-recently-touched *other*
    /// session spills — its next lookup misses and recomputes — and
    /// its key is returned so the flight recorder can log the
    /// eviction; a refresh, a fresh slot or a disabled arena return
    /// `None`.
    pub fn store(&self, session: u64, next_step: usize,
                 window: Vec<i32>) -> Option<u64> {
        let mut inner = self.inner.lock();
        if inner.slots.is_empty() {
            return None; // arena disabled
        }
        let mut spilled = None;
        let slot = match inner.by_session.get(&session).copied() {
            Some(i) => i,
            None => {
                let i = match inner.free.pop() {
                    Some(i) => i,
                    None => {
                        // spill the coldest session to make room
                        let victim = inner
                            .lru
                            .pop_front()
                            .expect("full pool must have an lru entry");
                        let i = inner
                            .by_session
                            .remove(&victim)
                            .expect("lru entry must own a slot");
                        inner.slots[i] = None;
                        self.evicted.fetch_add(1, Ordering::Relaxed);
                        spilled = Some(victim);
                        i
                    }
                };
                inner.by_session.insert(session, i);
                i
            }
        };
        inner.slots[slot] = Some(Page { session, next_step, window });
        inner.touch(session);
        inner.check();
        spilled
    }

    /// Free `session`'s page.  Idempotent: returns `true` only for
    /// the call that actually freed a page, so racing terminal paths
    /// (worker Done vs engine shed vs shutdown sweep) recycle exactly
    /// once and a session with no page is a harmless no-op.
    pub fn recycle(&self, session: u64) -> bool {
        let mut inner = self.inner.lock();
        let Some(i) = inner.by_session.remove(&session) else {
            return false;
        };
        debug_assert!(
            inner.slots[i].as_ref().is_some_and(|p| p.session == session),
            "session map points at a foreign page");
        inner.slots[i] = None;
        inner.free.push(i);
        if let Some(pos) = inner.lru.iter().position(|&s| s == session) {
            inner.lru.remove(pos);
        }
        inner.check();
        drop(inner);
        self.recycled.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Free every page (engine shutdown, after `shed_all`).
    pub fn clear(&self) {
        let mut inner = self.inner.lock();
        let sessions: Vec<u64> =
            inner.by_session.keys().copied().collect();
        for session in sessions {
            let i = inner.by_session.remove(&session).unwrap();
            inner.slots[i] = None;
            inner.free.push(i);
            self.recycled.fetch_add(1, Ordering::Relaxed);
        }
        inner.lru.clear();
        inner.check();
    }

    /// Sessions currently holding a page.
    pub fn live(&self) -> usize {
        self.inner.lock().by_session.len()
    }

    /// Decode-step lookups served from cache.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Decode-step lookups that fell back to the table recompute.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Pages freed by terminal paths (each counted once).
    pub fn recycled(&self) -> usize {
        self.recycled.load(Ordering::Relaxed)
    }

    /// Pages spilled to make room under pool pressure.
    pub fn evicted(&self) -> usize {
        self.evicted.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_then_lookup_hits_exactly_the_stored_step() {
        let arena = SessionArena::new(4);
        arena.store(7, 3, vec![1, 2, 3]);
        // wrong step: stale page must miss, not serve a wrong row
        assert!(arena.lookup(7, 2).is_none());
        assert!(arena.lookup(7, 4).is_none());
        assert_eq!(arena.lookup(7, 3), Some(vec![1, 2, 3]));
        // unknown session misses
        assert!(arena.lookup(8, 3).is_none());
        assert_eq!(arena.hits(), 1);
        assert_eq!(arena.misses(), 3);
    }

    #[test]
    fn refresh_replaces_the_sessions_page_in_place() {
        let arena = SessionArena::new(1);
        assert_eq!(arena.store(1, 1, vec![10]), None);
        assert_eq!(arena.store(1, 2, vec![10, 11]), None,
                   "a refresh never spills anyone");
        assert!(arena.lookup(1, 1).is_none(), "old step must be stale");
        assert_eq!(arena.lookup(1, 2), Some(vec![10, 11]));
        assert_eq!(arena.live(), 1);
        assert_eq!(arena.evicted(), 0,
                   "refreshing a held page is not an eviction");
    }

    #[test]
    fn full_pool_spills_the_coldest_session() {
        let arena = SessionArena::new(2);
        arena.store(1, 1, vec![1]);
        arena.store(2, 1, vec![2]);
        arena.lookup(1, 1); // session 1 is now the warmest
        assert_eq!(arena.store(3, 1, vec![3]), Some(2),
                   "the spill must name the coldest session");
        assert_eq!(arena.evicted(), 1);
        assert!(arena.lookup(2, 1).is_none(), "spilled session misses");
        assert_eq!(arena.lookup(1, 1), Some(vec![1]));
        assert_eq!(arena.lookup(3, 1), Some(vec![3]));
        assert_eq!(arena.live(), 2);
    }

    #[test]
    fn recycle_is_exactly_once_and_idempotent() {
        let arena = SessionArena::new(2);
        arena.store(5, 1, vec![5]);
        assert!(arena.recycle(5), "first recycle frees the page");
        assert!(!arena.recycle(5), "second recycle is a no-op");
        assert!(!arena.recycle(99), "never-stored session is a no-op");
        assert_eq!(arena.recycled(), 1);
        assert_eq!(arena.live(), 0);
        // the slot is reusable afterwards
        arena.store(6, 1, vec![6]);
        arena.store(7, 1, vec![7]);
        assert_eq!(arena.live(), 2);
        assert_eq!(arena.evicted(), 0);
    }

    #[test]
    fn disabled_arena_misses_everything_quietly() {
        let arena = SessionArena::new(0);
        arena.store(1, 1, vec![1]);
        assert!(arena.lookup(1, 1).is_none());
        assert!(!arena.recycle(1));
        assert_eq!(arena.live(), 0);
    }

    #[test]
    fn clear_frees_every_page_once() {
        let arena = SessionArena::new(4);
        for s in 0..3u64 {
            arena.store(s, 1, vec![s as i32]);
        }
        arena.clear();
        assert_eq!(arena.live(), 0);
        assert_eq!(arena.recycled(), 3);
        arena.clear(); // idempotent
        assert_eq!(arena.recycled(), 3);
    }
}
