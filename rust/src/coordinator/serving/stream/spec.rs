//! Speculative decode — the elastic ladder drafting for itself.
//!
//! ElastiFormer's thesis is that one model runs at many compute
//! levels, with self-consistency against the full-compute output as
//! the acceptance signal.  This module turns that into serving speed:
//! when the engine runs with `spec_k > 0`, a decode session's
//! post-prefill steps alternate between two step shapes instead of
//! the one-token decode loop —
//!
//!  * a **draft** item runs `k` cheap micro-steps at the *lowest*
//!    tier the session's floor allows, proposing `k` tokens from the
//!    session's current window (served through the ordinary arena hit
//!    path — the draft's base window is a page lookup, not a window
//!    rebuild);
//!  * the matching **verify** item re-enters the queue on the
//!    session's affine shard (`requeue_to`, so the draft's class keeps
//!    serving it) and checks the whole proposal in ONE top-tier pass:
//!    `k + 1` rows, where row `j` is the base window extended by the
//!    first `j` proposed tokens.  Row `j`'s sampled token is the
//!    top tier's own prediction for position `j` — so the longest
//!    agreeing prefix of the proposals is exactly the run of tokens
//!    the full-compute model would have produced itself, and the
//!    first disagreeing position already carries the verifier's
//!    replacement token.  Every verify therefore emits between 1 and
//!    `k + 1` tokens (accepted prefix + the verifier's token at the
//!    first disagreement, or a bonus token after a fully-accepted
//!    run): progress is guaranteed even under total rejection.
//!
//! The proposals live in the session's [`DraftBuf`] between the two
//! passes and are consumed **exactly once** by the verify resolution
//! — accepted or rejected, the buffer (and the arena page the next
//! window is deposited under) is recycled on every terminal path the
//! plain decode loop already covers, so mid-draft sheds, worker
//! panics and shutdown leak nothing.
//!
//! `k` adapts per class: every verify resolution feeds the class
//! controller's accept-rate EWMA
//! ([`CapacityController::observe_accept`]), and each draft batch
//! asks [`CapacityController::draft_k`] how much speculation the
//! learned rate justifies.  Under persistent rejection `k` collapses
//! to 1, so speculative mode can never trail plain decode by more
//! than one wasted verification pass per token — the no-regret floor
//! the adversarial tests pin down.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::Result;

use super::super::report::StreamShedRecord;
use super::super::worker::{execute_quarantine, sample_token, Executor,
                           UnitFate, WorkerFault};
use super::super::{EngineShared, Outcome, Pending, Request, ServeError};
use super::{Advance, SessionTable, StreamStats, StreamStep};

/// Which step shape a queued stream item executes as.  Step 0 is
/// always a prefill regardless of phase; the phase routes steps >= 1
/// into the plain decode path or the speculative draft/verify loop
/// (the `StepKind` dimension of the batch key keeps the three shapes
/// from ever sharing an executed batch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPhase {
    /// one token per admission — the plain decode loop
    Decode,
    /// propose `k` tokens at a cheap tier
    Draft,
    /// check the session's proposals in one top-tier pass
    Verify,
}

/// One session's in-flight speculative proposals, stashed by a draft
/// step and consumed exactly once by the matching verify resolution.
pub(crate) struct DraftBuf {
    /// the window the draft run started from: the verify pass derives
    /// its `k + 1` rows from this base, so verification never depends
    /// on the (possibly spilled) arena page
    pub base_row: Vec<i32>,
    /// proposed tokens, draft order
    pub tokens: Vec<i32>,
    /// tier the proposals were drafted at (recorded in the session's
    /// tier trajectory for every accepted token)
    pub tier: f32,
}

/// Per-worker-class speculative accounting, mirrored into the
/// report's `WorkerClassInfo` at shutdown.  The three counters are
/// updated together at verify resolution — never at draft time — so
/// `drafted == accepted + rejected` holds under mid-draft sheds (a
/// proposal that never reaches verification is not "drafted" for
/// accounting purposes: no verification batch was spent on it).
/// Relaxed counters throughout: pure statistics, read by report
/// assembly after the workers join (the join is the synchronization
/// point) — no cross-thread ordering is carried by these values.
#[derive(Debug, Default)]
pub(crate) struct SpecCounters {
    drafted: AtomicUsize,
    accepted: AtomicUsize,
    rejected: AtomicUsize,
    verifies: AtomicUsize,
}

impl SpecCounters {
    pub(crate) fn new() -> SpecCounters {
        SpecCounters::default()
    }

    /// Record one resolved verify pass: `accepted` of `drafted`
    /// proposals agreed with the verifier.
    pub(crate) fn add(&self, drafted: usize, accepted: usize) {
        let accepted = accepted.min(drafted);
        self.drafted.fetch_add(drafted, Ordering::Relaxed);
        self.accepted.fetch_add(accepted, Ordering::Relaxed);
        self.rejected.fetch_add(drafted - accepted, Ordering::Relaxed);
        self.verifies.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn drafted(&self) -> usize {
        self.drafted.load(Ordering::Relaxed)
    }

    pub(crate) fn accepted(&self) -> usize {
        self.accepted.load(Ordering::Relaxed)
    }

    pub(crate) fn rejected(&self) -> usize {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Resolved verify passes — the per-class cycle count that turns
    /// accept totals into a tokens-per-admission estimate.
    pub(crate) fn verifies(&self) -> usize {
        self.verifies.load(Ordering::Relaxed)
    }
}

/// Longest agreeing prefix: how many leading proposals match the
/// verifier's own predictions.  `verifier[j]` is the top tier's token
/// for position `j` (computed on the base window extended by the
/// first `j` proposals), so agreement is positional and order-strict.
pub(crate) fn accept_prefix(proposed: &[i32], verifier: &[i32]) -> usize {
    proposed
        .iter()
        .zip(verifier.iter())
        .take_while(|(p, v)| p == v)
        .count()
}

/// What one verify resolution decided, alongside the session's next
/// move.  `drafted`/`accepted` feed the class counters and the
/// controller's accept-rate EWMA; `next_window` (present iff the
/// session requeues) is the post-acceptance window the worker
/// deposits into its class arena under the next step index.
pub(crate) struct VerifyResolution {
    pub advance: Advance,
    pub drafted: usize,
    pub accepted: usize,
    pub next_window: Option<Vec<i32>>,
}

impl SessionTable {
    /// Stash one completed draft run in its session and hand back the
    /// verify item to re-admit (on the session's affine shard).
    /// `None` if the session terminated concurrently — the proposals
    /// die with it (the caller recycles the arena pages; no counters
    /// move, see [`SpecCounters`]).
    pub(crate) fn stash_draft(&self, st: &StreamStep, base_row: Vec<i32>,
                              tokens: Vec<i32>, tier: f32, now: Instant)
                              -> Option<Pending> {
        let entry = self.entry(st.session)?;
        let mut sess = entry.state.lock();
        if sess.sender.is_done() {
            return None; // shed won the race: discard the proposals
        }
        sess.draft = Some(DraftBuf { base_row, tokens, tier });
        let req = Request {
            id: sess.id,
            tokens: Vec::new(),
            slo: sess.slo.clone(),
        };
        let trace_id = sess.trace_id;
        drop(sess);
        self.note_step_item();
        Some(Pending {
            req,
            submitted: now,
            trace_id,
            outcome: Outcome::Stream(StreamStep {
                session: st.session,
                step: st.step,
                max_steps: st.max_steps,
                started: st.started,
                shard: st.shard,
                phase: StepPhase::Verify,
            }),
        })
    }

    /// How many proposals a session's pending verify carries (`None`
    /// if the session or its draft buffer is gone) — what the verify
    /// batch packer uses to budget rows without building them yet.
    pub(crate) fn draft_len(&self, key: u64) -> Option<usize> {
        let entry = self.entry(key)?;
        let sess = entry.state.lock();
        if sess.sender.is_done() {
            return None;
        }
        sess.draft.as_ref().map(|d| d.tokens.len())
    }

    /// The `k + 1` verification rows for one session's stashed draft:
    /// row `j` is the base window extended by the first `j` proposals
    /// (trimmed to the executor window).  Non-destructive — the
    /// buffer is consumed by [`resolve_verify`](Self::resolve_verify).
    /// `None` if the session or its draft buffer is gone.
    pub(crate) fn verify_rows(&self, key: u64, seq_len: usize)
                              -> Option<Vec<Vec<i32>>> {
        let entry = self.entry(key)?;
        let sess = entry.state.lock();
        if sess.sender.is_done() {
            return None;
        }
        let draft = sess.draft.as_ref()?;
        let k = draft.tokens.len();
        let mut rows = Vec::with_capacity(k + 1);
        for j in 0..=k {
            let mut row = draft.base_row.clone();
            row.extend_from_slice(&draft.tokens[..j]);
            if row.len() > seq_len {
                let cut = row.len() - seq_len;
                row.drain(..cut);
            }
            rows.push(row);
        }
        Some(rows)
    }

    /// Resolve one verified draft run: consume the session's draft
    /// buffer exactly once, accept the longest agreeing prefix, emit
    /// the accepted tokens plus the verifier's token at the first
    /// disagreement (or its bonus token after a full accept) through
    /// the stream in order, and hand back the session's next move.
    ///
    /// `verifier_tokens` are the top-tier samples for the `k + 1`
    /// verification rows, in row order.  Emission is capped at the
    /// session's remaining budget, so a near-complete session never
    /// overshoots `max_steps`.
    pub(crate) fn resolve_verify(&self, st: &StreamStep,
                                 verifier_tokens: &[i32],
                                 verify_tier: f32, seq_len: usize,
                                 now: Instant) -> VerifyResolution {
        let gone = VerifyResolution {
            advance: Advance::Gone,
            drafted: 0,
            accepted: 0,
            next_window: None,
        };
        let Some(entry) = self.entry(st.session) else {
            return gone;
        };
        let mut sess = entry.state.lock();
        if sess.sender.is_done() {
            return gone; // shed won the race: buffer dies with it
        }
        let Some(draft) = sess.draft.take() else {
            return gone; // stale verify: nothing to resolve
        };
        let k = draft.tokens.len();
        debug_assert_eq!(verifier_tokens.len(), k + 1,
                         "one verifier token per verification row");
        let accepted = accept_prefix(
            &draft.tokens,
            &verifier_tokens[..k.min(verifier_tokens.len())]);
        // accepted proposals, then the verifier's own token: the
        // replacement at the first disagreement, or the bonus token
        // extending a fully-accepted run — capped to remaining budget
        let budget = sess.max_steps.saturating_sub(sess.generated.len());
        let emit = (accepted + 1).min(budget.max(1));
        let mut next_window = draft.base_row;
        for i in 0..emit {
            let (token, tier) = if i < accepted {
                (draft.tokens[i], draft.tier)
            } else {
                (*verifier_tokens.get(i).unwrap_or(&0), verify_tier)
            };
            let step = sess.generated.len();
            sess.generated.push(token);
            sess.tiers.push(tier);
            sess.sender.token(step, tier, token);
            next_window.push(token);
        }
        if next_window.len() > seq_len {
            let cut = next_window.len() - seq_len;
            next_window.drain(..cut);
        }
        if sess.generated.len() >= sess.max_steps {
            let stats = StreamStats {
                id: sess.id,
                class: sess.slo.name.clone(),
                steps: sess.generated.len(),
                tiers: sess.tiers.clone(),
                total_ms: now
                    .saturating_duration_since(sess.started)
                    .as_secs_f64() * 1e3,
                first_token_ms: sess.first_token_ms,
                tokens_dropped: sess.sender.drops(),
            };
            sess.sender.finish_ref(stats.clone());
            drop(sess); // entry lock released before the map lock
            self.sessions.lock().remove(&st.session);
            return VerifyResolution {
                advance: Advance::Done(stats),
                drafted: k,
                accepted,
                next_window: None,
            };
        }
        let req = Request {
            id: sess.id,
            tokens: Vec::new(),
            slo: sess.slo.clone(),
        };
        let trace_id = sess.trace_id;
        drop(sess);
        self.note_step_item();
        VerifyResolution {
            advance: Advance::Requeue(Pending {
                req,
                submitted: now,
                trace_id,
                outcome: Outcome::Stream(StreamStep {
                    session: st.session,
                    step: st.step + emit,
                    max_steps: st.max_steps,
                    started: st.started,
                    shard: st.shard,
                    phase: StepPhase::Draft,
                }),
            }),
            drafted: k,
            accepted,
            next_window: Some(next_window),
        }
    }
}

/// Run one popped **draft** batch: build each session's base window
/// (arena hit path first, table recompute fallback), execute `k`
/// cheap micro-steps at the draft tier, stash the proposals, and
/// re-admit each session's verify item on its affine shard.  Mirrors
/// the main worker loop's fault discipline (the retry → bisect →
/// quarantine ladder per micro-round; FATAL faults escalate as
/// [`WorkerFault`] with the batch intact — a requeued draft rebuilds
/// idempotently from the arena/table) and its one-lock-per-log
/// batching.  Returns the number of executed batches (the `k`
/// micro-steps count as one).
pub(crate) fn run_draft_batch(shared: &EngineShared, worker: usize,
                              class_idx: usize, class_name: &str,
                              exec: &mut dyn Executor, floor: f32,
                              live: Vec<Pending>)
                              -> Result<usize, WorkerFault> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let controller = &shared.controllers[class_idx];
    let arena = &shared.arenas[class_idx];
    let trace = shared.trace.as_deref();
    // the draft tier: normally the cheapest rung the batch's
    // strictest floor allows — but a persistently LOW accept rate
    // means the cheap proposals are being thrown away, so the
    // controller may escalate one rung (`draft_tier`).  Adaptive k
    // rides the same lock: the learned accept rate scales how much
    // speculation is worth buying, clamped so the verify pass
    // (k + 1 rows) always fits one executor batch.
    let (tier, k) = {
        let ctl = controller.lock();
        (ctl.draft_tier(floor), ctl.draft_k(shared.spec_k))
    };
    let k = k.min(batch.saturating_sub(1)).max(1);
    let mut windows: Vec<Vec<i32>> = Vec::with_capacity(live.len());
    let mut items: Vec<Pending> = Vec::with_capacity(live.len());
    let mut cached_rows = 0usize;
    for p in live {
        let Outcome::Stream(st) = &p.outcome else {
            unreachable!("draft batches contain only stream items");
        };
        let hit = arena.lookup(st.session, st.step);
        match hit {
            Some(row) => {
                cached_rows += 1;
                if let Some(t) = trace {
                    t.arena_hit(worker, p.trace_id);
                }
                windows.push(row);
            }
            None => {
                // drafts are always post-prefill, so every fallback
                // here is a real miss
                if let Some(t) = trace {
                    t.arena_miss(worker, p.trace_id);
                }
                match shared.sessions.compute_row(st.session, seq_len) {
                    Some(row) => windows.push(row),
                    None => continue, // session terminated: stale step
                }
            }
        }
        items.push(p);
    }
    if items.is_empty() {
        return Ok(0);
    }
    if let Some(t) = trace {
        t.draft_round(worker, items.len());
    }
    // per-session draft depth: never draft past the session's budget
    let mut depths: Vec<usize> = items
        .iter()
        .map(|p| match &p.outcome {
            Outcome::Stream(st) => {
                k.min(st.max_steps.saturating_sub(st.step)).max(1)
            }
            Outcome::OneShot(_) => unreachable!(),
        })
        .collect();
    let rounds = depths.iter().copied().max().unwrap_or(1);
    let mut bases: Vec<Vec<i32>> = windows.clone();
    let mut proposals: Vec<Vec<i32>> =
        vec![Vec::with_capacity(rounds); items.len()];
    let mut stream_sheds: Vec<StreamShedRecord> = Vec::new();
    for round in 0..rounds {
        if items.is_empty()
            || round >= depths.iter().copied().max().unwrap_or(0)
        {
            break; // everyone left is drafted out (or quarantined)
        }
        // each micro-round runs the fault ladder with one ROW per
        // unit, so a poison session is isolated per round
        let units: Vec<Vec<Vec<i32>>> =
            windows.iter().map(|w| vec![w.clone()]).collect();
        // only the first micro-step pays the batch's recompute mix;
        // later rounds extend windows already in hand — the arena's
        // incremental cost model applies to every one of them
        if round == 0 {
            exec.note_batch_mix(items.len() - cached_rows, cached_rows);
        } else {
            exec.note_batch_mix(0, items.len());
        }
        let (fates, any_fail) = match execute_quarantine(
            shared, class_idx, worker, exec, tier, &units)
        {
            Ok(ok) => ok,
            Err(fatal) => {
                // FATAL: escalate with every item intact — nothing is
                // stashed yet, so a requeued draft restarts cleanly
                controller.lock().observe_batch_outcome(false);
                let n = items.len();
                return Err(WorkerFault {
                    msg: format!(
                        "{} worker {worker}: draft tier {tier} batch \
                         of {n}: {fatal}",
                        exec.name()),
                    inflight: items,
                });
            }
        };
        controller.lock().observe_batch_outcome(!any_fail);
        let mut poisoned: Vec<(usize, String)> = Vec::new();
        for (i, fate) in fates.into_iter().enumerate() {
            match fate {
                UnitFate::Served(unit_rows) => {
                    if round >= depths[i] {
                        continue; // this session's budget is shorter
                    }
                    let token = sample_token(&unit_rows[0]);
                    proposals[i].push(token);
                    let win = &mut windows[i];
                    win.push(token);
                    if win.len() > seq_len {
                        let cut = win.len() - seq_len;
                        win.drain(..cut);
                    }
                }
                UnitFate::Poisoned(msg) => poisoned.push((i, msg)),
            }
        }
        // quarantined sessions leave the round arrays entirely (shed
        // with the Poisoned verdict) — left in place they would
        // re-fail every remaining micro-round
        for (i, msg) in poisoned.into_iter().rev() {
            let p = items.remove(i);
            let tid = p.trace_id;
            let Outcome::Stream(st) = p.outcome else {
                unreachable!();
            };
            if let Some(rec) = shared.sessions.shed(
                st.session, ServeError::Poisoned(msg), class_name)
            {
                stream_sheds.push(rec);
                if let Some(t) = trace {
                    t.terminal(worker, tid, "shed-poisoned");
                }
            }
            shared.recycle_session(st.session);
            windows.remove(i);
            bases.remove(i);
            proposals.remove(i);
            depths.remove(i);
        }
    }
    // stash every session's proposals and re-admit its verify pass on
    // the affine shard; a closed queue terminates the session now
    let now = Instant::now();
    for (i, p) in items.into_iter().enumerate() {
        let tid = p.trace_id;
        let Outcome::Stream(st) = p.outcome else {
            unreachable!();
        };
        let base_row = std::mem::take(&mut bases[i]);
        let tokens = std::mem::take(&mut proposals[i]);
        match shared.sessions.stash_draft(&st, base_row, tokens, tier,
                                          now) {
            Some(verify) => {
                let urgent = verify.req.slo.deadline.is_some();
                match shared.queue.requeue_to(st.shard, verify, urgent) {
                    Ok(_) => {
                        if let Some(t) = trace {
                            t.requeue(worker, tid);
                        }
                    }
                    Err(stale) => {
                        if let Outcome::Stream(st) = stale.outcome {
                            if let Some(rec) = shared.sessions.shed(
                                st.session, ServeError::ShuttingDown,
                                class_name)
                            {
                                stream_sheds.push(rec);
                                if let Some(t) = trace {
                                    t.terminal(worker, tid,
                                               "shed-shutdown");
                                }
                            }
                            shared.recycle_session(st.session);
                        }
                    }
                }
            }
            // session terminated concurrently: proposals discarded,
            // pages freed (recycle is idempotent)
            None => shared.recycle_session(st.session),
        }
    }
    if !stream_sheds.is_empty() {
        shared.stream_shed.lock().append(&mut stream_sheds);
    }
    Ok(1)
}

/// Run one popped **verify** batch: pack sessions while their
/// `k + 1`-row verification fits the executor batch (overflow items
/// go straight back to their affine shards untouched), execute ONE
/// top-tier pass, and resolve each session — emit the accepted
/// prefix + the verifier's token, feed the class accept-rate EWMA and
/// counters, deposit the next window in the arena, and requeue the
/// next draft (or complete the session).  Returns executed batches
/// (0 when every popped item was stale or deferred).
pub(crate) fn run_verify_batch(shared: &EngineShared, worker: usize,
                               class_idx: usize, class_name: &str,
                               exec: &mut dyn Executor,
                               live: Vec<Pending>)
                               -> Result<usize, WorkerFault> {
    let batch = exec.batch().max(1);
    let seq_len = exec.seq_len();
    let controller = &shared.controllers[class_idx];
    let arena = &shared.arenas[class_idx];
    let trace = shared.trace.as_deref();
    // verification is always the TOP tier: the whole point is the
    // full-compute model's own opinion of the cheap proposals
    let tier = shared.caps[0];
    let mut items: Vec<Pending> = Vec::new();
    // one quarantine unit per SESSION: its k + 1 verification rows
    // live or die together — bisection isolates a poison session, not
    // a poison row of one (the rows are one request's data)
    let mut units: Vec<Vec<Vec<i32>>> = Vec::new();
    let mut used_rows = 0usize;
    let mut stream_sheds: Vec<StreamShedRecord> = Vec::new();
    for p in live {
        let Outcome::Stream(st) = &p.outcome else {
            unreachable!("verify batches contain only stream items");
        };
        let Some(k) = shared.sessions.draft_len(st.session) else {
            // session or buffer gone: stale step, free its pages
            shared.recycle_session(st.session);
            continue;
        };
        debug_assert!(k + 1 <= batch,
                      "draft_k is clamped to batch - 1 at draft time");
        if used_rows + k + 1 > batch {
            // no room in this pass: defer the whole session untouched
            // (its buffer stays stashed; the item keeps its identity)
            let urgent = p.req.slo.deadline.is_some();
            let tid = p.trace_id;
            let Outcome::Stream(st) = &p.outcome else {
                unreachable!();
            };
            let shard = st.shard;
            let session = st.session;
            match shared.queue.requeue_to(shard, p, urgent) {
                Ok(_) => {
                    if let Some(t) = trace {
                        t.requeue(worker, tid);
                    }
                }
                Err(stale) => {
                    if let Outcome::Stream(st) = stale.outcome {
                        if let Some(rec) = shared.sessions.shed(
                            st.session, ServeError::ShuttingDown,
                            class_name)
                        {
                            stream_sheds.push(rec);
                            if let Some(t) = trace {
                                t.terminal(worker, tid,
                                           "shed-shutdown");
                            }
                        }
                        shared.recycle_session(session);
                    }
                }
            }
            continue;
        }
        match shared.sessions.verify_rows(st.session, seq_len) {
            Some(vrows) => {
                used_rows += vrows.len();
                units.push(vrows);
                items.push(p);
            }
            None => shared.recycle_session(st.session),
        }
    }
    if items.is_empty() {
        if !stream_sheds.is_empty() {
            shared.stream_shed.lock().append(&mut stream_sheds);
        }
        return Ok(0);
    }
    // verification rows are full-window passes rebuilt from the draft
    // buffer — recompute-cost rows in the arena's cost model
    exec.note_batch_mix(used_rows, 0);
    let (fates, any_fail) = match execute_quarantine(
        shared, class_idx, worker, exec, tier, &units)
    {
        Ok(ok) => ok,
        Err(fatal) => {
            // FATAL: escalate with the packed sessions intact — their
            // draft buffers stay stashed, so a requeued verify item
            // rebuilds its rows idempotently
            controller.lock().observe_batch_outcome(false);
            let n = items.len();
            return Err(WorkerFault {
                msg: format!(
                    "{} worker {worker}: verify tier {tier} batch of \
                     {n}: {fatal}",
                    exec.name()),
                inflight: items,
            });
        }
    };
    controller.lock().observe_batch_outcome(!any_fail);
    let done = Instant::now();
    let counters = &shared.spec[class_idx];
    let mut stream_done: Vec<StreamStats> = Vec::new();
    for (p, fate) in items.into_iter().zip(fates) {
        let tid = p.trace_id;
        let Outcome::Stream(st) = p.outcome else {
            unreachable!();
        };
        let unit_rows = match fate {
            UnitFate::Served(rows) => rows,
            UnitFate::Poisoned(msg) => {
                // the poison session sheds alone; its co-packed
                // neighbours resolve normally.  Counters deliberately
                // do NOT move — they move only at verify resolution,
                // so drafted == accepted + rejected still holds.
                if let Some(rec) = shared.sessions.shed(
                    st.session, ServeError::Poisoned(msg), class_name)
                {
                    stream_sheds.push(rec);
                    if let Some(t) = trace {
                        t.terminal(worker, tid, "shed-poisoned");
                    }
                }
                shared.recycle_session(st.session);
                continue;
            }
        };
        let verifier_tokens: Vec<i32> = unit_rows
            .iter()
            .map(|r| sample_token(r))
            .collect();
        let res = shared.sessions.resolve_verify(
            &st, &verifier_tokens, tier, seq_len, done);
        if res.drafted > 0 {
            counters.add(res.drafted, res.accepted);
            controller
                .lock()
                .observe_accept(res.accepted, res.drafted);
            // mirrors the counters exactly: summed accepted/rejected
            // over these events must equal the report's spec totals
            if let Some(t) = trace {
                t.verify_resolve(worker, tid, res.accepted,
                                 res.drafted - res.accepted);
            }
        }
        match res.advance {
            Advance::Requeue(next) => {
                if let (Some(win), Outcome::Stream(nst)) =
                    (res.next_window, &next.outcome)
                {
                    let evicted =
                        arena.store(nst.session, nst.step, win);
                    if let (Some(t), Some(victim)) = (trace, evicted) {
                        t.arena_evict(worker, victim);
                    }
                }
                let urgent = next.req.slo.deadline.is_some();
                match shared.queue.requeue_to(st.shard, next, urgent) {
                    Ok(_) => {
                        if let Some(t) = trace {
                            t.requeue(worker, tid);
                        }
                    }
                    Err(stale) => {
                        if let Outcome::Stream(st) = stale.outcome {
                            if let Some(rec) = shared.sessions.shed(
                                st.session, ServeError::ShuttingDown,
                                class_name)
                            {
                                stream_sheds.push(rec);
                                if let Some(t) = trace {
                                    t.terminal(worker, tid,
                                               "shed-shutdown");
                                }
                            }
                            shared.recycle_session(st.session);
                        }
                    }
                }
            }
            Advance::Done(stats) => {
                shared.recycle_session(st.session);
                if let Some(t) = trace {
                    t.terminal(worker, tid, "stream-done");
                }
                stream_done.push(stats);
            }
            Advance::Gone => {
                shared.recycle_session(st.session);
            }
        }
    }
    if !stream_done.is_empty() {
        shared.stream_done.lock().append(&mut stream_done);
    }
    if !stream_sheds.is_empty() {
        shared.stream_shed.lock().append(&mut stream_sheds);
    }
    Ok(1)
}

#[cfg(test)]
mod tests {
    use super::super::{channel, StreamRequest};
    use super::*;

    fn admit_spec(table: &SessionTable, id: u64, prompt: Vec<i32>,
                  max_steps: usize, spec_k: usize)
                  -> (StreamStep, super::super::StreamResponse) {
        let (tx, rx) = channel(id, max_steps + 1);
        let pending = table.admit(
            StreamRequest::new(id, prompt, max_steps), tx,
            Instant::now(), 4, spec_k, 0);
        let st = match pending.outcome {
            Outcome::Stream(st) => st,
            _ => panic!("stream admit must yield a stream item"),
        };
        (st, rx)
    }

    #[test]
    fn accept_prefix_is_the_longest_agreeing_run() {
        assert_eq!(accept_prefix(&[1, 2, 3], &[1, 2, 3, 9]), 3);
        assert_eq!(accept_prefix(&[1, 2, 3], &[1, 9, 3, 9]), 1);
        assert_eq!(accept_prefix(&[1, 2, 3], &[9, 2, 3, 9]), 0);
        assert_eq!(accept_prefix(&[], &[7]), 0);
    }

    #[test]
    fn spec_counters_reconcile_by_construction() {
        let c = SpecCounters::new();
        c.add(4, 3);
        c.add(2, 0);
        c.add(3, 3);
        assert_eq!(c.drafted(), 9);
        assert_eq!(c.accepted(), 6);
        assert_eq!(c.rejected(), 3);
        assert_eq!(c.drafted(), c.accepted() + c.rejected());
        assert_eq!(c.verifies(), 3, "one cycle per resolved verify");
        // over-reporting accepts is clamped, the invariant holds
        c.add(2, 5);
        assert_eq!(c.drafted(), c.accepted() + c.rejected());
    }

    #[test]
    fn speculative_sessions_requeue_as_drafts_after_prefill() {
        let table = SessionTable::new();
        let (st, _rx) = admit_spec(&table, 1, vec![5], 8, 4);
        assert_eq!(st.phase, StepPhase::Decode, "step 0 is a prefill");
        match table.advance(&st, 7, 1.0, Instant::now()) {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(next) => {
                    assert_eq!(next.phase, StepPhase::Draft,
                               "spec sessions draft after prefill");
                    assert_eq!(next.step, 1);
                }
                _ => panic!("requeue must stay a stream item"),
            },
            _ => panic!("budget left: must requeue"),
        }
    }

    #[test]
    fn stash_then_verify_rows_extend_the_base_window() {
        let table = SessionTable::new();
        let (st, _rx) = admit_spec(&table, 2, vec![5], 8, 3);
        let verify = table
            .stash_draft(&st, vec![5, 7], vec![20, 21], 0.25,
                         Instant::now())
            .expect("live session must stash");
        match &verify.outcome {
            Outcome::Stream(v) => {
                assert_eq!(v.phase, StepPhase::Verify);
                assert_eq!(v.step, st.step, "verify re-checks the same \
                                             position");
                assert_eq!(v.shard, st.shard, "affinity preserved");
            }
            _ => panic!("verify must be a stream item"),
        }
        assert_eq!(table.draft_len(st.session), Some(2));
        let rows = table.verify_rows(st.session, 3).unwrap();
        assert_eq!(rows.len(), 3, "k + 1 rows");
        assert_eq!(rows[0], vec![5, 7]);
        assert_eq!(rows[1], vec![5, 7, 20]);
        assert_eq!(rows[2], vec![7, 20, 21], "trimmed to seq_len");
    }

    #[test]
    fn resolve_verify_accepts_prefix_and_falls_back_to_verifier() {
        let table = SessionTable::new();
        let (st0, rx) = admit_spec(&table, 3, vec![5], 8, 3);
        // prefill emits token 100 at step 0
        let st = match table.advance(&st0, 100, 1.0, Instant::now()) {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(st) => st,
                _ => unreachable!(),
            },
            _ => panic!("must requeue"),
        };
        table
            .stash_draft(&st, vec![5, 100], vec![20, 21, 22], 0.25,
                         Instant::now())
            .unwrap();
        // verifier agrees with 20, 21 but wants 30 at position 2
        let res = table.resolve_verify(&st, &[20, 21, 30, 31], 1.0, 8,
                                       Instant::now());
        assert_eq!(res.drafted, 3);
        assert_eq!(res.accepted, 2);
        let next = match res.advance {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(st) => st,
                _ => unreachable!(),
            },
            other => panic!(
                "budget left: must requeue, got {:?}",
                matches!(other, Advance::Done(_))),
        };
        assert_eq!(next.step, st.step + 3,
                   "accepted prefix + the verifier's replacement");
        assert_eq!(next.phase, StepPhase::Draft);
        assert_eq!(res.next_window.unwrap(), vec![5, 100, 20, 21, 30]);
        // the draft buffer is consumed exactly once
        assert_eq!(table.draft_len(st.session), None);
        // client saw prefill + 3 speculative tokens, in order
        let mut steps = Vec::new();
        let mut tokens = Vec::new();
        while let Ok(Some(ev)) =
            rx.recv_timeout(std::time::Duration::from_millis(50))
        {
            if let super::super::StreamEvent::Token { step, token, .. } =
                ev
            {
                steps.push(step);
                tokens.push(token);
            } else {
                break;
            }
        }
        assert_eq!(steps, vec![0, 1, 2, 3]);
        assert_eq!(tokens, vec![100, 20, 21, 30]);
    }

    #[test]
    fn resolve_verify_total_rejection_still_makes_progress() {
        let table = SessionTable::new();
        let (st0, _rx) = admit_spec(&table, 4, vec![5], 8, 3);
        let st = match table.advance(&st0, 100, 1.0, Instant::now()) {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(st) => st,
                _ => unreachable!(),
            },
            _ => panic!("must requeue"),
        };
        table
            .stash_draft(&st, vec![5, 100], vec![20, 21], 0.25,
                         Instant::now())
            .unwrap();
        let res = table.resolve_verify(&st, &[90, 91, 92], 1.0, 8,
                                       Instant::now());
        assert_eq!(res.accepted, 0);
        assert_eq!(res.drafted, 2);
        match res.advance {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(next) => assert_eq!(
                    next.step, st.step + 1,
                    "the verifier's own token is always emitted"),
                _ => unreachable!(),
            },
            _ => panic!("must requeue"),
        }
    }

    #[test]
    fn resolve_verify_caps_emission_at_the_session_budget() {
        let table = SessionTable::new();
        // max_steps 3: prefill emits one, so budget for spec is 2
        let (st0, rx) = admit_spec(&table, 5, vec![5], 3, 4);
        let st = match table.advance(&st0, 100, 1.0, Instant::now()) {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(st) => st,
                _ => unreachable!(),
            },
            _ => panic!("must requeue"),
        };
        table
            .stash_draft(&st, vec![5, 100], vec![20, 21, 22, 23], 0.25,
                         Instant::now())
            .unwrap();
        // full agreement would emit 5 tokens; the budget allows 2
        let res = table.resolve_verify(&st, &[20, 21, 22, 23, 24], 1.0,
                                       8, Instant::now());
        assert_eq!(res.drafted, 4);
        assert_eq!(res.accepted, 4);
        match res.advance {
            Advance::Done(stats) => {
                assert_eq!(stats.steps, 3, "never overshoots max_steps");
            }
            _ => panic!("budget exhausted: must complete"),
        }
        assert_eq!(table.live(), 0);
        let stats = rx.wait().expect("session completed");
        assert_eq!(stats.steps, 3);
        assert_eq!(stats.tiers.len(), 3,
                   "one tier record per emitted token");
    }

    #[test]
    fn shed_session_discards_draft_and_verify_resolution() {
        let table = SessionTable::new();
        let (st0, rx) = admit_spec(&table, 6, vec![5], 8, 3);
        let st = match table.advance(&st0, 100, 1.0, Instant::now()) {
            Advance::Requeue(p) => match p.outcome {
                Outcome::Stream(st) => st,
                _ => unreachable!(),
            },
            _ => panic!("must requeue"),
        };
        table
            .stash_draft(&st, vec![5, 100], vec![20], 0.25,
                         Instant::now())
            .unwrap();
        let rec = table.shed(st.session, ServeError::ShuttingDown,
                             "test");
        assert!(rec.is_some());
        // a late verify resolution is Gone and moves no counters
        let res = table.resolve_verify(&st, &[20, 21], 1.0, 8,
                                       Instant::now());
        assert!(matches!(res.advance, Advance::Gone));
        assert_eq!(res.drafted, 0);
        // a late stash is refused too
        assert!(table
            .stash_draft(&st, vec![5], vec![9], 0.25, Instant::now())
            .is_none());
        assert!(matches!(rx.wait(), Err(ServeError::ShuttingDown)));
    }
}
