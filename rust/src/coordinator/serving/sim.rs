//! Deterministic simulation executor: a mock serving backend with a
//! seeded per-tier latency model, so the entire serving pipeline —
//! submission, admission verdicts, dynamic batching, SLO-aware capacity
//! control, N-worker execution, response delivery, drain — runs
//! hermetically in `cargo test` with no artifacts on disk.
//!
//! Latency model per batch: `base_ms + ms_per_capacity * tier +
//! jitter_ms * u`, with `u ~ U[0,1)` drawn from a per-worker
//! `rng::Rng` stream (SplitMix-forked from the spec seed, so every run
//! is bit-reproducible).  Lower tiers are cheaper, mirroring the real
//! `serve_cap*` executables where token compaction shrinks the matmuls.
//!
//! Clock discipline: the modeled draw is only the *sleep input*.  All
//! `Completion` timings that reach a caller's `Reply` are measured by
//! the worker on one monotonic `Instant` clock (admission stamp ->
//! exec start -> exec end), never derived from the model — a sleep that
//! returns early or late can therefore never produce a negative queue
//! wait or an exec time that disagrees with wall clock.  The per-batch
//! [`SimBatchLog`] records both values so tests can compare them.

use std::time::{Duration, Instant};

use anyhow::Result;

use super::tier_matches;
use super::worker::{ExecOutput, Executor};
use super::FatalExecError;
use crate::rng::Rng;

/// Seeded chaos plan for the simulated backend: every fault the
/// engine's tolerance ladder must survive, drawn from the same
/// per-worker deterministic RNG stream as the latency model, so a
/// given `(seed, plan)` replays the exact same fault sequence on
/// every run.  `Default` is all-zeros — no faults, bit-identical to
/// the pre-chaos simulator.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultPlan {
    /// per-execute probability of a *transient* failure (an `Err`
    /// without the fatal marker — retryable in place)
    pub fail_p: f64,
    /// per-execute probability of a *fatal* fault (a
    /// [`FatalExecError`] — the executor must be torn down and
    /// respawned through the class factory)
    pub fatal_p: f64,
    /// per-execute probability of a latency spike
    pub spike_p: f64,
    /// extra modeled latency of one spike (ms)
    pub spike_ms: f64,
    /// tier skew on `fail_p`: > 0 makes *cheaper* tiers proportionally
    /// flakier (`p = fail_p * (1 + tier_bias * (1 - tier/top))`), so
    /// brownout-vs-shed dynamics can be exercised per rung; 0 = flat
    pub tier_bias: f64,
    /// deterministic poison marker: any batch whose token tensor
    /// contains this value *always* fails transiently, regardless of
    /// retries — the quarantine ladder's target.  0 disables (padded
    /// rows are zero-filled, so 0 can never be a marker).
    pub poison_token: i32,
}

/// Parameters of the simulated backend (all latencies per *batch*).
#[derive(Debug, Clone, Copy)]
pub struct SimSpec {
    pub batch: usize,
    pub seq_len: usize,
    /// fixed per-batch overhead, independent of tier
    pub base_ms: f64,
    /// additional cost of a full-capacity batch; scales with the tier
    pub ms_per_capacity: f64,
    /// uniform noise added on top (0 disables)
    pub jitter_ms: f64,
    /// modeled cost of *preparing* one window token for a batch row:
    /// a recomputed row (arena miss) pays `seq_len` tokens, a cached
    /// row (arena hit) pays 1 — the KV-saving the session arena is
    /// judged by (0 disables window-cost modeling entirely)
    pub recompute_ms_per_token: f64,
    /// probability that a row sampled at a *floored* tier disagrees
    /// with the top tier, scaled by how far below the top the tier
    /// sits: a row at `tier` diverges with probability `divergence *
    /// (1 - tier / top_tier)`, so the top tier itself never diverges
    /// and cheaper draft tiers disagree more often — the
    /// tier-dependent error model speculative decoding is judged by.
    /// 0 (the default) keeps every tier's argmax identical, exactly
    /// as before.
    pub divergence: f64,
    /// injected chaos (fault probabilities, poison marker, latency
    /// spikes); `FaultPlan::default()` = no faults
    pub fault: FaultPlan,
    pub seed: u64,
}

impl SimSpec {
    pub fn standard() -> SimSpec {
        SimSpec {
            batch: 8,
            seq_len: 32,
            base_ms: 0.5,
            ms_per_capacity: 1.5,
            jitter_ms: 0.2,
            recompute_ms_per_token: 0.0,
            divergence: 0.0,
            fault: FaultPlan::default(),
            seed: 0x51AB,
        }
    }

    /// Zero-latency variant for logic-only tests (queue/batcher/FIFO
    /// invariants) where wall-clock is irrelevant.
    pub fn instant() -> SimSpec {
        SimSpec {
            base_ms: 0.0,
            ms_per_capacity: 0.0,
            jitter_ms: 0.0,
            ..SimSpec::standard()
        }
    }

    /// Attach a chaos plan.
    pub fn with_fault(mut self, fault: FaultPlan) -> SimSpec {
        self.fault = fault;
        self
    }
}

/// One executed batch, as recorded by the simulator: the modeled draw
/// (what the latency model asked the sleep for) and the wall-clock time
/// the execute call actually took, on the same `Instant` clock the
/// worker stamps completions with.
#[derive(Debug, Clone, Copy)]
pub struct SimBatchLog {
    pub tier: f32,
    /// latency drawn from the seeded model (the sleep input)
    pub modeled_ms: f64,
    /// measured wall time of the execute call (>= modeled on a sane
    /// scheduler, but never trusted to be)
    pub wall_ms: f64,
}

/// The simulation backend.  Each worker gets its own instance (the
/// engine factory is called per worker), with an independent seeded RNG
/// stream derived from `spec.seed` and the worker index.
pub struct SimExecutor {
    spec: SimSpec,
    tiers: Vec<f32>,
    rng: Rng,
    record: bool,
    /// row mix of the batch about to execute, as announced by the
    /// worker through [`Executor::note_batch_mix`]: (recomputed rows,
    /// arena-cached rows); consumed (and reset) by the next `execute`
    pending_mix: (usize, usize),
    /// every executed batch, in this worker's execution order (only
    /// recorded when enabled — see [`SimExecutor::record_log`])
    pub log: Vec<SimBatchLog>,
}

impl SimExecutor {
    /// Direct construction records the per-batch log (handy in tests
    /// that hold the executor).  [`factory`] disables recording: inside
    /// the engine the boxed executor dies with its worker thread, so
    /// the log would be unreachable write-only growth on long sweeps.
    pub fn new(spec: SimSpec, tiers: &[f32], worker: usize) -> SimExecutor {
        assert!(!tiers.is_empty(), "no tiers configured");
        SimExecutor {
            spec,
            tiers: tiers.to_vec(),
            // independent, deterministic per-worker stream
            rng: Rng::new(spec.seed
                ^ (worker as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)),
            record: true,
            pending_mix: (0, 0),
            log: Vec::new(),
        }
    }

    /// Enable/disable per-batch log recording.
    pub fn record_log(mut self, on: bool) -> SimExecutor {
        self.record = on;
        self
    }

    /// Draw the next batch latency at `tier` from the seeded model.
    pub fn latency_ms(&mut self, tier: f32) -> f64 {
        self.spec.base_ms
            + self.spec.ms_per_capacity * tier as f64
            + self.spec.jitter_ms * self.rng.f64()
    }
}

impl Executor for SimExecutor {
    fn batch(&self) -> usize {
        self.spec.batch
    }

    fn seq_len(&self) -> usize {
        self.spec.seq_len
    }

    fn execute(&mut self, tier: f32, tokens: &[i32]) -> Result<ExecOutput> {
        anyhow::ensure!(
            tokens.len() == self.spec.batch * self.spec.seq_len,
            "sim executor: got {} tokens, want {} * {}",
            tokens.len(), self.spec.batch, self.spec.seq_len);
        anyhow::ensure!(
            self.tiers.iter().any(|&t| tier_matches(t, tier)),
            "sim executor: tier {tier} not in {:?}", self.tiers);
        // ---- injected chaos (every draw gated behind p > 0, so a
        // zero FaultPlan consumes no RNG and legacy streams replay
        // bit-identically) ----
        let plan = self.spec.fault;
        if plan.poison_token != 0 && tokens.contains(&plan.poison_token)
        {
            // the deterministic poison: ALWAYS a transient failure, so
            // retries never clear it and only the bisect ladder can
            // isolate the marked request
            anyhow::bail!("sim executor: poison token {} in batch",
                          plan.poison_token);
        }
        if plan.fatal_p > 0.0 && self.rng.f64() < plan.fatal_p {
            return Err(FatalExecError(
                "sim executor: injected fatal fault".into()).into());
        }
        if plan.fail_p > 0.0 {
            let top = self
                .tiers
                .iter()
                .cloned()
                .fold(f32::NEG_INFINITY, f32::max) as f64;
            let skew = 1.0
                + plan.tier_bias
                    * (1.0 - tier as f64 / top.max(1e-9)).max(0.0);
            if self.rng.f64() < plan.fail_p * skew {
                anyhow::bail!(
                    "sim executor: injected transient fault at tier \
                     {tier}");
            }
        }
        let spike_ms = if plan.spike_p > 0.0
            && self.rng.f64() < plan.spike_p
        {
            plan.spike_ms
        } else {
            0.0
        };
        // window-preparation cost: a recomputed row rebuilds its whole
        // sliding window (O(seq_len)), an arena-cached row appends one
        // token (O(1)) — the modeled saving the session arena buys
        let (recompute_rows, cached_rows) =
            std::mem::take(&mut self.pending_mix);
        let window_ms = self.spec.recompute_ms_per_token
            * (recompute_rows * self.spec.seq_len + cached_rows) as f64;
        let modeled_ms = self.latency_ms(tier) + window_ms + spike_ms;
        let t0 = Instant::now();
        if modeled_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(modeled_ms / 1e3));
        }
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        if self.record {
            self.log.push(SimBatchLog { tier, modeled_ms, wall_ms });
        }
        if self.spec.divergence > 0.0 {
            // tier-dependent disagreement: two logits per row, where
            // token 0 is "the top tier's answer" and token 1 is a
            // divergent sample.  A row at the top tier always argmaxes
            // to 0; a floored row flips to 1 with probability
            // `divergence * (1 - tier / top_tier)` — cheap draft tiers
            // disagree with their verifier more often, which is the
            // acceptance dynamics speculative decode must survive
            let top = self
                .tiers
                .iter()
                .cloned()
                .fold(f32::NEG_INFINITY, f32::max) as f64;
            let p = self.spec.divergence
                * (1.0 - (tier as f64 / top.max(1e-9))).max(0.0);
            let mut logits = Vec::with_capacity(2 * self.spec.batch);
            for _ in 0..self.spec.batch {
                if p > 0.0 && self.rng.f64() < p {
                    logits.extend_from_slice(&[0.0, tier]);
                } else {
                    logits.extend_from_slice(&[tier, 0.0]);
                }
            }
            return Ok(ExecOutput { logits });
        }
        // one synthetic logit row per batch slot: the tier served.
        // deterministic, and enough for callers to check that logits
        // really did flow back through their Response
        Ok(ExecOutput { logits: vec![tier; self.spec.batch] })
    }

    fn supports(&self, tier: f32) -> bool {
        self.tiers.iter().any(|&t| tier_matches(t, tier))
    }

    fn name(&self) -> &'static str {
        "sim"
    }

    fn note_batch_mix(&mut self, recompute_rows: usize,
                      cached_rows: usize) {
        self.pending_mix = (recompute_rows, cached_rows);
    }
}

/// Executor factory for [`super::ElasticEngine::start`]: one fresh
/// [`SimExecutor`] per worker over the given capacity ladder.
pub fn factory(spec: SimSpec, tiers: Vec<f32>)
               -> impl Fn(usize) -> Result<Box<dyn Executor>>
                   + Send + Sync + 'static {
    move |worker| {
        // log disabled: the boxed executor is unreachable from outside
        // the worker thread, so recording would only leak memory
        Ok(Box::new(SimExecutor::new(spec, &tiers, worker)
            .record_log(false)) as Box<dyn Executor>)
    }
}

/// Drive one hermetic sim-pipeline point: flood-submit `n` requests
/// into an engine with the given worker/shard topology over `spec`,
/// wait every response out, and return the report.  With a near-zero
/// latency spec, wall-clock is dominated by the host pipeline
/// (admission, shard selection, stealing, batch formation), which is
/// exactly what the shared-vs-sharded queue comparison in
/// `BENCH_serving.json` measures.  `shards = 1` reproduces the
/// pre-sharding single shared deque; `shards = workers` (or 0 = auto)
/// is the sharded work-stealing topology.
pub fn pipeline_point(spec: SimSpec, workers: usize, shards: usize,
                      n: usize) -> Result<super::ServeReport> {
    let cfg = super::ServeConfig::sim()
        .with_workers(workers)
        .with_queue_shards(shards)
        .with_queue_bound(128)
        .with_max_batch_wait(Duration::from_micros(200));
    let caps = cfg.capacities();
    let engine = super::ElasticEngine::start(cfg, factory(spec, caps))?;
    let responses: Vec<super::Response> = (0..n as u64)
        .map(|id| {
            engine.submit(super::Request::new(id, vec![1; spec.seq_len]))
        })
        .collect();
    for r in responses {
        r.wait()
            .map_err(|e| anyhow::anyhow!("sim pipeline serve failed: {e}"))?;
    }
    engine.shutdown()
}

/// Drive one hermetic *heterogeneous* sim-pipeline point: a named
/// worker class per `(name, spec, workers)` entry — e.g. a fast-GPU
/// class and a slow-CPU class with very different `SimSpec` latency
/// models — behind one sharded queue, flood-submit `n` requests, wait
/// every response out, and return the report (whose
/// `worker_class_sections` carry each class's tier mix and learned
/// exec estimates).  All specs must agree on `seq_len` (one token
/// shape per engine); batch sizes may differ per class.
pub fn pipeline_point_classes(classes: &[(&str, SimSpec, usize)],
                              shards: usize, n: usize)
                              -> Result<super::ServeReport> {
    anyhow::ensure!(!classes.is_empty(), "no worker classes given");
    let seq_len = classes[0].1.seq_len;
    anyhow::ensure!(
        classes.iter().all(|(_, s, _)| s.seq_len == seq_len),
        "worker classes must share one seq_len");
    let mut cfg = super::ServeConfig::sim()
        .with_queue_shards(shards)
        .with_queue_bound(128)
        .with_max_batch_wait(Duration::from_micros(200));
    let caps = cfg.capacities();
    for (name, spec, workers) in classes {
        cfg = cfg.with_worker_class(name, *workers,
                                    factory(*spec, caps.clone()));
    }
    let engine = super::ElasticEngine::start_fleet(cfg)?;
    let responses: Vec<super::Response> = (0..n as u64)
        .map(|id| engine.submit(super::Request::new(id, vec![1; seq_len])))
        .collect();
    for r in responses {
        r.wait()
            .map_err(|e| anyhow::anyhow!("hetero sim serve failed: {e}"))?;
    }
    engine.shutdown()
}

/// Drive one hermetic *streaming* pipeline point: `sessions`
/// concurrent decode sessions of `decode_steps` tokens each through
/// `submit_stream` on a sharded engine over `spec` — prompts prefill,
/// every generated token is a re-admitted decode step batching across
/// sessions (continuous batching), and each session's stream must end
/// in `Done`.  Returns the report, whose `stream_done` carries every
/// session's per-step tier trajectory and whose
/// [`tokens_per_s`](super::ServeReport::tokens_per_s) is the
/// streaming throughput figure recorded in `BENCH_serving.json`.
pub fn streaming_point(spec: SimSpec, workers: usize, shards: usize,
                       sessions: usize, decode_steps: usize)
                       -> Result<super::ServeReport> {
    let cfg = super::ServeConfig::sim()
        .with_workers(workers)
        .with_queue_shards(shards)
        .with_queue_bound(128)
        .with_max_batch_wait(Duration::from_micros(200));
    let caps = cfg.capacities();
    let prompt_len = (spec.seq_len / 2).max(1);
    let engine = super::ElasticEngine::start(cfg, factory(spec, caps))?;
    let streams: Vec<super::StreamResponse> = (0..sessions as u64)
        .map(|id| {
            engine.submit_stream(super::StreamRequest::new(
                id, vec![1; prompt_len], decode_steps))
        })
        .collect();
    for s in streams {
        let stats = s
            .wait()
            .map_err(|e| anyhow::anyhow!("sim stream shed: {e}"))?;
        anyhow::ensure!(stats.steps == decode_steps,
                        "session {} stopped at {} of {decode_steps} steps",
                        stats.id, stats.steps);
    }
    let report = engine.shutdown()?;
    anyhow::ensure!(
        report.sessions_started
            == report.stream_done.len() + report.stream_shed.len(),
        "stream logs do not reconcile: {} started, {} done, {} shed",
        report.sessions_started, report.stream_done.len(),
        report.stream_shed.len());
    Ok(report)
}

/// Drive one hermetic *speculative* streaming point: like
/// [`streaming_point`], but sessions draft up to `spec_k` tokens per
/// admission at the lowest floored tier and verify them in one
/// top-tier pass (`stream::spec`).  With `spec.divergence` > 0 the
/// draft tier genuinely disagrees with the verifier some of the time,
/// so the report's accept rate lands strictly between 0 and 1.
/// Asserts the speculative ledger reconciles (`drafted == accepted +
/// rejected`, and per-class sections agree with the totals) before
/// returning the report.
pub fn speculative_point(spec: SimSpec, workers: usize, shards: usize,
                         sessions: usize, decode_steps: usize,
                         spec_k: usize) -> Result<super::ServeReport> {
    let cfg = super::ServeConfig::sim()
        .with_workers(workers)
        .with_queue_shards(shards)
        .with_queue_bound(128)
        .with_max_batch_wait(Duration::from_micros(200))
        .with_spec_k(spec_k);
    let caps = cfg.capacities();
    let prompt_len = (spec.seq_len / 2).max(1);
    let engine = super::ElasticEngine::start(cfg, factory(spec, caps))?;
    let streams: Vec<super::StreamResponse> = (0..sessions as u64)
        .map(|id| {
            engine.submit_stream(super::StreamRequest::new(
                id, vec![1; prompt_len], decode_steps))
        })
        .collect();
    for s in streams {
        let stats = s
            .wait()
            .map_err(|e| anyhow::anyhow!("sim spec stream shed: {e}"))?;
        anyhow::ensure!(stats.steps == decode_steps,
                        "session {} stopped at {} of {decode_steps} steps",
                        stats.id, stats.steps);
    }
    let report = engine.shutdown()?;
    anyhow::ensure!(
        report.sessions_started
            == report.stream_done.len() + report.stream_shed.len(),
        "stream logs do not reconcile: {} started, {} done, {} shed",
        report.sessions_started, report.stream_done.len(),
        report.stream_shed.len());
    anyhow::ensure!(
        report.spec_drafted == report.spec_accepted + report.spec_rejected,
        "speculative ledger does not reconcile: {} drafted != {} \
         accepted + {} rejected",
        report.spec_drafted, report.spec_accepted, report.spec_rejected);
    for s in report.spec_sections() {
        anyhow::ensure!(s.drafted == s.accepted + s.rejected,
                        "class {} ledger does not reconcile", s.class);
    }
    Ok(report)
}

/// Drive one hermetic *chaos* point: `n` one-shot requests plus
/// `sessions` speculative decode sessions through an engine whose sim
/// backend injects the given [`FaultPlan`] — transient failures ride
/// the retry ladder, fatal faults exercise supervised respawn, and
/// (when `fault.poison_token != 0`) request id 0 is submitted as a
/// deterministic poison that must shed as
/// [`ServeError::Poisoned`](super::ServeError::Poisoned) while every
/// co-batched neighbour completes.  Asserts exactly-once resolution
/// for every submission and that the engine NEVER closed under way
/// (no `ShuttingDown` verdict before shutdown), then returns the
/// report; callers judge availability as
/// `(completions + stream done) / (n + sessions)`.
pub fn faults_point(spec: SimSpec, workers: usize, shards: usize,
                    n: usize, sessions: usize, decode_steps: usize,
                    spec_k: usize) -> Result<super::ServeReport> {
    let cfg = super::ServeConfig::sim()
        .with_workers(workers)
        .with_queue_shards(shards)
        .with_queue_bound(128)
        .with_max_batch_wait(Duration::from_micros(200))
        .with_spec_k(spec_k)
        // zero backoff keeps the hermetic run fast (the retry COUNT is
        // what the ladder is judged by, not the sleep), and a roomy
        // restart budget lets injected fatal faults exercise respawn
        // without ever exhausting the fleet mid-run
        .with_fault_policy(super::FaultPolicy::default()
            .with_backoff_ms(0)
            .with_restart_budget(16));
    let caps = cfg.capacities();
    let prompt_len = (spec.seq_len / 2).max(1);
    let poison = spec.fault.poison_token;
    let engine = super::ElasticEngine::start(cfg, factory(spec, caps))?;
    let responses: Vec<super::Response> = (0..n as u64)
        .map(|id| {
            let tokens = if id == 0 && poison != 0 {
                vec![poison; spec.seq_len]
            } else {
                vec![1; spec.seq_len]
            };
            engine.submit(super::Request::new(id, tokens))
        })
        .collect();
    let streams: Vec<super::StreamResponse> = (0..sessions as u64)
        .map(|id| {
            engine.submit_stream(super::StreamRequest::new(
                n as u64 + id, vec![1; prompt_len], decode_steps))
        })
        .collect();
    for (i, r) in responses.into_iter().enumerate() {
        match r.wait() {
            Ok(_) => {}
            Err(super::ServeError::Poisoned(_)) => {
                anyhow::ensure!(
                    poison != 0 && i == 0,
                    "request {i} quarantined but only id 0 is poison");
            }
            Err(e) => anyhow::bail!(
                "chaos one-shot {i} resolved {e} — the fleet must \
                 absorb transient faults without an outage"),
        }
    }
    for s in streams {
        match s.wait() {
            Ok(stats) => anyhow::ensure!(
                stats.steps == decode_steps,
                "session {} stopped at {} of {decode_steps} steps",
                stats.id, stats.steps),
            Err(e) => anyhow::bail!(
                "chaos session shed {e} — streams must survive \
                 injected faults"),
        }
    }
    let report = engine.shutdown()?;
    anyhow::ensure!(
        report.sessions_started
            == report.stream_done.len() + report.stream_shed.len(),
        "stream logs do not reconcile: {} started, {} done, {} shed",
        report.sessions_started, report.stream_done.len(),
        report.stream_shed.len());
    if poison != 0 {
        anyhow::ensure!(
            report.completions.len() == n.saturating_sub(1),
            "poison run must serve exactly n-1 one-shots, served {} \
             of {n}",
            report.completions.len());
    }
    Ok(report)
}

/// Drive one hermetic *traced* point: like [`pipeline_point`] plus
/// optional decode sessions, but with the flight recorder on at
/// `capacity` events per lane.  Drains the recorder after shutdown and
/// returns the report, the merged event stream, and the exact ledger,
/// so callers can reconcile events against report counters (the
/// acceptance criterion: admits == submissions, terminals == served +
/// shed + session outcomes, verify-resolve sums == spec counters) or
/// measure tracing overhead against an untraced twin.
pub fn traced_point(spec: SimSpec, workers: usize, shards: usize,
                    n: usize, sessions: usize, decode_steps: usize,
                    spec_k: usize, capacity: usize)
                    -> Result<(super::ServeReport, Vec<super::Stamped>,
                               super::TraceCounts)> {
    let cfg = super::ServeConfig::sim()
        .with_workers(workers)
        .with_queue_shards(shards)
        .with_queue_bound(128)
        .with_max_batch_wait(Duration::from_micros(200))
        .with_spec_k(spec_k)
        .with_trace_capacity(capacity);
    let caps = cfg.capacities();
    let prompt_len = (spec.seq_len / 2).max(1);
    let engine = super::ElasticEngine::start(cfg, factory(spec, caps))?;
    let recorder = engine
        .trace_recorder()
        .ok_or_else(|| anyhow::anyhow!("traced point built no recorder"))?;
    let responses: Vec<super::Response> = (0..n as u64)
        .map(|id| {
            engine.submit(super::Request::new(id, vec![1; spec.seq_len]))
        })
        .collect();
    let streams: Vec<super::StreamResponse> = (0..sessions as u64)
        .map(|id| {
            engine.submit_stream(super::StreamRequest::new(
                n as u64 + id, vec![1; prompt_len], decode_steps))
        })
        .collect();
    for r in responses {
        r.wait()
            .map_err(|e| anyhow::anyhow!("traced sim serve failed: {e}"))?;
    }
    for s in streams {
        s.wait()
            .map_err(|e| anyhow::anyhow!("traced sim stream shed: {e}"))?;
    }
    let report = engine.shutdown()?;
    // workers are joined: the ledger is quiescent and must reconcile
    let events = recorder.drain();
    let counts = recorder.counts();
    anyhow::ensure!(
        counts.dropped + counts.exported == counts.emitted,
        "trace ledger does not reconcile: {counts:?}");
    Ok((report, events, counts))
}

/// One row of the machine-readable sim-pipeline record
/// (`BENCH_serving.json`).
pub struct BenchRow {
    /// topology label: "shared" (1 shard), "sharded" (1 per worker),
    /// "hetero" (sharded + heterogeneous worker classes), "streaming"
    /// (decode sessions through `submit_stream`), "faults" (chaos
    /// injection through [`faults_point`]), or "trace" (flight
    /// recorder on, via [`traced_point`])
    pub queue: &'static str,
    pub workers: usize,
    pub shards: usize,
    /// worker-class topology, e.g. "fast=2:slow=2"; empty = homogeneous
    pub classes: String,
    /// injected transient fault rate (chaos rows; 0 elsewhere)
    pub fault_rate: f64,
    /// total submissions (one-shots + sessions) behind this row; > 0
    /// marks a chaos row and enables the availability fields
    pub submitted: usize,
    /// traced-over-untraced req/s ratio (trace rows; 0 elsewhere) —
    /// the cost of the flight recorder on the hot path, ~1.0 when
    /// tracing is cheap
    pub trace_overhead: f64,
    pub report: super::ServeReport,
}

/// Write the sim-pipeline results as `BENCH_serving.json`-style JSON:
/// req/s, p50/p99 latency and mean capacity per (queue topology,
/// worker count), plus the sharded/shared throughput ratio per worker
/// count — the cross-PR perf-trajectory record.  Written by both the
/// release-mode `hotpath` bench (the number that counts) and the
/// hermetic `tests/bench_gate.rs` suite (so every tier-1 run refreshes
/// the file even where `cargo bench` never runs).
pub fn write_bench_json(path: &std::path::Path, source: &str,
                        spec: SimSpec, requests: usize,
                        rows: &[BenchRow]) -> Result<()> {
    use crate::json::Value;
    let spec_obj = Value::Obj(vec![
        ("batch".into(), Value::Num(spec.batch as f64)),
        ("seq_len".into(), Value::Num(spec.seq_len as f64)),
        ("base_ms".into(), Value::Num(spec.base_ms)),
        ("ms_per_capacity".into(), Value::Num(spec.ms_per_capacity)),
        ("jitter_ms".into(), Value::Num(spec.jitter_ms)),
        ("divergence".into(), Value::Num(spec.divergence)),
        ("seed".into(), Value::Num(spec.seed as f64)),
    ]);
    let results: Vec<Value> = rows
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("queue".into(), Value::Str(r.queue.to_string())),
                ("workers".into(), Value::Num(r.workers as f64)),
                ("shards".into(), Value::Num(r.shards as f64)),
                ("worker_classes".into(), Value::Str(r.classes.clone())),
                ("req_per_s".into(),
                 Value::Num(r.report.throughput_rps())),
                ("p50_ms".into(), Value::Num(r.report.latency_p(0.5))),
                ("p99_ms".into(), Value::Num(r.report.latency_p(0.99))),
                ("mean_capacity".into(),
                 Value::Num(r.report.mean_capacity())),
                ("served".into(),
                 Value::Num(r.report.completions.len() as f64)),
            ];
            if r.report.sessions_started > 0 {
                // streaming rows record the session economy: how many
                // sessions ran, how many tokens landed, and tokens/s
                let done = &r.report.stream_done;
                let steps: usize = done.iter().map(|s| s.steps).sum::<usize>()
                    + r.report.stream_shed.iter()
                        .map(|s| s.steps_done).sum::<usize>();
                fields.push(("sessions".into(),
                             Value::Num(r.report.sessions_started as f64)));
                fields.push(("sessions_completed".into(),
                             Value::Num(done.len() as f64)));
                fields.push(("sessions_shed".into(),
                             Value::Num(r.report.stream_shed.len() as f64)));
                fields.push(("stream_tokens".into(),
                             Value::Num(steps as f64)));
                fields.push(("tokens_per_s".into(),
                             Value::Num(r.report.tokens_per_s())));
                fields.push(("cache_hit_rate".into(),
                             Value::Num(r.report.cache_hit_rate())));
                // the speculative economy: how often the cheap draft
                // tier agreed with the verifier, and how many tokens
                // each admission item bought (1.0 = plain decode)
                fields.push(("spec_accept_rate".into(),
                             Value::Num(r.report.spec_accept_rate())));
                fields.push(("tokens_per_admission".into(),
                             Value::Num(r.report.tokens_per_admission())));
            }
            if r.submitted > 0 {
                // chaos rows record availability under injected
                // faults plus the fault-ladder economy (retries,
                // bisections, quarantines, respawns, breaker trips)
                let served = r.report.completions.len()
                    + r.report.stream_done.len();
                let (mut retries, mut splits, mut poisoned) = (0, 0, 0);
                let (mut respawns, mut trips) = (0, 0);
                for s in r.report.fault_sections() {
                    retries += s.retries;
                    splits += s.splits;
                    poisoned += s.poisoned;
                    respawns += s.respawns;
                    trips += s.breaker_trips;
                }
                fields.push(("fault_rate".into(),
                             Value::Num(r.fault_rate)));
                fields.push(("submitted".into(),
                             Value::Num(r.submitted as f64)));
                fields.push(("availability".into(),
                             Value::Num(served as f64
                                 / r.submitted as f64)));
                fields.push(("retries".into(),
                             Value::Num(retries as f64)));
                fields.push(("splits".into(), Value::Num(splits as f64)));
                fields.push(("poisoned".into(),
                             Value::Num(poisoned as f64)));
                fields.push(("respawns".into(),
                             Value::Num(respawns as f64)));
                fields.push(("breaker_trips".into(),
                             Value::Num(trips as f64)));
            }
            if r.trace_overhead > 0.0 {
                // trace rows record what the flight recorder costs:
                // traced req/s over the untraced twin's req/s
                fields.push(("trace_overhead".into(),
                             Value::Num(r.trace_overhead)));
            }
            if r.report.worker_classes.len() > 1 {
                // heterogeneous rows also record how each device class
                // fared — the per-class controllers are the point
                let secs: Vec<Value> = r
                    .report
                    .worker_class_sections()
                    .into_iter()
                    .map(|s| {
                        Value::Obj(vec![
                            ("class".into(), Value::Str(s.class)),
                            ("workers".into(),
                             Value::Num(s.workers as f64)),
                            ("served".into(), Value::Num(s.served as f64)),
                            ("mean_capacity".into(),
                             Value::Num(s.mean_capacity)),
                        ])
                    })
                    .collect();
                fields.push(("class_sections".into(), Value::Arr(secs)));
            }
            Value::Obj(fields)
        })
        .collect();
    let mut speedups: Vec<(String, Value)> = Vec::new();
    for r in rows.iter().filter(|r| r.queue == "sharded") {
        if let Some(base) = rows
            .iter()
            .find(|b| b.queue == "shared" && b.workers == r.workers)
        {
            let ratio = r.report.throughput_rps()
                / base.report.throughput_rps().max(1e-9);
            speedups.push((format!("w{}", r.workers), Value::Num(ratio)));
        }
    }
    let doc = Value::Obj(vec![
        ("bench".into(), Value::Str("sim_pipeline".into())),
        ("source".into(), Value::Str(source.to_string())),
        ("requests".into(), Value::Num(requests as f64)),
        ("spec".into(), spec_obj),
        ("results".into(), Value::Arr(results)),
        ("speedup_sharded_over_shared".into(), Value::Obj(speedups)),
    ]);
    crate::metrics::write_file(path, &crate::json::to_string_pretty(&doc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_model_is_deterministic_per_worker() {
        let spec = SimSpec::standard();
        let tiers = [1.0f32, 0.5];
        let mut a = SimExecutor::new(spec, &tiers, 3);
        let mut b = SimExecutor::new(spec, &tiers, 3);
        let mut c = SimExecutor::new(spec, &tiers, 4);
        let xs: Vec<f64> = (0..8).map(|_| a.latency_ms(1.0)).collect();
        let ys: Vec<f64> = (0..8).map(|_| b.latency_ms(1.0)).collect();
        let zs: Vec<f64> = (0..8).map(|_| c.latency_ms(1.0)).collect();
        assert_eq!(xs, ys, "same worker stream must repeat");
        assert_ne!(xs, zs, "distinct workers must get distinct streams");
    }

    #[test]
    fn lower_tiers_are_cheaper() {
        let spec = SimSpec { jitter_ms: 0.0, ..SimSpec::standard() };
        let mut e = SimExecutor::new(spec, &[1.0, 0.25], 0);
        assert!(e.latency_ms(0.25) < e.latency_ms(1.0));
    }

    #[test]
    fn execute_validates_shape_and_tier() {
        let spec = SimSpec { batch: 2, seq_len: 3, ..SimSpec::instant() };
        let mut e = SimExecutor::new(spec, &[1.0, 0.5], 0);
        let out = e.execute(1.0, &[0; 6]).unwrap();
        assert_eq!(out.logits, vec![1.0, 1.0], "one row per batch slot");
        assert!(e.execute(1.0, &[0; 5]).is_err(), "wrong token count");
        assert!(e.execute(0.33, &[0; 6]).is_err(), "unconfigured tier");
        assert_eq!(e.log.len(), 1);
    }

    #[test]
    fn pipeline_point_serves_everything_and_bench_json_roundtrips() {
        let spec = SimSpec { batch: 4, seq_len: 8, ..SimSpec::instant() };
        let shared = pipeline_point(spec, 2, 1, 24).unwrap();
        let sharded = pipeline_point(spec, 2, 2, 24).unwrap();
        assert_eq!(shared.completions.len(), 24);
        assert_eq!(sharded.completions.len(), 24);
        let rows = vec![
            BenchRow { queue: "shared", workers: 2, shards: 1,
                       classes: String::new(), fault_rate: 0.0,
                       submitted: 0, trace_overhead: 0.0,
                       report: shared },
            BenchRow { queue: "sharded", workers: 2, shards: 2,
                       classes: String::new(), fault_rate: 0.0,
                       submitted: 0, trace_overhead: 0.0,
                       report: sharded },
        ];
        let path = std::env::temp_dir().join(format!(
            "ef_bench_serving_{}.json", std::process::id()));
        write_bench_json(&path, "sim.rs unit test", spec, 24, &rows)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = crate::json::parse(&text).unwrap();
        assert_eq!(doc.req("bench").unwrap().as_str().unwrap(),
                   "sim_pipeline");
        assert_eq!(doc.req("results").unwrap().as_arr().unwrap().len(), 2);
        let ratio = doc
            .req("speedup_sharded_over_shared").unwrap()
            .req("w2").unwrap()
            .as_f64().unwrap();
        assert!(ratio.is_finite() && ratio > 0.0, "ratio {ratio}");
    }

    #[test]
    fn hetero_pipeline_point_serves_everything_and_reports_classes() {
        let fast = SimSpec { batch: 4, seq_len: 8, ..SimSpec::instant() };
        let slow = SimSpec { base_ms: 0.2, ..fast };
        let report =
            pipeline_point_classes(&[("fast", fast, 2), ("slow", slow, 2)],
                                   4, 32)
                .unwrap();
        assert_eq!(report.completions.len(), 32);
        let mut ids: Vec<u64> =
            report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..32).collect::<Vec<_>>());
        assert_eq!(report.worker_classes.len(), 2);
        let rows = vec![BenchRow {
            queue: "hetero",
            workers: 4,
            shards: 4,
            classes: "fast=2:slow=2".into(),
            fault_rate: 0.0,
            submitted: 0,
            trace_overhead: 0.0,
            report,
        }];
        let path = std::env::temp_dir().join(format!(
            "ef_bench_hetero_{}.json", std::process::id()));
        write_bench_json(&path, "sim.rs unit test", fast, 32, &rows)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = crate::json::parse(&text).unwrap();
        let row = &doc.req("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.req("worker_classes").unwrap().as_str().unwrap(),
                   "fast=2:slow=2");
        let secs = row.req("class_sections").unwrap().as_arr().unwrap();
        assert_eq!(secs.len(), 2, "hetero rows carry per-class sections");
    }

    #[test]
    fn streaming_point_completes_sessions_and_bench_row_roundtrips() {
        let spec = SimSpec { batch: 4, seq_len: 8, ..SimSpec::instant() };
        let report = streaming_point(spec, 2, 2, 6, 5).unwrap();
        assert_eq!(report.sessions_started, 6);
        assert_eq!(report.stream_done.len(), 6);
        assert!(report.stream_shed.is_empty());
        assert!(report.stream_done.iter().all(
            |s| s.steps == 5 && s.tiers.len() == 5));
        assert!(report.tokens_per_s() > 0.0);
        let rows = vec![BenchRow {
            queue: "streaming",
            workers: 2,
            shards: 2,
            classes: String::new(),
            fault_rate: 0.0,
            submitted: 0,
            trace_overhead: 0.0,
            report,
        }];
        let path = std::env::temp_dir().join(format!(
            "ef_bench_streaming_{}.json", std::process::id()));
        write_bench_json(&path, "sim.rs unit test", spec, 6, &rows)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = crate::json::parse(&text).unwrap();
        let row = &doc.req("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.req("queue").unwrap().as_str().unwrap(),
                   "streaming");
        assert_eq!(row.req("sessions").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(row.req("stream_tokens").unwrap().as_f64().unwrap(),
                   30.0);
        let tps = row.req("tokens_per_s").unwrap().as_f64().unwrap();
        assert!(tps.is_finite() && tps > 0.0, "tokens/s {tps}");
        // the default arena is live, so some decode rows must have hit
        let chr = row.req("cache_hit_rate").unwrap().as_f64().unwrap();
        assert!(chr.is_finite() && chr > 0.0, "cache hit rate {chr}");
    }

    #[test]
    fn divergence_flips_floored_rows_but_never_the_top_tier() {
        let spec = SimSpec {
            batch: 8,
            seq_len: 4,
            divergence: 1.0,
            ..SimSpec::instant()
        };
        let tokens = vec![0; spec.batch * spec.seq_len];
        let mut e = SimExecutor::new(spec, &[1.0, 0.25], 0);
        // top tier: divergence probability is exactly 0 — the verifier
        // is the ground truth and never disagrees with itself
        let out = e.execute(1.0, &tokens).unwrap();
        assert_eq!(out.logits.len(), 16, "two logits per row");
        for row in out.logits.chunks(2) {
            assert!(row[0] > row[1], "top tier row diverged: {row:?}");
        }
        // floored tier at full divergence: p = 0.75, so over a few
        // batches some rows flip to token 1 and some stay at token 0
        let (mut flips, mut total) = (0usize, 0usize);
        for _ in 0..8 {
            let out = e.execute(0.25, &tokens).unwrap();
            for row in out.logits.chunks(2) {
                total += 1;
                if row[1] > row[0] {
                    flips += 1;
                }
            }
        }
        assert!(flips > 0, "floored tier never diverged");
        assert!(flips < total, "floored tier always diverged");
        // divergence 0 preserves the legacy single-logit rows exactly
        let plain_spec = SimSpec {
            batch: 8,
            seq_len: 4,
            ..SimSpec::instant()
        };
        let mut plain = SimExecutor::new(plain_spec, &[1.0, 0.25], 0);
        assert_eq!(plain.execute(0.25, &tokens).unwrap().logits,
                   vec![0.25f32; 8]);
    }

    #[test]
    fn speculative_point_reconciles_and_beats_plain_admission_economy() {
        let spec = SimSpec {
            batch: 8,
            seq_len: 8,
            divergence: 0.05,
            ..SimSpec::instant()
        };
        let report = speculative_point(spec, 2, 2, 6, 12, 4).unwrap();
        assert_eq!(report.stream_done.len(), 6);
        assert!(report.stream_shed.is_empty());
        assert!(report.spec_drafted > 0, "speculative mode must draft");
        assert_eq!(report.spec_drafted,
                   report.spec_accepted + report.spec_rejected);
        assert!(report.spec_accept_rate() > 0.0,
                "mild divergence must still accept most drafts");
        assert!(report.tokens_per_admission() > 1.0,
                "accepted drafts must beat the one-token-per-item \
                 plain-decode economy, got {}",
                report.tokens_per_admission());
        assert!(!report.spec_sections().is_empty());
    }

    #[test]
    fn zero_fault_plan_replays_legacy_rng_streams_bit_identically() {
        // the chaos draws are gated behind p > 0: a default FaultPlan
        // must consume no RNG, so pre-chaos latency/divergence
        // sequences replay exactly
        let spec = SimSpec { batch: 2, seq_len: 2, ..SimSpec::standard() };
        let tokens = vec![1; 4];
        let mut a = SimExecutor::new(spec, &[1.0], 0);
        let mut b = SimExecutor::new(
            spec.with_fault(FaultPlan::default()), &[1.0], 0);
        for _ in 0..6 {
            a.execute(1.0, &tokens).unwrap();
            b.execute(1.0, &tokens).unwrap();
        }
        let am: Vec<f64> = a.log.iter().map(|l| l.modeled_ms).collect();
        let bm: Vec<f64> = b.log.iter().map(|l| l.modeled_ms).collect();
        assert_eq!(am, bm);
    }

    #[test]
    fn injected_faults_are_deterministic_and_classified() {
        let spec = SimSpec {
            batch: 2,
            seq_len: 2,
            fault: FaultPlan { fail_p: 0.5, ..FaultPlan::default() },
            ..SimSpec::instant()
        };
        let tokens = vec![1; 4];
        let run = |mut e: SimExecutor| -> Vec<bool> {
            (0..32).map(|_| e.execute(1.0, &tokens).is_ok()).collect()
        };
        let xs = run(SimExecutor::new(spec, &[1.0], 0));
        let ys = run(SimExecutor::new(spec, &[1.0], 0));
        assert_eq!(xs, ys, "same seed must inject the same faults");
        assert!(xs.iter().any(|&ok| ok) && xs.iter().any(|&ok| !ok),
                "fail_p 0.5 must both fail and succeed over 32 draws");
        // fatal faults carry the FatalExecError marker in the chain
        let fatal_spec = SimSpec {
            fault: FaultPlan { fatal_p: 1.0, ..FaultPlan::default() },
            ..spec
        };
        let mut f = SimExecutor::new(fatal_spec, &[1.0], 0);
        let err = f.execute(1.0, &tokens).unwrap_err();
        assert!(err.chain().any(
                    |c| c.downcast_ref::<FatalExecError>().is_some()),
                "injected fatal fault must be marked fatal");
        // the poison marker always fails, independent of the RNG
        let poison_spec = SimSpec {
            fault: FaultPlan { poison_token: 7,
                               ..FaultPlan::default() },
            ..spec
        };
        let mut p = SimExecutor::new(poison_spec, &[1.0], 0);
        for _ in 0..8 {
            assert!(p.execute(1.0, &[7, 1, 1, 1]).is_err());
        }
        assert!(p.execute(1.0, &tokens).is_ok(),
                "unpoisoned batches still serve");
    }

    #[test]
    fn faults_point_quarantines_poison_and_bench_row_roundtrips() {
        let spec = SimSpec {
            batch: 4,
            seq_len: 8,
            fault: FaultPlan { fail_p: 0.2, poison_token: 7,
                               ..FaultPlan::default() },
            ..SimSpec::instant()
        };
        let report = faults_point(spec, 2, 2, 40, 4, 5, 2).unwrap();
        assert_eq!(report.completions.len(), 39,
                   "all but the poison one-shot must serve");
        assert_eq!(report.stream_done.len(), 4);
        let secs = report.fault_sections();
        assert!(!secs.is_empty(), "chaos must leave fault sections");
        assert!(secs.iter().map(|s| s.poisoned).sum::<usize>() >= 1);
        assert!(secs.iter().map(|s| s.retries).sum::<usize>() > 0,
                "fail_p 0.2 must force retries");
        let rows = vec![BenchRow {
            queue: "faults",
            workers: 2,
            shards: 2,
            classes: String::new(),
            fault_rate: 0.2,
            submitted: 44,
            trace_overhead: 0.0,
            report,
        }];
        let path = std::env::temp_dir().join(format!(
            "ef_bench_faults_{}.json", std::process::id()));
        write_bench_json(&path, "sim.rs unit test", spec, 44, &rows)
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let doc = crate::json::parse(&text).unwrap();
        let row = &doc.req("results").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.req("queue").unwrap().as_str().unwrap(), "faults");
        let avail = row.req("availability").unwrap().as_f64().unwrap();
        assert!(avail > 0.9 && avail <= 1.0, "availability {avail}");
        let poisoned = row.req("poisoned").unwrap().as_f64().unwrap();
        let submitted = row.req("submitted").unwrap().as_f64().unwrap();
        assert!(poisoned >= 1.0 && poisoned <= submitted);
    }

    #[test]
    fn traced_point_reconciles_events_with_the_report() {
        // the PR's acceptance criterion, as a seeded hermetic run:
        // admit events == submissions, terminal events == every
        // resolution the report knows about, and the speculative
        // event stream sums to exactly the report's spec counters
        let spec = SimSpec {
            batch: 4,
            seq_len: 8,
            divergence: 0.05,
            ..SimSpec::instant()
        };
        let (n, sessions, steps) = (24usize, 4usize, 6usize);
        let (report, events, counts) =
            traced_point(spec, 2, 2, n, sessions, steps, 2, 4096)
                .unwrap();
        assert_eq!(report.completions.len(), n);
        assert_eq!(report.stream_done.len(), sessions);
        let count_kind = |k: &str| {
            events.iter().filter(|e| e.kind() == k).count()
        };
        assert_eq!(count_kind("admit"), n + sessions,
                   "one admit per submission");
        let resolutions = report.completions.len() + report.sheds.len()
            + report.stream_done.len() + report.stream_shed.len();
        assert_eq!(count_kind("terminal"), resolutions,
                   "exactly one terminal per resolved request/session");
        assert!(events.iter().all(|e| {
            e.kind() != "terminal" && e.kind() != "admit"
                || e.trace_id != 0
        }), "lifecycle events always carry a real trace id");
        // the speculative ledger, replayed from the event stream
        let (mut acc, mut rej) = (0usize, 0usize);
        for e in &events {
            if let Some((a, r)) = e.verify_counts() {
                acc += a;
                rej += r;
            }
        }
        assert_eq!((acc, rej),
                   (report.spec_accepted, report.spec_rejected),
                   "verify-resolve events must sum to the spec totals");
        assert!(report.spec_drafted > 0 && count_kind("draft-round") > 0,
                "speculative mode must draft and emit draft rounds");
        // nothing overflowed at this capacity, so the export is total
        assert_eq!(counts.dropped, 0);
        assert_eq!(counts.exported, events.len() as u64);
    }

    #[test]
    fn recomputed_rows_cost_seq_len_and_cached_rows_cost_one() {
        let spec = SimSpec {
            batch: 4,
            seq_len: 16,
            base_ms: 0.0,
            ms_per_capacity: 0.0,
            jitter_ms: 0.0,
            recompute_ms_per_token: 0.001,
            ..SimSpec::standard()
        };
        let tokens = vec![0; spec.batch * spec.seq_len];
        let mut e = SimExecutor::new(spec, &[1.0], 0);
        e.note_batch_mix(4, 0);
        e.execute(1.0, &tokens).unwrap();
        e.note_batch_mix(0, 4);
        e.execute(1.0, &tokens).unwrap();
        // the announced mix is consumed: an unannounced batch pays no
        // window cost at all
        e.execute(1.0, &tokens).unwrap();
        let recompute = e.log[0].modeled_ms;
        let cached = e.log[1].modeled_ms;
        assert!((recompute - 0.001 * 64.0).abs() < 1e-12,
                "recompute {recompute}");
        assert!((cached - 0.001 * 4.0).abs() < 1e-12, "cached {cached}");
        assert_eq!(e.log[2].modeled_ms, 0.0);
        assert!(recompute / cached > 10.0,
                "hit path must be O(1) in window length, got \
                 {recompute} vs {cached}");
    }

    #[test]
    fn log_records_modeled_and_wall_on_one_clock() {
        let spec = SimSpec {
            batch: 1,
            seq_len: 1,
            base_ms: 1.0,
            ms_per_capacity: 0.0,
            jitter_ms: 0.0,
            ..SimSpec::standard()
        };
        let mut e = SimExecutor::new(spec, &[1.0], 0);
        e.execute(1.0, &[0]).unwrap();
        let entry = e.log[0];
        assert_eq!(entry.modeled_ms, 1.0);
        // wall time is measured, non-negative, and at least the sleep
        // on a sane scheduler — but the invariant we rely on elsewhere
        // is only non-negativity on the shared clock
        assert!(entry.wall_ms >= 0.0);
    }
}
