//! Flat-parameter checkpoint format.
//!
//! Binary layout (little-endian):
//!   magic  b"EFCK"            | version u32 (=1)
//!   config name (u32 len + utf8) | kind (u32 len + utf8, e.g. "teacher",
//!   "router_r8")              | step u64 | param count u64 | f32 data
//!
//! The param count is validated against the manifest layout at load time;
//! `noise` implements the Fig. 4 "student = teacher + gaussian noise"
//! perturbation without round-tripping through Python.

use std::fs;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::rng::Rng;

const MAGIC: &[u8; 4] = b"EFCK";
const VERSION: u32 = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub config: String,
    pub kind: String,
    pub step: u64,
    pub params: Vec<f32>,
}

impl Checkpoint {
    pub fn new(config: &str, kind: &str, step: u64, params: Vec<f32>) -> Self {
        Checkpoint {
            config: config.to_string(),
            kind: kind.to_string(),
            step,
            params,
        }
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut buf = Vec::with_capacity(self.params.len() * 4 + 64);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        write_str(&mut buf, &self.config);
        write_str(&mut buf, &self.kind);
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for p in &self.params {
            buf.extend_from_slice(&p.to_le_bytes());
        }
        let tmp = path.as_ref().with_extension("tmp");
        fs::write(&tmp, &buf)?;
        fs::rename(&tmp, path.as_ref())
            .with_context(|| format!("rename to {:?}", path.as_ref()))?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut f = fs::File::open(&path)
            .with_context(|| format!("open checkpoint {:?}", path.as_ref()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated checkpoint");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 4)? != MAGIC {
            bail!("bad magic (not an EFCK checkpoint)");
        }
        let ver = u32::from_le_bytes(take(&mut pos, 4)?.try_into()?);
        if ver != VERSION {
            bail!("unsupported checkpoint version {ver}");
        }
        let config = read_str(&buf, &mut pos)?;
        let kind = read_str(&buf, &mut pos)?;
        let step = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?);
        let n = u64::from_le_bytes(take(&mut pos, 8)?.try_into()?) as usize;
        if buf.len() - pos != n * 4 {
            bail!("checkpoint data length mismatch: header says {} params, \
                   file has {} bytes of data", n, buf.len() - pos);
        }
        let mut params = Vec::with_capacity(n);
        for i in 0..n {
            let off = pos + i * 4;
            params.push(f32::from_le_bytes(buf[off..off + 4].try_into()?));
        }
        Ok(Checkpoint { config, kind, step, params })
    }

    /// Validate against an expected layout size.
    pub fn expect(&self, config: &str, kind: &str, n: usize) -> Result<()> {
        if self.config != config {
            bail!("checkpoint is for config {:?}, wanted {:?}",
                  self.config, config);
        }
        if self.kind != kind {
            bail!("checkpoint kind {:?}, wanted {:?}", self.kind, kind);
        }
        if self.params.len() != n {
            bail!("checkpoint has {} params, layout wants {}",
                  self.params.len(), n);
        }
        Ok(())
    }

    /// Fig. 4's noised student: params + N(0, std).
    pub fn noised(&self, std: f32, seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let params = self
            .params
            .iter()
            .map(|&p| p + rng.gaussian_f32(std))
            .collect();
        Checkpoint {
            config: self.config.clone(),
            kind: format!("{}_noised", self.kind),
            step: self.step,
            params,
        }
    }
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn read_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    if *pos + 4 > buf.len() {
        bail!("truncated checkpoint (string length)");
    }
    let n = u32::from_le_bytes(buf[*pos..*pos + 4].try_into()?) as usize;
    *pos += 4;
    if *pos + n > buf.len() {
        bail!("truncated checkpoint (string body)");
    }
    let s = String::from_utf8(buf[*pos..*pos + n].to_vec())?;
    *pos += n;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("efck_test_{name}.bin"))
    }

    #[test]
    fn roundtrip() {
        let ck = Checkpoint::new("lm_tiny", "teacher", 123,
                                 vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        let path = tmpfile("roundtrip");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn expect_validates() {
        let ck = Checkpoint::new("lm_tiny", "router_r8", 0, vec![0.0; 10]);
        assert!(ck.expect("lm_tiny", "router_r8", 10).is_ok());
        assert!(ck.expect("lm_base", "router_r8", 10).is_err());
        assert!(ck.expect("lm_tiny", "teacher", 10).is_err());
        assert!(ck.expect("lm_tiny", "router_r8", 11).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let ck = Checkpoint::new("c", "k", 0, vec![1.0; 8]);
        let path = tmpfile("corrupt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn noise_changes_params_deterministically() {
        let ck = Checkpoint::new("c", "teacher", 0, vec![0.0; 100]);
        let n1 = ck.noised(0.1, 7);
        let n2 = ck.noised(0.1, 7);
        assert_eq!(n1.params, n2.params);
        assert!(n1.params.iter().any(|&p| p != 0.0));
        assert_eq!(n1.kind, "teacher_noised");
        let rms = (n1.params.iter().map(|p| p * p).sum::<f32>() / 100.0).sqrt();
        assert!((rms - 0.1).abs() < 0.05, "rms {rms}");
    }
}
